#!/usr/bin/env python3
"""Intra-repo link checker for docs/*.md and README.md (stdlib only).

Every relative markdown link must resolve to a real file (directories
count), and a ``#fragment`` pointing into a markdown file must match one
of that file's headings (GitHub-style slugs).  External links (with a
scheme) are not fetched — this guards the repo's own structure, not the
internet.  Exit 0 = all links resolve; nonzero prints one line per
breakage.

Run: python scripts/check_docs_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE = re.compile(r"^(```|~~~)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$")


def strip_fenced_code(text: str) -> str:
    """Drop fenced code blocks — link syntax inside them is illustrative."""
    out, fenced = [], False
    for line in text.splitlines():
        if FENCE.match(line.strip()):
            fenced = not fenced
            continue
        out.append("" if fenced else line)
    return "\n".join(out)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, spaces → dashes, punctuation out."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(md_path: Path) -> set[str]:
    slugs = set()
    for line in strip_fenced_code(md_path.read_text()).splitlines():
        m = HEADING.match(line)
        if m:
            slugs.add(github_slug(m.group(1)))
    return slugs


def check_file(md_path: Path, repo: Path) -> list[str]:
    errors = []
    text = strip_fenced_code(md_path.read_text())
    for target in LINK.findall(text):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:, ...
            continue
        path_part, _, fragment = target.partition("#")
        if not path_part:                               # same-file anchor
            dest = md_path
        else:
            dest = (md_path.parent / path_part).resolve()
            try:
                dest.relative_to(repo)
            except ValueError:
                errors.append(f"{md_path}: link escapes the repo: {target}")
                continue
            if not dest.exists():
                errors.append(f"{md_path}: broken link: {target}")
                continue
        if fragment and dest.suffix == ".md" and dest.exists():
            if fragment not in anchors_of(dest):
                errors.append(f"{md_path}: missing anchor: {target}")
    return errors


def main() -> int:
    repo = Path(__file__).resolve().parents[1]
    files = sorted((repo / "docs").glob("*.md")) + [repo / "README.md"]
    missing = [f for f in files if not f.exists()]
    errors = [f"missing doc file: {f}" for f in missing]
    for f in files:
        if f.exists():
            errors.extend(check_file(f, repo))
    for e in errors:
        print(e, file=sys.stderr)
    n_files = len(files) - len(missing)
    if not errors:
        print(f"docs links OK ({n_files} files)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
