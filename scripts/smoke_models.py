"""Dev smoke: tiny config per family, forward + prefill + decode + train grad."""
import jax
import jax.numpy as jnp

from repro.models import LM, ModelConfig, MoECfg, SSMCfg, HybridCfg
from repro.models.steps import make_train_step, init_train_state, cross_entropy

B, S, V = 2, 16, 64


def tiny(family, **kw):
    base = dict(name=f"tiny-{family}", family=family, n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab=V, param_dtype="float32",
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


CFGS = {
    "dense": tiny("dense", qkv_bias=True),
    "swa": tiny("dense", sliding_window=8),
    "vlm": tiny("vlm", m_rope=True, m_rope_sections=(2, 1, 1), n_vision_patches=4),
    # capacity_factor=4.0 ⇒ no token drops at this size, so the decode-vs-
    # full-forward consistency check is exact (capacity drops are the one
    # legitimate prefill/decode divergence in MoE; tested in tests/test_models)
    "moe": tiny("moe", moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=32,
                                  capacity_factor=4.0)),
    "ssm1": tiny("ssm", n_heads=0, n_kv_heads=0, d_ff=0,
                 ssm=SSMCfg(d_state=4, version=1)),
    "ssm2": tiny("ssm", n_heads=0, n_kv_heads=0, d_ff=0,
                 ssm=SSMCfg(d_state=4, version=2, headdim=8)),
    "hybrid": tiny("hybrid", n_heads=4, n_kv_heads=4, d_ff=64,
                   ssm=SSMCfg(d_state=4, version=2, headdim=8),
                   hybrid=HybridCfg(attn_every=2, n_shared_blocks=2)),
    "audio": tiny("audio", enc_dec=True, n_enc_layers=2),
}


def inputs_for(cfg, key):
    out = {"tokens": jax.random.randint(key, (B, S), 0, V)}
    if cfg.family == "vlm":
        out["patches"] = jnp.ones((B, cfg.n_vision_patches, cfg.d_model), jnp.float32)
    if cfg.enc_dec:
        out["frames"] = jnp.ones((B, S, cfg.d_model), jnp.float32)
    return out


for name, cfg in CFGS.items():
    key = jax.random.PRNGKey(0)
    params, axes = LM.init(key, cfg)
    # axes mirrors params?
    jax.tree.map(lambda p, a: None, params,
                 jax.tree.map(lambda x: x, axes,
                              is_leaf=lambda x: isinstance(x, tuple)))
    batch = inputs_for(cfg, key)
    logits, aux = LM.apply(params, batch, cfg)
    assert logits.shape == (B, S, V), (name, logits.shape)
    assert not jnp.isnan(logits).any(), name

    # prefill + decode consistency with full forward
    lp, cache = LM.prefill(params, batch, cfg, max_seq=S + 4)
    assert lp.shape == (B, 1, V)
    err = jnp.max(jnp.abs(lp[:, 0] - logits[:, -1]))
    tok = jnp.argmax(lp[:, 0], -1)[:, None]
    ld, cache2 = LM.decode(params, tok, cfg, cache)
    assert ld.shape == (B, 1, V)
    assert not jnp.isnan(ld).any(), name

    # verify decode matches a full forward on the extended sequence
    if not cfg.enc_dec and cfg.family != "vlm":
        batch2 = {"tokens": jnp.concatenate([batch["tokens"], tok], axis=1)}
        logits2, _ = LM.apply(params, batch2, cfg)
        derr = jnp.max(jnp.abs(ld[:, 0] - logits2[:, -1]))
    else:
        derr = jnp.zeros(())

    # one train step
    batch_t = dict(batch)
    batch_t["labels"] = batch["tokens"]
    train_step, (opt_init, _) = make_train_step(cfg, lr=1e-3)
    state = init_train_state(key, cfg, opt_init)
    state2, metrics = jax.jit(train_step)(state, batch_t)
    assert jnp.isfinite(metrics["loss"]), name
    print(f"{name:8s} ok  loss={float(metrics['loss']):.3f} "
          f"prefill_err={float(err):.2e} decode_err={float(derr):.2e}")

print("ALL OK")
