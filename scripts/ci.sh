#!/usr/bin/env bash
# Tier-1 CI entrypoint: install dev deps, run the Pallas kernel-equivalence
# suites first (the `kernels` marker — fast signal when a kernel change
# breaks oracle parity), then the main suite, then the chaos soak standalone
# (the `chaos` marker: scripted kills + straggler evictions over a mixed
# proc/TCP fleet).  Record the decode-kernel ablation (BENCH_decode.json)
# and the replica-fabric smokes: TCP (2 local workers + the submit-batching
# RPC before/after — BENCH_serving.json) and proc (BENCH_serving_proc.json)
# — perf-trajectory artifacts the workflow uploads — then the closed-loop
# serving smoke.  Mirrors .github/workflows/ci.yml so the same command
# works locally.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install --quiet -r requirements-dev.txt

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q -m kernels
python -m pytest -x -q -m "not kernels and not chaos"
python -m pytest -x -q -m chaos
python -m benchmarks.serving_latency --kernel both --smoke --out BENCH_decode.json
python -m benchmarks.serving_latency --topology tcp --smoke --out BENCH_serving.json
python -m benchmarks.serving_latency --topology proc --smoke --out BENCH_serving_proc.json
python examples/serve_autoscale.py --smoke
