#!/usr/bin/env bash
# Tier-1 CI entrypoint: install dev deps, run the full suite, then the
# closed-loop serving smoke (examples/serve_autoscale.py --smoke).
# Mirrors .github/workflows/ci.yml so the same command works locally.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install --quiet -r requirements-dev.txt

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q
python examples/serve_autoscale.py --smoke
