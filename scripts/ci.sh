#!/usr/bin/env bash
# Tier-1 CI entrypoint: install dev deps, run the Pallas kernel-equivalence
# suites first (the `kernels` marker — fast signal when a kernel change
# breaks oracle parity), then the rest of the suite, record the decode-kernel
# ablation (BENCH_decode.json) and the replica-fabric smoke on the
# multi-process topology (BENCH_serving.json) — both perf-trajectory
# artifacts the workflow uploads — then the closed-loop serving smoke.
# Mirrors .github/workflows/ci.yml so the same command works locally.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install --quiet -r requirements-dev.txt

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q -m kernels
python -m pytest -x -q -m "not kernels"
python -m benchmarks.serving_latency --kernel both --smoke --out BENCH_decode.json
python -m benchmarks.serving_latency --topology proc --smoke --out BENCH_serving.json
python examples/serve_autoscale.py --smoke
