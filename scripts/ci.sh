#!/usr/bin/env bash
# Tier-1 CI entrypoint: install dev deps, run the Pallas kernel-equivalence
# suites first (the `kernels` marker — fast signal when a kernel change
# breaks oracle parity), then the main suite, then the chaos soak standalone
# (the `chaos` marker: scripted kills + straggler evictions over a mixed
# proc/TCP fleet), then the docs job (intra-repo links in docs/*.md +
# README must resolve — stdlib checker, no new deps).  Record the
# decode-kernel ablation plus the speculative-decoding tokens/s ablation
# (spec-on vs spec-off × pallas/ref × dense/paged on a prompt-echo workload;
# exits nonzero if a greedy stream diverges from plain decode, a greedy arm
# pulls host logits, or speculation regresses tokens/s — both merged into
# BENCH_decode.json) and the replica-fabric smokes:
# TCP (2 local workers + the submit-batching RPC before/after —
# BENCH_serving.json), proc (BENCH_serving_proc.json), and the gated
# ≥2-process pod smoke (jax.distributed ranks via --pod-rank; skips cleanly
# where multi-process init is unavailable — BENCH_serving_pod.json), and the
# KV-pool ablation (paged block tables vs dense rings at fixed cache HBM:
# ≥2x concurrent in-flight + shared-prefix prefill savings, streams
# bit-identical — BENCH_paged.json), and the learned-policy A/B (record a
# planner fleet trace, offline-train the allocator on it, redeploy it as
# the hybrid scaler vs the pure planner under identical chaos; bars: no
# worse on SLO-violation rate and slot utilization —
# BENCH_learned_policy.json), and the heterogeneous-fleet tier ablation
# (profile-aware planner + laned admission + scripted spot preemptions vs
# a blind flat fleet; bars: interactive tw-p95 inside the SLO under
# preemptions, every submitted request completes, aware spend below blind —
# BENCH_tiers.json), the multi-region geo ablation (replicas striped
# across two regions with the plan's RTT matrix injected as virtual-clock
# delay and the spot leg priced by the seeded market; bars: region-aware
# beats region-blind on interactive traffic-weighted p95 at no higher
# realized cost, every request completes — BENCH_regions.json), and the
# sim-side five-region sweep (util gain + cost reduction must hold in
# every region — BENCH_multi_region.json) — perf-trajectory artifacts the
# workflow uploads — then the closed-loop serving smoke.  Mirrors .github/workflows/ci.yml so the same command
# works locally.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install --quiet -r requirements-dev.txt

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q -m kernels
python -m pytest -x -q -m "not kernels and not chaos"
python -m pytest -x -q -m chaos
python scripts/check_docs_links.py
python -m benchmarks.serving_latency --kernel both --speculative --smoke --out BENCH_decode.json
python -m benchmarks.serving_latency --topology tcp --smoke --out BENCH_serving.json
python -m benchmarks.serving_latency --topology proc --smoke --out BENCH_serving_proc.json
python -m benchmarks.serving_latency --topology pod --smoke --out BENCH_serving_pod.json
python -m benchmarks.serving_latency --pool paged --smoke --out BENCH_paged.json
python -m benchmarks.serving_latency --learned --smoke --out BENCH_learned_policy.json
python -m benchmarks.serving_latency --tiers --smoke --out BENCH_tiers.json
python -m benchmarks.serving_latency --regions --smoke --out BENCH_regions.json
python -m benchmarks.multi_region --smoke --out BENCH_multi_region.json
python examples/serve_autoscale.py --smoke
