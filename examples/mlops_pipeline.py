"""The paper's headline experiment (§4.1.1) end to end: traditional MLOps vs
the DNN-powered pipeline on two simulated days of diurnal + spiky traffic,
serving the 1B-class profile measured by the compiled dry-run.

Prints the paper's comparison table with our reproduced numbers.

Run:  PYTHONPATH=src:. python examples/mlops_pipeline.py
"""
import numpy as np

from benchmarks.common import (
    N_TICKS, run_fleet, traffic_weighted_p95,
)
from benchmarks.deployment_efficiency import run as deploy_run

print("simulating 2 days of fleet operation (traditional vs DNN-powered)...")
rows = {}
for ctrl in ("traditional", "dnn"):
    rs = [run_fleet(controller=ctrl, n_ticks=N_TICKS, seed=s) for s in (0, 1)]
    rows[ctrl] = {
        "util": float(np.mean([r.utilization for r in rs])),
        "lat": float(np.mean([traffic_weighted_p95(r) for r in rs])),
        "cost": float(np.mean([r.cost_per_1k for r in rs])),
        "err": float(np.mean([r.error_rate for r in rs])),
    }

dep = deploy_run()["detail"]

t, d = rows["traditional"], rows["dnn"]
print(f"""
                         Traditional    DNN-powered    paper (§4.1.1)
  deployment time        {dep['traditional_s']/60:7.1f} min    {dep['dnn_s']/60:7.1f} min    45 -> 28 min
  resource utilization   {t['util']:10.1%}    {d['util']:10.1%}    58% -> 82%
  cost / 1k inferences   ${t['cost']:9.4f}    ${d['cost']:9.4f}    -38.3%
  serving latency (p95)  {t['lat']:7.0f} ms     {d['lat']:7.0f} ms     250 -> 180 ms
  timeout error rate     {t['err']:10.2%}    {d['err']:10.2%}    (not reported)
""")
print("the DNN path: predictive allocation (forecaster + constrained "
      "optimizer),\nmonitoring-driven adaptation, canary rollouts, and "
      "cost-aware provider selection.")
