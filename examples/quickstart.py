"""Quickstart: the three layers of the framework in one script.

  1. data plane  — build a (reduced) assigned architecture, run a train step
                   and a prefill→decode round trip;
  2. kernels     — the Pallas flash-attention kernel vs its jnp oracle;
  3. control     — the paper's control plane makes one scaling decision and
                   one deployment-strategy selection against the
                   roofline-grounded performance model.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import LM
from repro.models.steps import init_train_state, make_train_step

print("assigned architectures:", ", ".join(ARCH_IDS))

# ---------------------------------------------------------------- 1. model
cfg = get_smoke_config("qwen2.5-3b")
print(f"\n[1] {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
      f"heads={cfg.n_heads}/{cfg.n_kv_heads} ({cfg.n_params()/1e6:.1f}M params)")

key = jax.random.PRNGKey(0)
batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab)}
batch["labels"] = batch["tokens"]

train_step, (opt_init, _) = make_train_step(cfg, lr=1e-3)
state = init_train_state(key, cfg, opt_init)
step = jax.jit(train_step)
for i in range(3):
    state, metrics = step(state, batch)
    print(f"    train step {i}: loss={float(metrics['loss']):.4f}")

logits, cache = LM.prefill(state.params, {"tokens": batch["tokens"]}, cfg,
                           max_seq=40)
tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)[:, None]
for i in range(4):
    logits, cache = LM.decode(state.params, tok, cfg, cache)
    tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)[:, None]
print(f"    prefill+decode ok, cache index = {int(cache['index'])}")

# ---------------------------------------------------------------- 2. kernel
from repro.kernels import ops, ref

q = jax.random.normal(key, (1, 128, 8, 64))
k = jax.random.normal(jax.random.fold_in(key, 1), (1, 128, 4, 64))
v = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 4, 64))
out = ops.flash_attention(q, k, v, causal=True)          # interpret on CPU
err = float(jnp.max(jnp.abs(out - ref.flash_attention_ref(q, k, v))))
print(f"\n[2] pallas flash attention vs oracle: max err {err:.2e}")

# ---------------------------------------------------------------- 3. control
from repro.core.allocation.allocator import AllocatorConfig, PredictiveAllocator
from repro.core.dnn.features import deploy_vector
from repro.core.orchestration.selector import DecisionTreeSelector, DeploymentContext
from repro.core.scaling.scaler import ScalingConstraints
from repro.sim import RooflineDB, ServiceProfile, ServingModel, WorkloadSpec

db = RooflineDB("results/dryrun")
profile = ServiceProfile.from_db(db, "h2o-danube-1.8b")   # 1B-class
model = ServingModel(profile, WorkloadSpec(prompt_len=256, gen_len=12),
                     slo_ms=200.0)
print(f"\n[3] roofline-grounded profile: decode step "
      f"{profile.decode_step_s*1e3:.1f} ms/token, bottleneck "
      f"{profile.bottleneck} (from the compiled dry-run)")

alloc = PredictiveAllocator(
    model.latency_util, ScalingConstraints(slo_ms=200.0),
    deploy_vector(model_params_b=1.8, family="dense", mesh_model=16,
                  mesh_data=16, region_idx=0, slo_ms=200, cost_weight=0.5),
    cfg=AllocatorConfig(mode="planner"))
for rps in (20.0, 40.0, 80.0, 160.0):
    alloc.observe({"rps": rps})
    d = alloc.decide({"rps": rps, "rps_window": [rps]})
    alloc.apply(d)
    print(f"    load {rps:5.0f} rps -> {d.target_replicas:2d} replicas "
          f"(pred p95 {d.predicted_latency_ms:.0f} ms, {d.reason})")

strategy = DecisionTreeSelector().select(DeploymentContext(
    model_params_b=3, traffic_rps=500, slo_ms=200, error_budget=0.0005,
    spare_capacity_frac=0.15, cost_sensitivity=0.5, is_critical=True))
print(f"    deployment strategy for this context: {strategy}")
print("\nquickstart complete.")
