"""Fault-tolerant training with elastic re-mesh: the control plane's scaling
action applied to a *training* job.

  1. train a reduced model with periodic async checkpoints;
  2. simulate a preemption (the job dies mid-run);
  3. the allocator's ReMesh action restores the checkpoint onto a different
     mesh topology (here 1×1 — on a pod this is e.g. (16,16) → (12,16) after
     losing 4 hosts) and training continues, with the counted data pipeline
     replaying byte-identical batches.

Run:  PYTHONPATH=src python examples/train_elastic.py
"""
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.data import DataConfig, TokenPipeline
from repro.launch.elastic import ReMesh, elastic_restore
from repro.models.steps import init_train_state, make_train_step

cfg = get_smoke_config("olmoe-1b-7b")       # a MoE — the richest state
root = Path(tempfile.mkdtemp()) / "ckpt"
data = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4,
                                seed=7))

print(f"[phase 1] training {cfg.name} ({cfg.n_params()/1e6:.1f}M params)")
train_step, (opt_init, _) = make_train_step(cfg, lr=1e-3)
state = init_train_state(jax.random.PRNGKey(0), cfg, opt_init)
step_fn = jax.jit(train_step)
mgr = CheckpointManager(root)
PREEMPT_AT = 8
for step in range(PREEMPT_AT):
    batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
    state, metrics = step_fn(state, batch)
    if (step + 1) % 4 == 0:
        mgr.save(step + 1, state)           # async — training continues
        print(f"  step {step+1}: loss={float(metrics['loss']):.4f} "
              f"[checkpoint queued]")
    else:
        print(f"  step {step+1}: loss={float(metrics['loss']):.4f}")
mgr.wait()
print(f"[phase 2] PREEMPTION at step {PREEMPT_AT} — process gone; latest "
      f"checkpoint: step {mgr.latest_step()}")

print("[phase 3] allocator emits ReMesh(data=1, model=1) — elastic restore")
state2, jitted, mesh = elastic_restore(root, cfg,
                                       ReMesh(data_axis=1, model_axis=1),
                                       lr=1e-3)
resume_step = int(jax.device_get(state2.step))
print(f"  restored onto mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
      f"at step {resume_step}")

for step in range(resume_step, resume_step + 4):
    batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
    state2, metrics = jitted(state2, batch)
    print(f"  step {step+1}: loss={float(metrics['loss']):.4f} (resumed)")

# determinism proof: the resumed batch at step k equals the original stream
b_orig = data.batch(resume_step)["tokens"]
b_new = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4,
                                 seed=7)).batch(resume_step)["tokens"]
assert np.array_equal(b_orig, b_new)
print("\ntrain_elastic complete: checkpoint → preemption → re-mesh → "
      "deterministic resume all verified.")
