"""End-to-end serving driver: a REAL model served with batched requests,
monitored and scaled by the paper's control plane.

The data plane is the actual ServingEngine (reduced qwen2.5-3b, continuous
slot batching, prefill + decode over a shared KV cache).  Every second of
wall time is one control tick: the engine's measured latencies/throughput
feed the MetricsCollector; the AnomalyDetector watches for load spikes; the
PredictiveAllocator decides how many replicas the fleet *would* run (the
single local engine stands in for one replica of the fleet — spare capacity
is simulated, since this container has one CPU).

Run:  PYTHONPATH=src python examples/serve_autoscale.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.allocation.allocator import AllocatorConfig, PredictiveAllocator
from repro.core.dnn.features import deploy_vector
from repro.core.monitoring.anomaly import AnomalyDetector
from repro.core.monitoring.collector import MetricsCollector, ReplicaReport
from repro.core.scaling.scaler import ScalingConstraints
from repro.launch.serve import ServingEngine

SLOTS = 4
GEN_LEN = 8
PROMPT_LEN = 16
N_TICKS = 12

cfg = get_smoke_config("qwen2.5-3b")
engine = ServingEngine(cfg, slots=SLOTS, max_seq=48, seed=0)
rng = np.random.default_rng(0)

collector = MetricsCollector()
anomaly = AnomalyDetector(z_threshold=3.0, min_history=4)


def engine_capacity_model(replicas: int, rps: float):
    """Perf model grounded in the engine's own measured step time."""
    step_s = max(measured["step_s"], 1e-3)
    service = GEN_LEN * step_s
    cap = replicas * SLOTS / service
    util = min(rps / max(cap, 1e-9), 1.0)
    lat = service * (1.0 + 3.0 * max(util - 0.8, 0.0) / 0.2)
    return lat * 1e3, util


measured = {"step_s": 0.05}
alloc = PredictiveAllocator(
    engine_capacity_model, ScalingConstraints(slo_ms=2000.0, max_replicas=16),
    deploy_vector(model_params_b=0.003, family="dense", mesh_model=1,
                  mesh_data=1, region_idx=0, slo_ms=2000, cost_weight=0.5),
    cfg=AllocatorConfig(mode="planner"))

print(f"engine: {cfg.name} {cfg.n_params()/1e6:.1f}M params, {SLOTS} slots")
owners = {}
next_rid = 0
lat_done: dict[int, float] = {}
t_admit: dict[int, float] = {}
replicas = 1

for tick in range(N_TICKS):
    # load profile: calm → spike → calm
    rps_target = 3.0 if tick < 4 else (12.0 if tick < 8 else 3.0)
    n_arrivals = rng.poisson(rps_target)
    t0 = time.time()
    lats, served = [], 0
    # admit as many arrivals as there are free slots (rest queue → dropped)
    for _ in range(n_arrivals):
        free = [s for s in range(SLOTS) if not engine.active[s]]
        if not free:
            break
        slot = free[0]
        prompt = rng.integers(3, cfg.vocab, size=PROMPT_LEN).astype(np.int32)
        engine.admit(slot, prompt, GEN_LEN)
        owners[slot] = next_rid
        t_admit[next_rid] = time.time()
        next_rid += 1
    # decode for ~1 simulated tick
    steps = 0
    while engine.active.any() and steps < GEN_LEN:
        done = engine.tick()
        steps += 1
        for slot in done:
            rid = owners[slot]
            lats.append((time.time() - t_admit[rid]) * 1e3)
            served += 1
    wall = time.time() - t0
    if steps:
        measured["step_s"] = wall / steps
    collector.submit(ReplicaReport(
        replica_id=0, tick=tick, latency_ms_samples=lats, n_requests=served,
        n_errors=max(n_arrivals - served - int(np.sum(engine.active)), 0),
        flop_util=float(np.mean(engine.active)), hbm_util=0.5, ici_util=0.2,
        mem_frac=0.4, queue_depth=0))
    rec = collector.aggregate(tick, n_replicas=replicas, max_replicas=16)
    rec["rps"] = float(n_arrivals)
    rec["rps_window"] = [rec["rps"]]
    anomalies = anomaly.update(tick, {"rps": rec["rps"]})
    alloc.observe(rec)
    alloc.replicas = replicas
    decision = alloc.decide(rec)
    alloc.apply(decision)
    replicas = decision.target_replicas
    flag = " [ANOMALY]" if anomalies else ""
    print(f"tick {tick:2d}: rps={rps_target:4.0f} served={served} "
          f"p50={rec['latency_p50']:.0f}ms slots_busy="
          f"{int(np.sum(engine.active))} -> fleet target {replicas} "
          f"replicas ({decision.reason}){flag}")

print("\nserve_autoscale complete: the engine served real batched requests "
      "while the control plane tracked load and scaled the (simulated) fleet.")
