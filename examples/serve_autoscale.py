"""Closed-loop autoscaling demo: the control plane drives a REAL multi-replica
data plane.

The loop itself lives in repro/serving/closed_loop.py and is shared verbatim
with benchmarks/serving_latency.py --engine: a ReplicaRouter over actual
ServingEngines (reduced qwen2.5-3b by default: continuous slot batching,
chunked prefill, per-slot ring positions), Poisson arrivals on a calm→spike→
calm profile, per-replica reports into the MetricsCollector, the
AnomalyDetector watching load, and the PredictiveAllocator's scaling
decisions *actuated* via router.scale_to — replicas really appear and drain
mid-run.

The run log shows, per tick: load, completions, p50/p95 latency, per-replica
slot utilization, and the realized replica count with the decision reason —
so the scaling event's before/after is visible directly.  Exits 1 if the
scaler never changed the replica count (CI smoke relies on this).

Run:  PYTHONPATH=src python examples/serve_autoscale.py --smoke
"""
import argparse
import dataclasses
import sys

from repro.configs import get_config, get_smoke_config
from repro.serving.closed_loop import LoopConfig, run_closed_loop
from repro.serving.router import TOPOLOGIES


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-fast); required for CI")
    ap.add_argument("--ticks", type=int, default=14)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--topology", choices=TOPOLOGIES, default="inproc",
                    help="replica backend: in-process engines, one engine "
                         "sharded over the local device mesh, worker "
                         "subprocesses behind the socket transport, TCP "
                         "workers the router dials, or multi-process pods "
                         "(N worker ranks behind one head)")
    ap.add_argument("--workers", default=None, metavar="HOST:PORT,...",
                    help="tcp/pod topology: comma-separated addresses of "
                         "pre-started worker pods (tcp: python -m "
                         "repro.serving.worker --listen host:port; pod: "
                         "the pod HEADS) to attach to; omitted, local "
                         "workers/pods are spawned on kernel-picked ports")
    ap.add_argument("--pod-size", type=int, default=2,
                    help="pod topology: worker ranks per replica")
    args = ap.parse_args(argv)
    if args.workers and args.topology not in ("tcp", "pod"):
        ap.error("--workers only applies to --topology tcp/pod")

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    print(f"engine: {cfg.name} {cfg.n_params() / 1e6:.1f}M params, "
          f"router starts at 1 {args.topology} replica")
    addrs = tuple(args.workers.split(",")) if args.workers else ()
    lc = dataclasses.replace(LoopConfig(), topology=args.topology,
                             addrs=addrs, pod_size=args.pod_size)
    router, logs = run_closed_loop(cfg, autoscale=True, ticks=args.ticks,
                                   seed=args.seed, lc=lc)
    for t in logs:
        util = " ".join(f"r{rid}={u:.2f}" for rid, u in t.replica_util)
        flag = " [ANOMALY]" if t.anomaly else ""
        if t.evicted:
            flag += f" [EVICTED r{','.join(map(str, t.evicted))}]"
        print(f"tick {t.tick:2d}: rps={t.rps_target:4.1f} "
              f"arrivals={t.arrivals:2d} served={t.served:2d} "
              f"p50={t.latency_p50_ms:6.0f}ms p95={t.latency_p95_ms:6.0f}ms "
              f"queue={t.queue_depth:4.1f} slot_util[{util}] "
              f"-> {t.replicas} replicas ({t.reason}){flag}")

    m = router.metrics()
    router.close()
    transport = (f", transport={m['transport_ms']:.2f}ms"
                 if m["transport_ms"] else "")
    print(f"\nfleet totals: {m['completed']} requests, "
          f"{m['completed_tokens']} tokens, p50={m['latency_p50_ms']:.0f}ms "
          f"p95={m['latency_p95_ms']:.0f}ms, "
          f"throughput={m['throughput_tok_s']:.1f} tok/s (virtual)"
          f"{transport}")
    trajectory = [1] + [t.replicas for t in logs]
    if len(set(trajectory)) == 1:
        print("FAIL: the scaler never changed the replica count")
        return 1
    print(f"replica trajectory: {trajectory} — the control plane scaled "
          f"the real data plane mid-run.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
