"""Benchmark harness: one module per paper table/figure (DESIGN.md §9).

    PYTHONPATH=src python -m benchmarks.run [--only name] [--json out.json]

Prints one CSV line per benchmark:  name,us_per_call,derived
and writes the full detail records to results/benchmarks.json.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

MODULES = [
    "deployment_efficiency",     # §4.1.1  45 min -> 28 min
    "resource_utilization",      # §4.1.1  58% -> 82%
    "cost_per_inference",        # §4.1.1  $0.12 -> $0.074
    "serving_latency",           # §4.1.1  250 ms -> 180 ms
    "load_testing",              # §4.2.1  1k -> 100k RPS under 200 ms
    "adaptation",                # §4.2.2  reallocation < 30 s
    "feature_importance",        # §4.4    35/30/20/15
    "multi_region",              # §4.1.2  five regions
    "allocator_ablation",        # §3.3.1  planner vs rl vs hybrid modes
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset")
    ap.add_argument("--json", default="results/benchmarks.json")
    args = ap.parse_args(argv)

    names = args.only.split(",") if args.only else MODULES
    print("name,us_per_call,derived")
    records, failed = [], []
    for name in names:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            t0 = time.time()
            rec = mod.run()
            rec["wall_s"] = round(time.time() - t0, 2)
            records.append(rec)
            print(f"{rec['name']},{rec['us_per_call']:.2f},\"{rec['derived']}\"",
                  flush=True)
        except Exception:
            failed.append(name)
            print(f"{name},NaN,\"FAILED\"", flush=True)
            traceback.print_exc()
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(records, indent=1, default=str))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
