"""Ablation of the predictive allocator's decision modes (paper §3.3.1):

  planner — forecaster + constrained optimizer only (no learning)
  rl      — the double-DQN acts, shielded by the constraint envelope
  hybrid  — DQN chooses among planner-feasible actions (the paper's
            "learning component refining the model-based planner")

Same traces/seeds for all three; the paper's claim is that the learned
component is at least competitive inside the safety envelope while
optimizing the util/cost trade-off online.
"""
import time

import numpy as np

from benchmarks.common import N_TICKS, run_fleet


def run():
    t0 = time.perf_counter()
    out = {}
    # learned modes get half a simulated day of burn-in — the paper's §5.3
    # "initial training period" — and are scored on the following day
    for mode in ("planner", "hybrid", "rl"):
        burn = 0 if mode == "planner" else N_TICKS // 4
        rs = [run_fleet(controller="dnn", mode=mode,
                        n_ticks=N_TICKS // 2 + burn, burnin=burn,
                        seed=s) for s in (0,)]
        out[mode] = {
            "util": float(np.mean([r.utilization for r in rs])),
            "cost_per_1k": float(np.mean([r.cost_per_1k for r in rs])),
            "error_rate": float(np.mean([r.error_rate for r in rs])),
            "p95_ms": float(np.mean([r.latency_p95_ms for r in rs])),
        }
    wall = time.perf_counter() - t0
    d = " ".join(f"{m}:util={v['util']:.2f}/$​{v['cost_per_1k']:.3f}"
                 f"/err={v['error_rate']:.3f}" for m, v in out.items())
    # the shielded learned modes must stay within guardrails of the planner
    ok = all(v["error_rate"] <= out["planner"]["error_rate"] + 0.03
             for v in out.values())
    return {
        "name": "allocator_ablation",
        "us_per_call": wall * 1e6 / (3 * 2 * (N_TICKS // 2)),
        "derived": d + (" (envelope held)" if ok else " (ENVELOPE BROKEN)"),
        "detail": {"modes": out, "envelope_held": bool(ok)},
    }


if __name__ == "__main__":
    r = run()
    print(r["derived"])
    for m, v in r["detail"]["modes"].items():
        print(f"  {m:8s} util {v['util']:.3f}  $per1k {v['cost_per_1k']:.4f}  "
              f"err {v['error_rate']:.4f}  p95 {v['p95_ms']:.0f}ms")
