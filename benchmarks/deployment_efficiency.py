"""Paper §4.1.1: initial deployment time 45 min → 28 min (-37.8%).

Traditional: sequential staged rollout with manual approval gates between
stages, no compile cache, conservative fixed soaks (sim/baseline.py).
DNN-optimized: the orchestrator's strategy selector picks the strategy for
the context; rollout runs through the RolloutManager with statistical canary
gates (soak windows sized for test power, no human gates, warm compile
cache).  The deploy-time model is TPU-native: slice provisioning + sharded
checkpoint streaming + compile warmup (DESIGN.md §3).
"""
import time

import numpy as np

from repro.configs import get_config
from repro.core.orchestration.rollout import CanarySample, Phase, RolloutManager
from repro.core.orchestration.selector import DecisionTreeSelector, DeploymentContext
from repro.core.orchestration.strategies import CATALOG, DeployEnv
from repro.sim.baseline import traditional_deploy_seconds

PAPER = {"traditional_min": 45.0, "dnn_min": 28.0}

# calibration (EXPERIMENTS.md §Benchmarks): TPU-slice acquisition ~3 min,
# cold-compile warmup ~2 min; traditional soaks 6×45 s dashboards-watching
# ticks + ~105 s manual approval per stage; the DNN path sizes canary soak
# windows at 2×120 s (Welch-test power at production RPS) with no human gate.
ENV = dict(provision_s=180.0, compile_warmup_s=120.0, hbm_fill_gbps=1.0)
TRAD_TICK_S = 45.0
TRAD_GATE_S = 105.0
DNN_TICK_S = 120.0


def deploy_env(arch="qwen2-vl-7b", *, tick_s: float) -> DeployEnv:
    cfg = get_config(arch)
    return DeployEnv(params_bytes=cfg.n_params() * 2.0,   # bf16 checkpoint
                     chips_per_replica=16, n_replicas=16, tick_s=tick_s,
                     **ENV)


def dnn_deploy_seconds(env: DeployEnv, strategy: str, seed=0) -> float:
    rng = np.random.default_rng(seed)
    mgr = RolloutManager(strategy, env)
    mgr.start()
    while mgr.state.phase not in (Phase.COMPLETED, Phase.ROLLED_BACK):
        healthy = CanarySample(rng.normal(100, 8, 400), 400, 0, 0.6)
        control = CanarySample(rng.normal(100, 8, 400), 400, 0, 0.6)
        mgr.tick(canary=healthy, control=control)
    return mgr.state.elapsed_s


def run():
    t0 = time.perf_counter()
    env_trad = deploy_env(tick_s=TRAD_TICK_S)
    trad_s = traditional_deploy_seconds(env_trad, operator_gate_s=TRAD_GATE_S)

    # a critical production deploy with a strict error budget — the paper's
    # "1B+ models serving production traffic" setting
    ctx = DeploymentContext(model_params_b=7.6, traffic_rps=500, slo_ms=200,
                            error_budget=0.0005, spare_capacity_frac=0.15,
                            cost_sensitivity=0.5, is_critical=True)
    strategy = DecisionTreeSelector().select(ctx)
    env_dnn = deploy_env(tick_s=DNN_TICK_S)      # statistical soak windows
    dnn_s = dnn_deploy_seconds(env_dnn, strategy)
    wall = time.perf_counter() - t0
    n_calls = len(CATALOG)
    return {
        "name": "deployment_efficiency",
        "us_per_call": wall * 1e6 / n_calls,
        "derived": (f"deploy {trad_s/60:.1f}min->{dnn_s/60:.1f}min "
                    f"({(dnn_s/trad_s-1)*100:+.1f}%) paper 45->28 (-37.8%); "
                    f"strategy={strategy}"),
        "detail": {"traditional_s": trad_s, "dnn_s": dnn_s,
                   "reduction": 1 - dnn_s / trad_s, "strategy": strategy,
                   "paper": PAPER,
                   "all_strategies_s": {
                       name: dnn_deploy_seconds(env_dnn, name)
                       for name in CATALOG}},
    }


if __name__ == "__main__":
    r = run()
    print(r["derived"])
    for k, v in r["detail"]["all_strategies_s"].items():
        print(f"  {k:20s} {v/60:6.1f} min")
