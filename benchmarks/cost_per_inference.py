"""Paper §4.1.1: cost per inference $0.12 → $0.074 (-38.3%).

Cost here is USD per 1000 inferences (the absolute magnitude depends on the
priced unit; the paper's ratio is the reproduction target).  The DNN path's
saving decomposes into (a) higher utilization (fewer replica-hours per
request) and (b) the framework's cost-aware provider selection (gcp vs the
traditional default aws) — the paper's multi-cloud optimization (§5.2).
"""
import time

import numpy as np

from benchmarks.common import SEEDS, N_TICKS, headline_comparison

PAPER_REDUCTION = 0.383


def run():
    t0 = time.perf_counter()
    trad = [headline_comparison("traditional", s) for s in SEEDS]
    dnn = [headline_comparison("dnn", s) for s in SEEDS]
    wall = time.perf_counter() - t0
    c_t = float(np.mean([r.cost_per_1k for r in trad]))
    c_d = float(np.mean([r.cost_per_1k for r in dnn]))
    # decomposition: same-provider cost (utilization effect only)
    util_effect = float(np.mean([t.utilization for t in trad])
                        / np.mean([d.utilization for d in dnn]))
    provider_effect = 1.20 / 1.35
    return {
        "name": "cost_per_inference",
        "us_per_call": wall * 1e6 / max(len(SEEDS) * 2 * N_TICKS, 1),
        "derived": (f"$per1k {c_t:.4f}->{c_d:.4f} ({(c_d/c_t-1)*100:+.1f}%) "
                    f"paper -38.3%; decomposition util x{util_effect:.2f} "
                    f"provider x{provider_effect:.2f}"),
        "detail": {"traditional_per_1k": c_t, "dnn_per_1k": c_d,
                   "reduction": 1 - c_d / c_t,
                   "paper_reduction": PAPER_REDUCTION,
                   "spend_traditional": float(np.mean([r.spend_usd for r in trad])),
                   "spend_dnn": float(np.mean([r.spend_usd for r in dnn]))},
    }


if __name__ == "__main__":
    print(run()["derived"])
