"""Roofline report (deliverable g): the full 33-cell baseline table.

Reads results/dryrun/<arch>__<shape>__single.json (written by
repro.launch.dryrun --probe) through the RooflineDB and derives, per cell:

  t_compute    = FLOPs_dev / 197e12        (TPU v5e bf16 peak)
  t_memory     = bytes_dev / 819e9         (HBM bandwidth)
  t_collective = coll_bytes_dev / 50e9     (ICI link bandwidth)

dominant term = bottleneck; roofline fraction = t_dominant-at-ideal /
step_time where "ideal" is the compute term (how close the cell is to being
compute-bound, the MFU-style score); MODEL_FLOPS = 6·N_active·D (train) or
2·N_active·D (serve) compares useful model math against compiled HLO FLOPs.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [--dir results/dryrun]
"""
from __future__ import annotations

import argparse

from repro.configs import ARCH_IDS, get_config
from repro.models import SHAPES, applicable_shapes
from repro.sim.roofline_db import RooflineDB, PEAK_FLOPS


def model_flops_per_device(cfg, shape, chips: int) -> float:
    """Useful model math per device for one step (6·N·D train, 2·N·D serve)."""
    n = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens / chips
    tokens = (shape.global_batch * shape.seq_len if shape.kind == "prefill"
              else shape.global_batch)
    return 2.0 * n * tokens / chips


def cell_report(db: RooflineDB, arch: str, shape_name: str, mesh="single"):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    t = db.terms(arch, shape_name, mesh)
    mf = model_flops_per_device(cfg, shape, t.chips)
    return {
        "arch": arch,
        "shape": shape_name,
        "t_compute": t.t_compute,
        "t_memory": t.t_memory,
        "t_collective": t.t_collective,
        "step_time": t.step_time,
        "bottleneck": t.bottleneck,
        "model_flops": mf,
        "hlo_flops": t.flops,
        "useful_frac": mf / t.flops if t.flops else 0.0,
        # MFU-style roofline fraction: useful model FLOPs over what the chips
        # could do in the actual (bottlenecked) step time.
        "roofline_frac": mf / (t.step_time * PEAK_FLOPS) if t.step_time else 0.0,
        "measured": t.measured,
        "mem_gb": t.mem_per_dev / 2**30,
    }


def full_table(db: RooflineDB | None = None, mesh: str = "single"):
    db = db or RooflineDB()
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name in applicable_shapes(cfg):
            rows.append(cell_report(db, arch, shape_name, mesh))
    return rows


def fmt_row(r) -> str:
    return (f"| {r['arch']} | {r['shape']} | {r['t_compute']*1e3:9.3f} | "
            f"{r['t_memory']*1e3:9.3f} | {r['t_collective']*1e3:9.3f} | "
            f"{r['bottleneck']:10s} | {r['useful_frac']*100:5.1f}% | "
            f"{r['roofline_frac']*100:5.1f}% |")


HEADER = ("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
          "bottleneck | useful | roofline |\n"
          "|---|---|---|---|---|---|---|---|")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--sort", default="roofline_frac")
    args = ap.parse_args()
    db = RooflineDB(args.dir)
    rows = full_table(db, args.mesh)
    print(HEADER)
    for r in sorted(rows, key=lambda r: r[args.sort]):
        print(fmt_row(r))
    n_meas = sum(r["measured"] for r in rows)
    print(f"\n{len(rows)} cells, {n_meas} measured from compiled dry-run")


if __name__ == "__main__":
    main()
