"""Paper §4.1.1: resource utilization 58% → 82% (+41.4%).

Traditional = static sizing at mean-load × 1.25 margin (the paper's "static
rules"); DNN = the predictive control plane (forecaster + constrained
optimizer + monitoring-driven adaptation).  Three seeds, two simulated days,
1B-class profile grounded in the compiled dry-run.  Also reports the
reactive-threshold ablation (a stronger baseline than the paper's).
"""
import time

import numpy as np

from benchmarks.common import N_TICKS, SEEDS, headline_comparison, run_fleet

PAPER = {"traditional": 0.58, "dnn": 0.82}


def run():
    t0 = time.perf_counter()
    trad = [headline_comparison("traditional", s).utilization for s in SEEDS]
    dnn = [headline_comparison("dnn", s).utilization for s in SEEDS]
    thr = [run_fleet(controller="threshold", n_ticks=N_TICKS, seed=s
                     ).utilization for s in SEEDS[:1]]
    wall = time.perf_counter() - t0
    u_t, u_d = float(np.mean(trad)), float(np.mean(dnn))
    return {
        "name": "resource_utilization",
        "us_per_call": wall * 1e6 / (len(SEEDS) * 2 * N_TICKS),  # per sim tick
        "derived": (f"util {u_t:.3f}->{u_d:.3f} (+{(u_d/u_t-1)*100:.1f}%) "
                    f"paper 0.58->0.82; threshold-ablation {thr[0]:.3f}"),
        "detail": {"traditional": u_t, "dnn": u_d, "threshold": thr[0],
                   "improvement_rel": u_d / u_t - 1,
                   "paper": PAPER, "seeds": list(SEEDS)},
    }


if __name__ == "__main__":
    print(run()["derived"])
