"""Paper §4.2.2: resource reallocation within 30 s of detecting significant
workload changes; recovery from a 2× step change.

Two measurements on a 10 s-tick fleet:
  * decision latency — ticks from the workload step to the first scale-up
    decision (the paper's "reallocation within 30 s" claim is about the
    control loop, not hardware provisioning);
  * recovery time — ticks until p95 is back under the SLO (includes the
    provisioning delay the cloud charges regardless of controller).
"""
import time

import numpy as np

from benchmarks.common import default_workload, make_profile, run_fleet

TICK_S = 10.0
STEP_AT = 120                 # tick index of the 2× load step


def run():
    profile = make_profile()
    w = default_workload()
    cap1 = profile.requests_per_s(w)
    n_ticks = 400
    base = cap1 * 10 * 0.6
    trace = np.full(n_ticks, base)
    trace[STEP_AT:] = base * 2.0

    t0 = time.perf_counter()
    rec = []
    res = run_fleet(controller="dnn", trace=trace, n_ticks=n_ticks,
                    tick_s=TICK_S, seed=0, record_streams=rec)
    wall = time.perf_counter() - t0

    replicas = res.replicas
    pre = replicas[STEP_AT - 1]
    scale_tick = next((t for t in range(STEP_AT, n_ticks)
                       if replicas[t] > pre), None)
    decision_s = (scale_tick - STEP_AT + 1) * TICK_S if scale_tick else None

    slo = 200.0
    over = [t for t in range(STEP_AT, n_ticks) if res.lats[t] > slo]
    recovery_s = ((max(over) - STEP_AT + 1) * TICK_S) if over else 0.0

    ok = decision_s is not None and decision_s <= 30.0
    return {
        "name": "adaptation",
        "us_per_call": wall * 1e6 / n_ticks,
        "derived": (f"scale-up decision {decision_s:.0f}s after 2x step "
                    f"({'<=' if ok else '>'}30s, paper <30s); "
                    f"p95 recovery {recovery_s:.0f}s (incl provisioning)"),
        "detail": {"decision_s": decision_s, "recovery_s": recovery_s,
                   "replicas_before": int(pre),
                   "replicas_after": int(replicas[-1]),
                   "within_30s": bool(ok)},
    }


if __name__ == "__main__":
    print(run()["derived"])
