"""Paper §4.1.1: serving latency 250 ms → 180 ms (-28%).

Reported as traffic-weighted p95 (each tick's p95 weighted by load — what
users actually experience): the static baseline is under-provisioned exactly
when traffic peaks, so its user-experienced tail is far worse than its
calm-hour average.  Error (timeout) rates are reported alongside — dropped
requests don't even appear in a latency histogram.
"""
import time

import numpy as np

from benchmarks.common import (
    SEEDS, N_TICKS, SLO_MS, headline_comparison, traffic_weighted_p95,
)

PAPER = {"traditional_ms": 250.0, "dnn_ms": 180.0}


def run():
    t0 = time.perf_counter()
    trad = [headline_comparison("traditional", s) for s in SEEDS]
    dnn = [headline_comparison("dnn", s) for s in SEEDS]
    wall = time.perf_counter() - t0
    l_t = float(np.mean([traffic_weighted_p95(r) for r in trad]))
    l_d = float(np.mean([traffic_weighted_p95(r) for r in dnn]))
    e_t = float(np.mean([r.error_rate for r in trad]))
    e_d = float(np.mean([r.error_rate for r in dnn]))
    return {
        "name": "serving_latency",
        "us_per_call": wall * 1e6 / max(len(SEEDS) * 2 * N_TICKS, 1),
        "derived": (f"tw-p95 {l_t:.0f}ms->{l_d:.0f}ms ({(l_d/l_t-1)*100:+.1f}%) "
                    f"paper 250->180 (-28%); err {e_t:.3f}->{e_d:.3f}"),
        "detail": {"traditional_ms": l_t, "dnn_ms": l_d,
                   "reduction": 1 - l_d / l_t,
                   "err_traditional": e_t, "err_dnn": e_d,
                   "slo_ms": SLO_MS, "paper": PAPER},
    }


if __name__ == "__main__":
    print(run()["derived"])
