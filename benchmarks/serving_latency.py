"""Paper §4.1.1: serving latency 250 ms → 180 ms (-28%).

Reported as traffic-weighted p95 (each tick's p95 weighted by load — what
users actually experience): the static baseline is under-provisioned exactly
when traffic peaks, so its user-experienced tail is far worse than its
calm-hour average.  Error (timeout) rates are reported alongside — dropped
requests don't even appear in a latency histogram.

Two layers:
  * run()        — the queueing-model fleet simulation (paper-scale, fast);
  * run_engine() — the SAME experiment on the real CPU data plane: a
    ReplicaRouter over actual ServingEngines, autoscaled by the planner vs
    pinned at one replica, under an identical calm→spike→calm profile.
    (`python -m benchmarks.serving_latency --engine`)
"""
import time

import numpy as np

from benchmarks.common import (
    SEEDS, N_TICKS, SLO_MS, headline_comparison, traffic_weighted_p95,
)

PAPER = {"traditional_ms": 250.0, "dnn_ms": 180.0}


def run():
    t0 = time.perf_counter()
    trad = [headline_comparison("traditional", s) for s in SEEDS]
    dnn = [headline_comparison("dnn", s) for s in SEEDS]
    wall = time.perf_counter() - t0
    l_t = float(np.mean([traffic_weighted_p95(r) for r in trad]))
    l_d = float(np.mean([traffic_weighted_p95(r) for r in dnn]))
    e_t = float(np.mean([r.error_rate for r in trad]))
    e_d = float(np.mean([r.error_rate for r in dnn]))
    return {
        "name": "serving_latency",
        "us_per_call": wall * 1e6 / max(len(SEEDS) * 2 * N_TICKS, 1),
        "derived": (f"tw-p95 {l_t:.0f}ms->{l_d:.0f}ms ({(l_d/l_t-1)*100:+.1f}%) "
                    f"paper 250->180 (-28%); err {e_t:.3f}->{e_d:.3f}"),
        "detail": {"traditional_ms": l_t, "dnn_ms": l_d,
                   "reduction": 1 - l_d / l_t,
                   "err_traditional": e_t, "err_dnn": e_d,
                   "slo_ms": SLO_MS, "paper": PAPER},
    }


# ---------------------------------------------------------------------------
# real-engine closed loop (CPU smoke scale)
# ---------------------------------------------------------------------------

ENGINE_TICKS = 12
ENGINE_SLO_MS = 2000.0


def _closed_loop(autoscale: bool, *, seed: int = 0, ticks: int = ENGINE_TICKS):
    """One calm→spike→calm run on the real data plane — the SAME driver as
    examples/serve_autoscale.py (repro/serving/closed_loop.py); returns
    (traffic-weighted p95 ms, completed, mean slot utilization, backlog)."""
    from repro.configs import get_smoke_config
    from repro.serving.closed_loop import run_closed_loop

    cfg = get_smoke_config("qwen2.5-3b")
    router, logs = run_closed_loop(cfg, autoscale=autoscale, ticks=ticks,
                                   seed=seed)
    tw_num = sum(t.latency_p95_ms * t.arrivals for t in logs)
    tw_den = sum(t.arrivals for t in logs)
    m = router.metrics()
    backlog = tw_den - m["completed"]      # stuck requests never even reach
    return tw_num / max(tw_den, 1), m["completed"], m["slot_utilization"], \
        backlog                            # the latency histogram


def run_engine(seed: int = 0, ticks: int = ENGINE_TICKS):
    """Static-1-replica vs closed-loop on the real engine."""
    from repro.serving.closed_loop import LoopConfig
    t0 = time.perf_counter()
    p95_s, done_s, util_s, back_s = _closed_loop(False, seed=seed, ticks=ticks)
    p95_a, done_a, util_a, back_a = _closed_loop(True, seed=seed, ticks=ticks)
    wall = time.perf_counter() - t0
    steps = 2 * ticks * LoopConfig().steps_per_tick
    return {
        "name": "serving_latency_engine",
        "us_per_call": wall * 1e6 / max(steps, 1),
        "derived": (f"real-engine static vs closed-loop: completed "
                    f"{done_s}->{done_a}, backlog {back_s}->{back_a}, "
                    f"tw-p95 {p95_s:.0f}ms->{p95_a:.0f}ms (static p95 is "
                    f"survivor-biased by its backlog)"),
        "detail": {"static_ms": p95_s, "autoscaled_ms": p95_a,
                   "completed_static": done_s, "completed_auto": done_a,
                   "backlog_static": back_s, "backlog_auto": back_a,
                   "slot_util_static": util_s, "slot_util_auto": util_a,
                   "slo_ms": ENGINE_SLO_MS},
    }


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", action="store_true",
                    help="run the real-engine closed loop (CPU smoke)")
    args = ap.parse_args()
    print((run_engine() if args.engine else run())["derived"])
