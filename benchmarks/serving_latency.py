"""Paper §4.1.1: serving latency 250 ms → 180 ms (-28%).

Reported as traffic-weighted p95 (each tick's p95 weighted by load — what
users actually experience): the static baseline is under-provisioned exactly
when traffic peaks, so its user-experienced tail is far worse than its
calm-hour average.  Error (timeout) rates are reported alongside — dropped
requests don't even appear in a latency histogram.

Three layers:
  * run()        — the queueing-model fleet simulation (paper-scale, fast);
  * run_engine() — the SAME experiment on the real CPU data plane: a
    ReplicaRouter over actual ServingEngines, autoscaled by the planner vs
    pinned at one replica, under an identical calm→spike→calm profile.
    (`python -m benchmarks.serving_latency --engine`)
  * run_kernel_ablation() — the decode data path itself: one staggered
    continuous-batching run per kernel (`ref` = jnp scatter + masked sdpa,
    `pallas` = fused vector-index split-K kernel + ring-scatter write,
    interpret mode on CPU), recording per-tick decode wall time and
    asserting the token streams are identical.
    (`python -m benchmarks.serving_latency --kernel both --smoke` writes
    BENCH_decode.json — the CI perf-trajectory artifact)
  * run_speculative_ablation() — speculative decoding tokens/s: spec-on vs
    spec-off × pallas vs ref × dense vs paged on a repetitive (prompt-echo)
    workload, asserting every greedy stream is bit-identical, that no
    greedy arm pulls host logits (the fused-sampling bar), and recording
    draft acceptance.  Merged into BENCH_decode.json.
    (`python -m benchmarks.serving_latency --speculative --smoke`)
"""
import json
import time

import numpy as np

from benchmarks.common import (
    SEEDS, N_TICKS, SLO_MS, headline_comparison, traffic_weighted_p95,
)

PAPER = {"traditional_ms": 250.0, "dnn_ms": 180.0}


def run():
    t0 = time.perf_counter()
    trad = [headline_comparison("traditional", s) for s in SEEDS]
    dnn = [headline_comparison("dnn", s) for s in SEEDS]
    wall = time.perf_counter() - t0
    l_t = float(np.mean([traffic_weighted_p95(r) for r in trad]))
    l_d = float(np.mean([traffic_weighted_p95(r) for r in dnn]))
    e_t = float(np.mean([r.error_rate for r in trad]))
    e_d = float(np.mean([r.error_rate for r in dnn]))
    return {
        "name": "serving_latency",
        "us_per_call": wall * 1e6 / max(len(SEEDS) * 2 * N_TICKS, 1),
        "derived": (f"tw-p95 {l_t:.0f}ms->{l_d:.0f}ms ({(l_d/l_t-1)*100:+.1f}%) "
                    f"paper 250->180 (-28%); err {e_t:.3f}->{e_d:.3f}"),
        "detail": {"traditional_ms": l_t, "dnn_ms": l_d,
                   "reduction": 1 - l_d / l_t,
                   "err_traditional": e_t, "err_dnn": e_d,
                   "slo_ms": SLO_MS, "paper": PAPER},
    }


# ---------------------------------------------------------------------------
# real-engine closed loop (CPU smoke scale)
# ---------------------------------------------------------------------------

ENGINE_TICKS = 12
ENGINE_SLO_MS = 2000.0


def _closed_loop(autoscale: bool, *, seed: int = 0, ticks: int = ENGINE_TICKS,
                 topology: str = "inproc", max_replicas: int | None = None):
    """One calm→spike→calm run on the real data plane — the SAME driver as
    examples/serve_autoscale.py (repro/serving/closed_loop.py); returns
    (traffic-weighted p95 ms, completed, mean slot utilization, backlog,
    transport_ms)."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.serving.closed_loop import LoopConfig, run_closed_loop

    cfg = get_smoke_config("qwen2.5-3b")
    lc = LoopConfig(topology=topology)
    if max_replicas is not None:
        lc = dataclasses.replace(lc, max_replicas=max_replicas)
    router, logs = run_closed_loop(cfg, autoscale=autoscale, ticks=ticks,
                                   seed=seed, lc=lc)
    tw_num = sum(t.latency_p95_ms * t.arrivals for t in logs)
    tw_den = sum(t.arrivals for t in logs)
    m = router.metrics()
    router.close()
    backlog = tw_den - m["completed"]      # stuck requests never even reach
    return tw_num / max(tw_den, 1), m["completed"], m["slot_utilization"], \
        backlog, m["transport_ms"], m["rpc_count"]  # the latency histogram


def run_engine(seed: int = 0, ticks: int = ENGINE_TICKS,
               topology: str = "inproc"):
    """Static-1-replica vs closed-loop on the real engine."""
    from repro.serving.closed_loop import LoopConfig
    t0 = time.perf_counter()
    p95_s, done_s, util_s, back_s, *_ = _closed_loop(
        False, seed=seed, ticks=ticks, topology=topology)
    p95_a, done_a, util_a, back_a, *_ = _closed_loop(
        True, seed=seed, ticks=ticks, topology=topology)
    wall = time.perf_counter() - t0
    steps = 2 * ticks * LoopConfig().steps_per_tick
    return {
        "name": "serving_latency_engine",
        "us_per_call": wall * 1e6 / max(steps, 1),
        "derived": (f"real-engine ({topology}) static vs closed-loop: "
                    f"completed {done_s}->{done_a}, "
                    f"backlog {back_s}->{back_a}, "
                    f"tw-p95 {p95_s:.0f}ms->{p95_a:.0f}ms (static p95 is "
                    f"survivor-biased by its backlog)"),
        "detail": {"static_ms": p95_s, "autoscaled_ms": p95_a,
                   "completed_static": done_s, "completed_auto": done_a,
                   "backlog_static": back_s, "backlog_auto": back_a,
                   "slot_util_static": util_s, "slot_util_auto": util_a,
                   "topology": topology, "slo_ms": ENGINE_SLO_MS},
    }


# ---------------------------------------------------------------------------
# replica-topology smoke (the replica-fabric trajectory artifact)
# ---------------------------------------------------------------------------

TOPOLOGY_SCALES = {
    "smoke": dict(ticks=6, max_replicas=2),
    "full": dict(ticks=ENGINE_TICKS, max_replicas=4),
}


def run_topology(topology: str, smoke: bool = True, seed: int = 0):
    """One autoscaled closed-loop run on the requested replica backend,
    recorded for the CI trajectory (BENCH_serving.json): wall time per
    decode round, completions, backlog, and — for the proc/tcp topologies —
    the measured per-replica transport latency and total RPC count.  The
    same driver, the same seed, the same arrival profile as --engine; only
    the replica fabric changes underneath."""
    from repro.serving.closed_loop import LoopConfig
    scale = TOPOLOGY_SCALES["smoke" if smoke else "full"]
    t0 = time.perf_counter()
    p95, done, util, backlog, transport, rpcs = _closed_loop(
        True, seed=seed, ticks=scale["ticks"], topology=topology,
        max_replicas=scale["max_replicas"])
    wall = time.perf_counter() - t0
    steps = scale["ticks"] * LoopConfig().steps_per_tick
    return {
        "name": "serving_topology",
        "topology": topology,
        "us_per_call": wall * 1e6 / max(steps, 1),
        "derived": (f"{topology} closed loop: {done} completed, "
                    f"backlog {backlog}, tw-p95 {p95:.0f}ms, "
                    f"transport {transport:.2f}ms, {rpcs} RPCs, "
                    f"wall {wall:.1f}s"),
        "detail": {"completed": done, "backlog": backlog,
                   "tw_p95_ms": p95, "slot_util": util,
                   "transport_ms": transport, "rpc_count": rpcs,
                   "wall_s": wall, "seed": seed, **scale},
    }


# ---------------------------------------------------------------------------
# multi-process pod smoke (the ≥2-process jax.distributed trajectory record)
# ---------------------------------------------------------------------------


def run_pod_smoke(pod_size: int = 2, seed: int = 0, n_requests: int = 4,
                  gen_len: int = 4):
    """Drive ONE ``pod_size``-rank pod (worker ranks joined over
    jax.distributed, lockstep digest-verified) through a seeded burst and
    assert its token streams equal an in-process replica's on the same
    seed — the observational-identity bar, recorded for the CI trajectory
    (BENCH_serving_pod.json) together with the pod's mode and whether the
    backend could place one program across the ranks.  GATED: where
    multi-process init is unavailable the record is an explicit skip, not
    a failure."""
    from repro.configs import get_smoke_config
    from repro.serving import DistributedPodReplica, InProcessReplica, \
        MetricsObserver
    from repro.serving.scheduler import Request

    cfg = get_smoke_config("qwen2.5-3b")

    def burst(rep):
        rng = np.random.default_rng(seed)
        reqs = [Request(rid=i, prompt=rng.integers(
                    3, cfg.vocab, size=6).astype(np.int32),
                    gen_len=gen_len) for i in range(n_requests)]
        done, now = [], 0.0
        for r in reqs:
            rep.submit(r, now=0.0)
        while len(done) < n_requests and now < 500:
            now += 1.0
            done.extend(rep.step(now))
        return {r.rid: list(r.tokens_out) for r in done}

    want = burst(InProcessReplica.build(cfg, slots=2, max_seq=24,
                                        prefill_chunk=4))
    t0 = time.perf_counter()
    try:
        pod = DistributedPodReplica(cfg, slots=2, max_seq=24,
                                    prefill_chunk=4, pod_size=pod_size)
    except Exception as e:
        msg = str(e).lower()
        if any(s in msg for s in ("distributed", "initialize",
                                  "coordinator")):
            return {"name": "serving_pod", "skipped": f"{e}",
                    "derived": f"pod smoke SKIPPED (multi-process init "
                               f"unavailable): {e}"}
        raise
    try:
        obs = MetricsObserver(pod.addr)
        info = obs.status()["pod"]
        got = burst(pod)
        pod.lifetime()                   # one transport-EWMA sample
        observed = obs.lifetime()
        obs.close()
    finally:
        pod.close()
    wall = time.perf_counter() - t0
    match = got == want
    return {
        "name": "serving_pod",
        "pod_size": pod_size,
        "streams_match": bool(match),
        "derived": (f"{pod_size}-rank pod ({info['mode']}, spmd_capable="
                    f"{info['spmd_capable']}): {len(got)} requests, streams "
                    f"match inproc: {match}, observer saw "
                    f"{observed['total_completed']} completions, "
                    f"wall {wall:.1f}s"),
        "detail": {"pod": info, "wall_s": wall, "seed": seed,
                   "n_requests": n_requests, "gen_len": gen_len,
                   "transport_ms": pod.transport_ms,
                   "observer_lifetime": observed},
    }


# ---------------------------------------------------------------------------
# submit batching: RPCs per decode round, before vs after
# ---------------------------------------------------------------------------


def run_rpc_batching(topology: str = "tcp", batch: int = 4, rounds: int = 4,
                     seed: int = 0):
    """The transport term the batched step protocol removes: drive ONE
    remote replica through `rounds` bursts of `batch` submits each, with
    per-request submit RPCs (before) vs submits folded into the step
    message (after).  The decode schedule is identical in both modes —
    only the message count changes — so rpc_per_round is the clean
    before/after and the ≥2× acceptance bar lives here."""
    from repro.configs import get_smoke_config
    from repro.serving.replica import ProcessReplica, TcpReplica
    from repro.serving.scheduler import Request

    cfg = get_smoke_config("qwen2.5-3b")
    klass = {"proc": ProcessReplica, "tcp": TcpReplica}[topology]
    out = {}
    for label, batched in (("unbatched", False), ("batched", True)):
        rep = klass(cfg, slots=batch, max_seq=24, prefill_chunk=4,
                    batch_submits=batched)
        rng = np.random.default_rng(seed)

        def req(rid, now):
            r = Request(rid=rid, prompt=rng.integers(
                3, cfg.vocab, size=4).astype(np.int32), gen_len=2)
            rep.submit(r, now=now)

        now = 0.0
        req(10_000, now)                 # warm the jit outside the window
        while rep.pending:
            now += 1.0
            rep.step(now)
        rpc0, t0, steps, rid = rep.rpc_count, time.perf_counter(), 0, 0
        for _ in range(rounds):
            for _ in range(batch):
                req(rid, now)
                rid += 1
            while rep.pending:
                now += 1.0
                rep.step(now)
                steps += 1
        rpcs = rep.rpc_count - rpc0
        wall = time.perf_counter() - t0
        rep.lifetime()                   # one transport-EWMA sample
        out[label] = {"rpc_total": rpcs, "rpc_per_round": rpcs / rounds,
                      "steps_per_round": steps / rounds,
                      "transport_ms": rep.transport_ms, "wall_s": wall}
        rep.close()
    ratio = (out["unbatched"]["rpc_per_round"]
             / max(out["batched"]["rpc_per_round"], 1e-9))
    return {
        "name": "rpc_batching",
        "topology": topology, "batch": batch, "rounds": rounds,
        "rpc_ratio": ratio,
        "derived": (f"submit batching ({topology}, batch={batch}): "
                    f"{out['unbatched']['rpc_per_round']:.1f} -> "
                    f"{out['batched']['rpc_per_round']:.1f} RPCs/round "
                    f"({ratio:.2f}x fewer)"),
        "detail": out,
    }


# ---------------------------------------------------------------------------
# KV-pool ablation (dense per-slot rings vs paged block tables)
# ---------------------------------------------------------------------------

POOL_SCALES = {
    # the dense variant gets dense_slots rings of max_seq tokens; the paged
    # variant gets THE SAME cache HBM (dense_slots·nk blocks, + the trash
    # block) but n_requests slots over it — prefix sharing is what lets the
    # oversubscription actually admit
    "smoke": dict(n_requests=8, prefix_len=12, prompt_len=13, gen_len=3,
                  dense_slots=4, max_seq=16, block_size=4),
    "full": dict(n_requests=12, prefix_len=24, prompt_len=25, gen_len=7,
                 dense_slots=4, max_seq=32, block_size=4),
}


def _pool_run(pool: str, *, n_requests, prefix_len, prompt_len, gen_len,
              dense_slots, max_seq, block_size, seed: int = 0):
    """One warmup request (publishes the prefix blocks), then a burst of
    n_requests sharing its prefix; returns (peak in-flight, streams,
    lifetime counters, cache token capacity)."""
    from repro.configs import get_smoke_config
    from repro.serving import ServingEngine, shared_prefix_requests
    from repro.sim.serving import WorkloadSpec

    cfg = get_smoke_config("qwen2.5-3b")
    nk = max_seq // block_size
    if pool == "paged":
        num_blocks = dense_slots * nk + 1
        eng = ServingEngine(cfg, slots=n_requests, max_seq=max_seq,
                            prefill_chunk=prompt_len, pool="paged",
                            block_size=block_size, num_blocks=num_blocks)
        cache_tokens = num_blocks * block_size
    else:
        eng = ServingEngine(cfg, slots=dense_slots, max_seq=max_seq,
                            prefill_chunk=prompt_len)
        cache_tokens = dense_slots * max_seq
    spec = WorkloadSpec(prompt_len=prompt_len, gen_len=gen_len)
    rng = np.random.default_rng(seed)
    reqs = shared_prefix_requests(spec, n_requests + 1, cfg.vocab,
                                  prefix_len=prefix_len, rng=rng)
    done, now = [], 0.0
    eng.submit(reqs[0], now=now)         # warmup: registers the prefix
    while not eng.idle:
        now += 1.0
        done.extend(eng.step(now=now))
    for r in reqs[1:]:                   # the burst rides the warm prefix
        eng.submit(r, now=now)
    peak = 0
    while len(done) < len(reqs) and now < 2000:
        now += 1.0
        done.extend(eng.step(now=now))
        peak = max(peak, int(eng.active.sum()))
    assert len(done) == len(reqs), f"stalled at {len(done)}/{len(reqs)}"
    return peak, {r.rid: list(r.tokens_out) for r in done}, \
        eng.lifetime(), cache_tokens


def run_pool_ablation(smoke: bool = True, seed: int = 0):
    """Dense per-slot rings vs the paged block-table pool AT FIXED CACHE
    HBM, on a shared-prefix burst.  Records the two acceptance bars: peak
    concurrent in-flight ≥2× dense, and prefill compute cut by the shared-
    prefix fraction (prefill_tokens = prompt_tokens - tokens_shared) — while
    the token streams stay bit-identical."""
    scale = POOL_SCALES["smoke" if smoke else "full"]
    t0 = time.perf_counter()
    peak_d, streams_d, lt_d, hbm_d = _pool_run("dense", seed=seed, **scale)
    peak_p, streams_p, lt_p, hbm_p = _pool_run("paged", seed=seed, **scale)
    wall = time.perf_counter() - t0
    match = streams_d == streams_p
    shared_frac = lt_p["tokens_shared"] / max(lt_p["prompt_tokens"], 1)
    accounting_ok = (lt_p["prefill_tokens"]
                     == lt_p["prompt_tokens"] - lt_p["tokens_shared"])
    return {
        "name": "kv_pool_ablation",
        "streams_match": bool(match),
        "inflight_ratio": peak_p / max(peak_d, 1),
        "derived": (f"paged vs dense at ~{hbm_d} cached tokens: peak "
                    f"in-flight {peak_d}->{peak_p} "
                    f"({peak_p / max(peak_d, 1):.1f}x), prefill "
                    f"{lt_d['prefill_tokens']}->{lt_p['prefill_tokens']} "
                    f"tokens ({shared_frac:.0%} shared), streams match: "
                    f"{match}, wall {wall:.1f}s"),
        "detail": {"dense": {"peak_inflight": peak_d, "cache_tokens": hbm_d,
                             "prefill_tokens": lt_d["prefill_tokens"],
                             "prompt_tokens": lt_d["prompt_tokens"]},
                   "paged": {"peak_inflight": peak_p, "cache_tokens": hbm_p,
                             "prefill_tokens": lt_p["prefill_tokens"],
                             "prompt_tokens": lt_p["prompt_tokens"],
                             "prefix_hits": lt_p["prefix_hits"],
                             "tokens_shared": lt_p["tokens_shared"]},
                   "shared_frac": shared_frac,
                   "accounting_ok": bool(accounting_ok),
                   "scale": scale, "seed": seed, "wall_s": wall},
    }


# ---------------------------------------------------------------------------
# learned policy A/B (the closed learning loop: trace → train → redeploy)
# ---------------------------------------------------------------------------

LEARNED_SCALES = {
    "smoke": dict(trace_ticks=16, ab_ticks=14, max_replicas=3,
                  epochs=4, imitation_epochs=12, dqn_steps=24),
    "full": dict(trace_ticks=40, ab_ticks=28, max_replicas=4,
                 epochs=10, imitation_epochs=30, dqn_steps=80),
}


def run_learned_policy(smoke: bool = True, seed: int = 0):
    """The paper's learning loop, closed end-to-end on the real data plane:

      1. record a planner-driven fleet trace (TraceRecorder) under a bursty
         profile with scripted straggler injection (chaos identical across
         every arm — same seed, same script);
      2. offline-train a fresh allocator on the trace (supervised fit +
         DQN replay + planner imitation — core/dnn/traces.py);
      3. redeploy the learned policy AS the scaler (``mode="hybrid"``, DQN
         choice inside the planner's SLO envelope, learning online) and A/B
         it against the pure planner on the SAME seed/profile/chaos.

    Acceptance bars (CI, BENCH_learned_policy.json): the learned hybrid is
    no worse than the planner on arrivals-weighted SLO-violation rate and
    on fleet slot utilization."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.core.dnn.traces import TraceRecorder, pretrain_on_trace
    from repro.core.monitoring.collector import ReplicaReport
    from repro.serving.closed_loop import LoopConfig, run_closed_loop

    scale = LEARNED_SCALES["smoke" if smoke else "full"]
    cfg = get_smoke_config("qwen2.5-3b")
    lc = dataclasses.replace(LoopConfig(), max_replicas=scale["max_replicas"])

    def bursty(tick, ticks, lc):
        """two spikes with a calm trough between — the A/B load script."""
        q = max(ticks // 4, 1)
        return lc.spike_rps if (q <= tick < 2 * q or 3 * q <= tick) \
            else lc.calm_rps

    def make_chaos(ticks):
        """scripted straggler: for ``evict_after`` consecutive mid-burst
        windows one live replica reports 5s latencies (the rest baseline),
        driving a real eviction + replacement through the control plane."""
        q = max(ticks // 4, 1)
        straggle = set(range(q, q + lc.evict_after))

        def hook(tick, router, collector):
            if tick not in straggle:
                return
            live = sorted(r.replica_id for r in router.serving_replicas)
            if len(live) < 2:
                return
            for rid, lat in [(live[0], 5000.0)] + [(r, 100.0)
                                                   for r in live[1:]]:
                collector.submit(ReplicaReport(
                    replica_id=rid, tick=tick,
                    latency_ms_samples=[lat] * 4, n_requests=4, n_errors=0,
                    flop_util=0.5, hbm_util=0.5, ici_util=0.0,
                    mem_frac=0.5, queue_depth=0))
        return hook

    def arm(mode, ticks, *, recorder=None, prime=None):
        router, logs = run_closed_loop(
            cfg, autoscale=True, ticks=ticks, seed=seed,
            lc=dataclasses.replace(lc, alloc_mode=mode),
            profile=bursty, chaos_hook=make_chaos(ticks),
            recorder=recorder, prime_allocator=prime)
        m = router.metrics()
        router.close()
        arrivals = max(sum(t.arrivals for t in logs), 1)
        viol = sum(t.arrivals for t in logs
                   if t.latency_p95_ms > lc.slo_ms) / arrivals
        return {
            "slo_violation_rate": viol,
            "slot_utilization": m["slot_utilization"],
            "completed": m["completed"],
            "replica_ticks": sum(t.replicas for t in logs),
            "evictions": sum(len(t.evicted) for t in logs),
            "dqn_decisions": sum(1 for t in logs
                                 if t.reason.startswith("dqn")),
            "online_train_steps": sum(1 for t in logs
                                      if t.learn_loss is not None),
        }

    t0 = time.perf_counter()
    rec = TraceRecorder()
    arm("planner", scale["trace_ticks"], recorder=rec)       # 1. trace
    curves = {}

    def prime(alloc):
        curves.update(pretrain_on_trace(                     # 2. train
            alloc, rec.records, epochs=scale["epochs"],
            imitation_epochs=scale["imitation_epochs"],
            dqn_steps=scale["dqn_steps"], seed=seed))

    planner = arm("planner", scale["ab_ticks"])              # 3. A/B
    learned = arm("hybrid", scale["ab_ticks"], prime=prime)
    wall = time.perf_counter() - t0
    # "no worse" with a small smoke-scale tolerance: one straggler window
    # falling on a different tick must not flip the bar
    no_worse_slo = (learned["slo_violation_rate"]
                    <= planner["slo_violation_rate"] + 0.02)
    no_worse_util = (learned["slot_utilization"]
                     >= planner["slot_utilization"] - 0.05)
    return {
        "name": "learned_policy_ab",
        "no_worse_slo": bool(no_worse_slo),
        "no_worse_util": bool(no_worse_util),
        "derived": (f"learned(hybrid) vs planner under chaos: SLO-viol "
                    f"{planner['slo_violation_rate']:.2f}->"
                    f"{learned['slo_violation_rate']:.2f}, slot-util "
                    f"{planner['slot_utilization']:.2f}->"
                    f"{learned['slot_utilization']:.2f}, replica-ticks "
                    f"{planner['replica_ticks']}->"
                    f"{learned['replica_ticks']}, "
                    f"{learned['dqn_decisions']} dqn decisions, "
                    f"{len(rec)} trace ticks, wall {wall:.1f}s"),
        "detail": {"planner": planner, "learned": learned,
                   "trace_ticks": len(rec),
                   "pretrain": {k: ([round(float(v[0]), 4),
                                     round(float(v[-1]), 4)] if v else [])
                                for k, v in curves.items()
                                if isinstance(v, list)},
                   "transitions": curves.get("transitions", 0),
                   "scale": scale, "seed": seed, "wall_s": wall},
    }


# ---------------------------------------------------------------------------
# heterogeneous fleet + SLO tiers (profile-aware vs blind planner)
# ---------------------------------------------------------------------------

TIER_SCALES = {
    "smoke": dict(ticks=10, max_replicas=3, reserved=1, batch_frac=0.4),
    "full": dict(ticks=16, max_replicas=4, reserved=2, batch_frac=0.4),
}
TIER_SLO_MS = 2000.0


def _tier_arm(aware: bool, *, ticks, max_replicas, reserved, batch_frac,
              seed: int = 0):
    """One mixed-tier calm→spike→calm run.  ``aware`` runs the heterogeneous
    fleet (FleetPlan: ``reserved`` on-demand ids, the rest spot) with the
    profile-aware planner AND scripted preemptions of the highest-id spot
    replica during the spike; blind runs the same workload on a flat
    all-on-demand fleet (no profiles, no preemptions).  After the run the
    batch gate is released and the fleet drained, so "absorbs churn" is
    measured as every submitted request actually completing."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.core.dnn.traces import TraceRecorder
    from repro.serving.closed_loop import LoopConfig, run_closed_loop
    from repro.sim.serving import WorkloadSpec

    cfg = get_smoke_config("qwen2.5-3b")
    lc = dataclasses.replace(
        LoopConfig(), max_replicas=max_replicas, batch_frac=batch_frac,
        slo_ms=TIER_SLO_MS, reserved_replicas=reserved if aware else 0)
    # short requests keep the base service time well under the SLO, so the
    # interactive bar measures tier protection, not raw model speed
    spec = WorkloadSpec(prompt_len=8, gen_len=4)
    lo, hi = ticks * 2 // 7, ticks * 9 // 14   # default_profile's spike
    preempt_at = set(range(lo + 1, hi, 2)) if aware else set()

    def chaos(tick, router, collector):
        # spot reclaim, scripted: the highest-id preemptible replica
        # vanishes mid-spike (no replacement — that's the scaler's job)
        if tick not in preempt_at:
            return
        spot = sorted(r.replica_id for r in router.serving_replicas
                      if router.profile(r.replica_id).preemptible)
        if spot:
            router.preempt(spot[-1])

    rec = TraceRecorder()
    router, logs = run_closed_loop(cfg, autoscale=True, ticks=ticks,
                                   seed=seed, lc=lc, spec=spec,
                                   recorder=rec, chaos_hook=chaos)
    try:
        total = sum(t.arrivals for t in logs)
        drained = sum(t.served for t in logs)
        now = ticks * lc.steps_per_tick * lc.tick_s
        router.gate_batch(False)             # release: let batch finish
        steps = 0
        while drained < total and steps < 2000:
            now += lc.tick_s
            drained += len(router.step(now))
            steps += 1
        m = router.metrics()
    finally:
        router.close()
    w = [(r["latency_p95_interactive"], r["arrivals"]) for r in rec.records
         if r["latency_p95_interactive"] > 0.0]
    tw_p95_i = (sum(p * a for p, a in w) / max(sum(a for _, a in w), 1)
                if w else 0.0)
    return {
        "tw_p95_interactive_ms": tw_p95_i,
        "cost_total": float(sum(r["cost_per_tick"] for r in rec.records)),
        "arrivals": int(total),
        "completed": int(m["completed"]),
        "completed_interactive": int(m["completed_interactive"]),
        "completed_batch": int(m["completed_batch"]),
        "preemptions": int(m["preemptions"]),
        "tier_spills": int(m["tier_spills"]),
        "gated_ticks": int(sum(1 for t in logs if t.batch_gated)),
        "replica_ticks": int(sum(t.replicas for t in logs)),
        "drain_steps": steps,
    }


def run_tiers(smoke: bool = True, seed: int = 0):
    """SLO-tiered admission on a heterogeneous fleet, profile-aware vs
    blind.  Acceptance bars (CI, BENCH_tiers.json): the aware arm keeps the
    traffic-weighted interactive p95 inside the SLO while spot replicas are
    being reclaimed under it; every submitted request (batch included)
    still completes — the batch lane absorbs the churn; and the realized
    fleet spend is strictly below the blind all-on-demand arm's."""
    scale = TIER_SCALES["smoke" if smoke else "full"]
    t0 = time.perf_counter()
    aware = _tier_arm(True, seed=seed, **scale)
    blind = _tier_arm(False, seed=seed, **scale)
    wall = time.perf_counter() - t0
    interactive_ok = aware["tw_p95_interactive_ms"] <= TIER_SLO_MS
    absorbed = (aware["preemptions"] > 0
                and aware["completed"] == aware["arrivals"])
    cheaper = aware["cost_total"] < blind["cost_total"]
    return {
        "name": "tiered_fleet",
        "interactive_slo_ok": bool(interactive_ok),
        "churn_absorbed": bool(absorbed),
        "aware_cheaper": bool(cheaper),
        "derived": (f"aware vs blind: interactive tw-p95 "
                    f"{aware['tw_p95_interactive_ms']:.0f}ms (SLO "
                    f"{TIER_SLO_MS:.0f}ms) under "
                    f"{aware['preemptions']} preemptions, "
                    f"{aware['completed']}/{aware['arrivals']} completed "
                    f"({aware['completed_batch']} batch), cost "
                    f"{aware['cost_total']:.1f} vs {blind['cost_total']:.1f} "
                    f"({aware['cost_total'] / max(blind['cost_total'], 1e-9):.0%}), "
                    f"{aware['gated_ticks']} gated ticks, "
                    f"wall {wall:.1f}s"),
        "detail": {"aware": aware, "blind": blind, "slo_ms": TIER_SLO_MS,
                   "scale": scale, "seed": seed, "wall_s": wall},
    }


# ---------------------------------------------------------------------------
# multi-region geo ablation (region-aware vs region-blind placement)
# ---------------------------------------------------------------------------

REGION_SCALES = {
    # slots is kept small so the steady calm load already needs >1 replica
    # — geography only matters when there is more than one place to route
    "smoke": dict(ticks=12, max_replicas=4, reserved=2, batch_frac=0.4,
                  slots=4, calm_rps=5.0, spike_rps=12.0),
    "full": dict(ticks=16, max_replicas=4, reserved=2, batch_frac=0.4,
                 slots=4, calm_rps=5.0, spike_rps=12.0),
}
REGION_SLO_MS = 2000.0
# two-region stripe: even ids NA, odd ids APAC; traffic originates in NA,
# so every odd-id placement pays the NA↔APAC RTT (150 ms ≈ 1.5 decode
# ticks — big enough that placement shows up in the p95)
REGION_STRIPE = ("apac", "sa")   # the matrix's longest leg: 280 ms RTT


def _region_arm(aware: bool, *, ticks, max_replicas, reserved, batch_frac,
                slots, calm_rps, spike_rps, seed: int = 0):
    """One mixed-tier calm→spike→calm run on a GEOGRAPHIC fleet: replicas
    striped across two regions (reserved on-demand ids first, spot past
    them), the plan's RTT matrix injected into the fabric as deterministic
    virtual-clock delay, and the spot leg priced by the seeded market.
    Both arms run the SAME plan, seed, and injected latency — the only
    difference is ``region_aware``: whether the router prefers in-region
    capacity for interactive traffic or stays region-blind."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.core.dnn.traces import TraceRecorder
    from repro.serving.closed_loop import LoopConfig, run_closed_loop
    from repro.sim.serving import WorkloadSpec

    cfg = get_smoke_config("qwen2.5-3b")
    lc = dataclasses.replace(
        LoopConfig(), max_replicas=max_replicas, batch_frac=batch_frac,
        slots=slots, calm_rps=calm_rps, spike_rps=spike_rps,
        slo_ms=REGION_SLO_MS, reserved_replicas=reserved,
        regions=REGION_STRIPE, home_region=REGION_STRIPE[0],
        region_aware=aware, spot_market=True)
    spec = WorkloadSpec(prompt_len=8, gen_len=4)
    rec = TraceRecorder()
    router, logs = run_closed_loop(cfg, autoscale=True, ticks=ticks,
                                   seed=seed, lc=lc, spec=spec, recorder=rec)
    try:
        total = sum(t.arrivals for t in logs)
        drained = sum(t.served for t in logs)
        now = ticks * lc.steps_per_tick * lc.tick_s
        router.gate_batch(False)             # release: let batch finish
        steps = 0
        while drained < total and steps < 2000:
            now += lc.tick_s
            drained += len(router.step(now))
            steps += 1
        m = router.metrics()
    finally:
        router.close()
    w = [(r["latency_p95_interactive"], r["arrivals"]) for r in rec.records
         if r["latency_p95_interactive"] > 0.0]
    tw_p95_i = (sum(p * a for p, a in w) / max(sum(a for _, a in w), 1)
                if w else 0.0)
    return {
        "tw_p95_interactive_ms": tw_p95_i,
        "cost_total": float(sum(r["cost_per_tick"] for r in rec.records)),
        "arrivals": int(total),
        "completed": int(m["completed"]),
        "region_spills": int(m["region_spills"]),
        "tier_spills": int(m["tier_spills"]),
        "transport_ms_mean": float(np.mean(
            [r["transport_ms"] for r in rec.records])) if rec.records else 0.0,
        "spot_price_mean": float(np.mean(
            [r["spot_price"] for r in rec.records])) if rec.records else 0.0,
        "drain_steps": steps,
    }


def run_regions(smoke: bool = True, seed: int = 0):
    """Geographic fleet under a spot-price market, region-aware vs
    region-blind routing on the same seed.  Acceptance bars (CI,
    BENCH_regions.json): the aware arm beats blind on interactive
    traffic-weighted p95 under the injected inter-region RTT, at no higher
    realized cost, and every submitted request completes in both arms."""
    scale = REGION_SCALES["smoke" if smoke else "full"]
    t0 = time.perf_counter()
    aware = _region_arm(True, seed=seed, **scale)
    blind = _region_arm(False, seed=seed, **scale)
    wall = time.perf_counter() - t0
    latency_better = (aware["tw_p95_interactive_ms"]
                      < blind["tw_p95_interactive_ms"])
    # "no higher cost": same plan + market both arms, so this bars the
    # aware arm's scaling trajectory from buying its latency win
    cost_ok = aware["cost_total"] <= blind["cost_total"] * 1.001
    all_completed = (aware["completed"] == aware["arrivals"]
                     and blind["completed"] == blind["arrivals"])
    return {
        "name": "multi_region_fleet",
        "latency_better": bool(latency_better),
        "cost_ok": bool(cost_ok),
        "all_completed": bool(all_completed),
        "derived": (f"geo aware vs blind ({'+'.join(REGION_STRIPE)}): "
                    f"interactive tw-p95 {aware['tw_p95_interactive_ms']:.0f}"
                    f"ms vs {blind['tw_p95_interactive_ms']:.0f}ms "
                    f"({aware['tw_p95_interactive_ms'] / max(blind['tw_p95_interactive_ms'], 1e-9):.0%}), "
                    f"cost {aware['cost_total']:.1f} vs "
                    f"{blind['cost_total']:.1f}, "
                    f"{aware['region_spills']} region spills, "
                    f"spot mean {aware['spot_price_mean']:.2f}, "
                    f"{aware['completed']}/{aware['arrivals']} completed, "
                    f"wall {wall:.1f}s"),
        "detail": {"aware": aware, "blind": blind, "slo_ms": REGION_SLO_MS,
                   "regions": list(REGION_STRIPE), "scale": scale,
                   "seed": seed, "wall_s": wall},
    }


# ---------------------------------------------------------------------------
# decode-kernel ablation (pallas vs jnp reference data path)
# ---------------------------------------------------------------------------

KERNEL_SCALES = {
    # n_requests, prompt_len, gen_len, slots, max_seq
    "smoke": dict(n_requests=4, prompt_len=6, gen_len=5, slots=2, max_seq=24),
    "full": dict(n_requests=16, prompt_len=12, gen_len=16, slots=4,
                 max_seq=64),
}


def _kernel_run(use_pallas: bool, *, n_requests, prompt_len, gen_len, slots,
                max_seq, seed: int = 0):
    """One staggered continuous-batching run; returns (per-tick wall times,
    token streams by rid)."""
    from repro.configs import get_smoke_config
    from repro.serving import ServingEngine
    from repro.serving.scheduler import Request

    cfg = get_smoke_config("qwen2.5-3b", use_pallas=use_pallas)
    eng = ServingEngine(cfg, slots=slots, max_seq=max_seq,
                        prefill_chunk=max(prompt_len // 2, 2))
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i, prompt=rng.integers(
                3, cfg.vocab, size=prompt_len).astype(np.int32),
                gen_len=gen_len) for i in range(n_requests)]
    done, tick_s, now, step = [], [], 0.0, 0
    while len(done) < n_requests and step < 10_000:
        if step % 2 == 0 and step // 2 < len(reqs):
            eng.submit(reqs[step // 2], now=now)   # staggered admissions
        now += 1.0
        t0 = time.perf_counter()
        done.extend(eng.step(now=now))
        tick_s.append(time.perf_counter() - t0)
        step += 1
    assert len(done) == n_requests, f"stalled at {len(done)}/{n_requests}"
    return tick_s, {r.rid: list(r.tokens_out) for r in done}


def run_kernel_ablation(kernel: str = "both", smoke: bool = True,
                        seed: int = 0):
    """Per-kernel decode-path measurement + cross-path token equivalence."""
    scale = KERNEL_SCALES["smoke" if smoke else "full"]
    variants = {"ref": False, "pallas": True}
    if kernel != "both":
        variants = {kernel: variants[kernel]}
    out, streams = {}, {}
    for name, use_pallas in variants.items():
        ticks, toks = _kernel_run(use_pallas, seed=seed, **scale)
        warm = ticks[1:] if len(ticks) > 1 else ticks   # tick 0 pays the jit
        n_tokens = sum(len(t) for t in toks.values())
        out[name] = {
            "ticks": len(ticks),
            "mean_tick_ms": float(np.mean(warm)) * 1e3,
            "p95_tick_ms": float(np.percentile(warm, 95)) * 1e3,
            "tokens": n_tokens,
            # rate over warm ticks only — at smoke scale tick 0's compile
            # time would otherwise dominate the trajectory record
            "tok_per_s": n_tokens / max(sum(warm), 1e-9),
        }
        streams[name] = toks
    match = (len(streams) < 2
             or streams["ref"] == streams["pallas"])
    per = ", ".join(f"{k} {v['mean_tick_ms']:.1f}ms/tick"
                    for k, v in out.items())
    note = ("pallas runs INTERPRETED on CPU (correctness trajectory; "
            "compiled speed needs a TPU)")
    return {
        "name": "decode_kernel_ablation",
        "derived": f"{per}; token streams match: {match} — {note}",
        "tokens_match": bool(match),
        "detail": {"kernels": out, "scale": scale, "seed": seed},
    }


# ---------------------------------------------------------------------------
# speculative-decode ablation (draft + single-pass verify vs plain decode)
# ---------------------------------------------------------------------------

SPEC_SCALES = {
    # period: the repeated-phrase length of the prompt-echo workload —
    # period=4 with ngram=3 is the sweet spot where greedy decode on the
    # smoke model locks into the prompt's cycle and drafts keep landing.
    # n_requests == slots: every request admits (one-shot prefill) on the
    # first tick, so dropping that tick leaves a pure decode measurement.
    "smoke": dict(n_requests=4, prompt_len=12, gen_len=32, slots=4,
                  max_seq=52, period=4, spec_k=3, spec_ngram=3),
    "full": dict(n_requests=8, prompt_len=12, gen_len=48, slots=8,
                 max_seq=68, period=4, spec_k=3, spec_ngram=3),
}


def _spec_pair(use_pallas: bool, pool: str, spec_k: int, *, n_requests,
               prompt_len, gen_len, slots, max_seq, period, spec_ngram,
               seed: int = 0, rounds: int = 3):
    """One plain engine and one speculating engine, driven through the SAME
    decode burst in INTERLEAVED rounds.  Each engine's first burst pays
    every jit trace (prefill, fused decode, one verify trace per window
    width); the measured rounds alternate plain/spec back-to-back so both
    arms sample the same seconds of a shared CPU box — and because the tick
    sequence is deterministic, the per-tick-index MINIMUM across rounds is
    each arm's noise-floor estimate (contention only ever adds time).

    The timed region is DECODE ONLY: all requests admit on the first step
    (n_requests == slots, one-shot prefill), and that step — admission
    scatter plus each slot's first token — is excluded.  Speculation is a
    decode-path optimization; folding the arms' identical prefill compute
    into the rate would only dilute the measured effect (the TTFT/TPOT
    split, measured the standard way).  Returns {arm: (per-tick floor
    times, token streams, counters)}."""
    from repro.configs import get_smoke_config
    from repro.serving import ServingEngine
    from repro.serving.workload import repetitive_requests
    from repro.sim.serving import WorkloadSpec

    assert n_requests == slots, "one admission wave = one excluded tick"
    cfg = get_smoke_config("qwen2.5-3b", use_pallas=use_pallas)
    kw = dict(slots=slots, max_seq=max_seq,
              prefill_chunk=prompt_len, spec_ngram=spec_ngram)
    if pool == "paged":
        bs = 4
        kw.update(pool="paged", block_size=bs,
                  num_blocks=slots * (max_seq // bs) + 1)
    engines = {"plain": ServingEngine(cfg, spec_k=0, **kw),
               "spec": ServingEngine(cfg, spec_k=spec_k, **kw)}
    spec = WorkloadSpec(prompt_len=prompt_len, gen_len=gen_len)

    def burst(eng, base_rid):
        rng = np.random.default_rng(seed)     # same prompts every burst
        reqs = repetitive_requests(spec, n_requests, cfg.vocab,
                                   period=period, rng=rng, base_rid=base_rid)
        for r in reqs:
            eng.submit(r, now=0.0)
        done = list(eng.step(now=1.0))        # admissions + first token
        tick_s, now, step = [], 1.0, 0
        while len(done) < n_requests and step < 10_000:
            now += 1.0
            t0 = time.perf_counter()
            done.extend(eng.step(now=now))
            tick_s.append(time.perf_counter() - t0)
            step += 1
        assert len(done) == n_requests, f"stalled at {len(done)}/{n_requests}"
        return tick_s, {r.rid - base_rid: list(r.tokens_out) for r in done}

    out = {}
    for arm, eng in engines.items():
        burst(eng, 10_000)                    # warmup
        lt0, pulls0 = eng.lifetime(), eng.logits_pulls
        tick_s, streams = burst(eng, 0)
        lt = eng.lifetime()
        out[arm] = [np.asarray(tick_s), streams, {
            "spec_proposed": lt["spec_proposed"] - lt0["spec_proposed"],
            "spec_accepted": lt["spec_accepted"] - lt0["spec_accepted"],
            "logits_pulls": eng.logits_pulls - pulls0,
        }]
    for rep in range(1, rounds):              # interleaved re-measures
        for arm, eng in engines.items():
            tick_r, streams_r = burst(eng, rep * 20_000)
            assert streams_r == out[arm][1]   # determinism across bursts
            out[arm][0] = np.minimum(out[arm][0], tick_r)
    return out


def run_speculative_ablation(smoke: bool = True, seed: int = 0):
    """Speculative decoding tokens/s, controlled three ways: spec-on vs
    spec-off (the measurement), pallas vs ref sampling kernel and dense vs
    paged KV pool (the invariance axes).  All eight arms run greedy on the
    same prompt-echo burst, so every token stream must be bit-identical —
    speculation and the fused sampler are pure latency optimizations.  The
    zero-pull bar asserts no greedy arm materialized (slots, 1, V) logits
    on the host; acceptance comes from the engine's lifetime counters."""
    scale = SPEC_SCALES["smoke" if smoke else "full"]
    run_kw = {k: v for k, v in scale.items() if k != "spec_k"}
    t0 = time.perf_counter()
    arms, streams = {}, {}
    for kname, use_pallas in (("ref", False), ("pallas", True)):
        for pool in ("dense", "paged"):
            pair = _spec_pair(use_pallas, pool, scale["spec_k"],
                              seed=seed, **run_kw)
            for mode, (ticks, toks, ctr) in pair.items():
                label = f"{kname}/{pool}/{mode}"
                # each slot's first token lands on the excluded admission
                # tick — count only tokens the timed decode region emitted
                n_tokens = (sum(len(t) for t in toks.values())
                            - scale["n_requests"])
                arms[label] = {"ticks": len(ticks), "tokens": n_tokens,
                               "tok_per_s": n_tokens / max(float(
                                   np.sum(ticks)), 1e-9),
                               **ctr}
                streams[label] = toks
    wall = time.perf_counter() - t0
    first = next(iter(streams.values()))
    match = all(s == first for s in streams.values())
    zero_pulls = all(a["logits_pulls"] == 0 for a in arms.values())
    speedups = {f"{kn}/{pl}": (arms[f"{kn}/{pl}/spec"]["tok_per_s"]
                               / max(arms[f"{kn}/{pl}/plain"]["tok_per_s"],
                                     1e-9))
                for kn in ("ref", "pallas") for pl in ("dense", "paged")}
    prop = sum(a["spec_proposed"] for a in arms.values())
    acc = sum(a["spec_accepted"] for a in arms.values())
    accept_rate = acc / max(prop, 1)
    # the tok/s CI bar lives on the ref arms: pallas runs INTERPRETED on
    # CPU, so its wall times are a correctness trajectory, not perf — the
    # pallas ratios are recorded but only gate stream/pull correctness
    ref_speedups = [v for k, v in speedups.items() if k.startswith("ref")]
    return {
        "name": "speculative_decode_ablation",
        "streams_match": bool(match),
        "zero_pulls": bool(zero_pulls),
        "accept_rate": accept_rate,
        "min_speedup": min(ref_speedups),
        "best_ref_speedup": max(ref_speedups),
        "derived": (f"spec_k={scale['spec_k']} on prompt-echo "
                    f"(period={scale['period']}): tok/s "
                    + ", ".join(f"{k} x{v:.2f}" for k, v in speedups.items())
                    + f" (pallas interpreted on CPU); accept "
                    f"{accept_rate:.2f} ({acc}/{prop}), streams match: "
                    f"{match}, zero host logits pulls: {zero_pulls}, "
                    f"wall {wall:.1f}s"),
        "detail": {"arms": arms, "speedups": speedups, "scale": scale,
                   "seed": seed, "wall_s": wall},
    }


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", action="store_true",
                    help="run the real-engine closed loop (CPU smoke)")
    ap.add_argument("--kernel", choices=["pallas", "ref", "both"],
                    default=None,
                    help="decode data-path ablation: fused Pallas vector-"
                         "index kernel vs jnp reference")
    ap.add_argument("--speculative", action="store_true",
                    help="speculative-decode tokens/s ablation (spec-on vs "
                         "spec-off x pallas/ref x dense/paged on a prompt-"
                         "echo workload); merges into BENCH_decode.json "
                         "and composes with --kernel")
    ap.add_argument("--topology", choices=["inproc", "sharded", "proc",
                                           "tcp", "pod"],
                    default=None,
                    help="replica-fabric smoke: the closed loop on one "
                         "backend, recorded to --out (BENCH_serving.json); "
                         "proc/tcp also record submit-batching RPC counts; "
                         "pod runs the gated ≥2-process jax.distributed "
                         "smoke (BENCH_serving_pod.json)")
    ap.add_argument("--pool", choices=["dense", "paged"], default=None,
                    help="KV-pool ablation: dense per-slot rings vs paged "
                         "block tables with prefix sharing at fixed cache "
                         "HBM (either value runs BOTH variants — the flag "
                         "records which layout is under test; writes "
                         "BENCH_paged.json)")
    ap.add_argument("--tiers", action="store_true",
                    help="heterogeneous-fleet tier ablation: profile-aware "
                         "planner + laned admission + scripted spot "
                         "preemptions vs a blind flat fleet on the same "
                         "seed (writes BENCH_tiers.json)")
    ap.add_argument("--regions", action="store_true",
                    help="multi-region geo ablation: region-striped fleet "
                         "under a seeded spot-price market with injected "
                         "inter-region RTT, region-aware vs region-blind "
                         "routing on the same seed (writes "
                         "BENCH_regions.json)")
    ap.add_argument("--learned", action="store_true",
                    help="learned-policy A/B: record a planner trace, "
                         "offline-train the allocator on it, redeploy it "
                         "as the hybrid scaler vs the pure planner under "
                         "identical chaos (writes "
                         "BENCH_learned_policy.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="smallest ablation scale (CI artifact)")
    ap.add_argument("--out", default=None,
                    help="where --kernel / --topology write their JSON "
                         "record (defaults: BENCH_decode.json / "
                         "BENCH_serving.json)")
    args = ap.parse_args()
    if args.kernel or args.speculative:
        out_path = args.out or "BENCH_decode.json"
        if args.kernel:
            res = run_kernel_ablation(args.kernel, smoke=args.smoke)
        else:                # keep the kernel record if the file has one
            try:
                with open(out_path) as f:
                    res = json.load(f)
            except (OSError, ValueError):
                res = {"name": "decode_kernel_ablation"}
        if args.speculative:
            res["speculative"] = sp = run_speculative_ablation(
                smoke=args.smoke)
            print(sp["derived"])
        with open(out_path, "w") as f:
            json.dump(res, f, indent=2, sort_keys=True)
        if args.kernel:
            print(res["derived"])
            if not res["tokens_match"]:
                raise SystemExit("kernel ablation: token streams diverged")
        if args.speculative:
            if not sp["streams_match"]:
                raise SystemExit("speculative ablation: greedy token "
                                 "streams diverged from plain decode")
            if not sp["zero_pulls"]:
                raise SystemExit("speculative ablation: a greedy arm pulled "
                                 "host logits (fused sampling bypassed)")
            if sp["min_speedup"] < 1.0:
                raise SystemExit("speculative ablation: tokens/s regressed "
                                 "with speculation on")
    elif args.pool:
        res = run_pool_ablation(smoke=args.smoke)
        with open(args.out or "BENCH_paged.json", "w") as f:
            json.dump(res, f, indent=2, sort_keys=True)
        print(res["derived"])
        if not res["streams_match"]:
            raise SystemExit("pool ablation: token streams diverged")
        if res["inflight_ratio"] < 2.0:
            raise SystemExit("pool ablation: paged pool should hold >=2x "
                             "the dense pool's concurrent requests at "
                             "fixed cache HBM")
        if not res["detail"]["accounting_ok"]:
            raise SystemExit("pool ablation: prefill_tokens != "
                             "prompt_tokens - tokens_shared")
    elif args.tiers:
        res = run_tiers(smoke=args.smoke)
        with open(args.out or "BENCH_tiers.json", "w") as f:
            json.dump(res, f, indent=2, sort_keys=True)
        print(res["derived"])
        if not res["interactive_slo_ok"]:
            raise SystemExit("tiered fleet: interactive tw-p95 blew the "
                             "SLO despite the batch gate")
        if not res["churn_absorbed"]:
            raise SystemExit("tiered fleet: preemption churn was not "
                             "absorbed (no preemptions fired, or submitted "
                             "work was lost)")
        if not res["aware_cheaper"]:
            raise SystemExit("tiered fleet: the profile-aware plan should "
                             "cost less than the blind all-on-demand fleet")
    elif args.regions:
        res = run_regions(smoke=args.smoke)
        with open(args.out or "BENCH_regions.json", "w") as f:
            json.dump(res, f, indent=2, sort_keys=True)
        print(res["derived"])
        if not res["latency_better"]:
            raise SystemExit("regions: region-aware placement should beat "
                             "region-blind on interactive tw-p95 under "
                             "injected inter-region RTT")
        if not res["cost_ok"]:
            raise SystemExit("regions: the aware arm must not buy its "
                             "latency win (realized cost above blind)")
        if not res["all_completed"]:
            raise SystemExit("regions: submitted work was lost")
    elif args.learned:
        res = run_learned_policy(smoke=args.smoke)
        with open(args.out or "BENCH_learned_policy.json", "w") as f:
            json.dump(res, f, indent=2, sort_keys=True)
        print(res["derived"])
        if not res["no_worse_slo"]:
            raise SystemExit("learned policy: hybrid SLO-violation rate "
                             "worse than the planner's")
        if not res["no_worse_util"]:
            raise SystemExit("learned policy: hybrid slot utilization "
                             "worse than the planner's")
    elif args.topology == "pod":
        res = run_pod_smoke()
        print(res["derived"])
        with open(args.out or "BENCH_serving_pod.json", "w") as f:
            json.dump(res, f, indent=2, sort_keys=True)
        if not res.get("skipped") and not res["streams_match"]:
            raise SystemExit("pod smoke: token streams diverged from inproc")
    elif args.topology:
        res = run_topology(args.topology, smoke=args.smoke)
        print(res["derived"])
        if args.topology in ("proc", "tcp"):
            res["rpc_batching"] = run_rpc_batching(args.topology)
            print(res["rpc_batching"]["derived"])
        with open(args.out or "BENCH_serving.json", "w") as f:
            json.dump(res, f, indent=2, sort_keys=True)
        if res["detail"]["completed"] == 0:
            raise SystemExit("topology smoke: nothing completed")
        if res.get("rpc_batching", {}).get("rpc_ratio", 99.0) < 2.0:
            raise SystemExit("rpc batching: step-folded submits should cut "
                             "RPCs/round by >=2x at batch >= 4")
    else:
        print((run_engine() if args.engine else run())["derived"])
