"""Shared fleet-simulation harness for the paper-table benchmarks.

One tick loop wires together the full system: workload trace → roofline-
grounded queueing model (per-replica numbers from the compiled dry-run) →
metrics collector → controller (traditional reactive baseline, or the
DNN-powered predictive allocator) → multi-cloud cluster (cost + provisioning
delays).  Every §4.1 headline number falls out of this loop under a different
controller/provider configuration.

Calibration notes (recorded in EXPERIMENTS.md §Benchmarks):
  * arch defaults to h2o-danube-1.8b — the paper evaluates "1 billion
    parameter models";
  * WorkloadSpec(prompt 256, gen 16) puts the per-request service time at
    ~150-200 ms, the paper's latency regime;
  * the traditional baseline runs on the paper's implied defaults: premium
    provider (aws), reactive threshold autoscaling; the DNN path additionally
    applies the framework's cost-aware provider selection (gcp) — the paper's
    multi-cloud optimization (§5.2).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np

from repro.core.allocation.allocator import AllocatorConfig, PredictiveAllocator
from repro.core.dnn.features import deploy_vector
from repro.core.monitoring.adapt import AdaptiveOptimizer
from repro.core.monitoring.anomaly import AnomalyDetector
from repro.core.monitoring.collector import MetricsCollector, ReplicaReport
from repro.core.scaling.scaler import ScalingConstraints
from repro.sim import (
    Cluster, RooflineDB, ServiceProfile, ServingModel, ThresholdAutoscaler,
    TraceConfig, WorkloadSpec, generate_trace,
)

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "results" / "dryrun"

ARCH_1B = "h2o-danube-1.8b"          # the paper's "1B parameter" class
SLO_MS = 200.0                        # paper §4.2.1: "under 200ms"
SEEDS = (0, 1, 2)
N_TICKS = 576                         # 2 days of 5-min ticks

_HEADLINE_CACHE: dict = {}


def headline_comparison(controller: str, seed: int) -> "FleetResult":
    """Memoized §4.1.1 run — utilization / cost / latency benchmarks all read
    the same three-seed traditional-vs-DNN comparison."""
    key = (controller, seed)
    if key not in _HEADLINE_CACHE:
        _HEADLINE_CACHE[key] = run_fleet(controller=controller,
                                         n_ticks=N_TICKS, seed=seed)
    return _HEADLINE_CACHE[key]


def traffic_weighted_p95(r: "FleetResult") -> float:
    """p95 weighted by per-tick load — how users experience the fleet."""
    return float(np.average(r.lats, weights=np.maximum(r.utils, 1e-9)))


@dataclasses.dataclass
class FleetResult:
    utilization: float
    latency_p95_ms: float
    latency_p50_ms: float
    cost_per_1k: float                # USD per 1000 inferences
    error_rate: float
    spend_usd: float
    served: int
    replica_ticks: int
    utils: np.ndarray
    lats: np.ndarray
    replicas: np.ndarray
    decisions_per_s: float = 0.0


def make_profile(arch: str = ARCH_1B) -> ServiceProfile:
    return ServiceProfile.from_db(RooflineDB(DRYRUN_DIR), arch)


def default_workload() -> WorkloadSpec:
    # prompt 256 + 12 generated tokens ⇒ ~127 ms service time on the 1B-class
    # profile — a 200 ms SLO is then *feasible but tight* (p95 floor ≈ 171 ms
    # after queueing dispersion), which is the paper's operating regime.
    return WorkloadSpec(prompt_len=256, gen_len=12)


def make_controller(kind: str, profile, workload, *, slo_ms=SLO_MS,
                    max_replicas=64, mode="planner", seed=0,
                    static_sized_for=None, max_step=8, cooldown_ticks=3):
    """kind: 'traditional' (static sizing — the paper's comparison point) |
    'threshold' (reactive autoscaler — the stronger ablation baseline) |
    'dnn' (the predictive control plane)."""
    if kind == "traditional":
        # sized once for (observed mean load × margin), then frozen — the
        # paper's "static rules … manual intervention" traditional practice
        state = {"replicas": None}

        def decide(metrics, current, perf_model):
            if state["replicas"] is None:
                lam = static_sized_for or metrics.get("rps", 1.0)
                r = 1
                while r < max_replicas:
                    lat, util = perf_model(r, lam)
                    if lat <= slo_ms and util <= 0.80:
                        break
                    r += 1
                state["replicas"] = r
            return state["replicas"]

        return decide

    if kind == "threshold":
        thr = ThresholdAutoscaler(hi=0.75, lo=0.25, patience=3, max_step=2,
                                  max_replicas=max_replicas)

        def decide(metrics, current, perf_model):
            return thr.decide(metrics, current)

        return decide

    holder = {}

    def perf_model(replicas, rps):
        return holder["m"](replicas, rps)

    base_constraints = ScalingConstraints(max_replicas=max_replicas,
                                          slo_ms=slo_ms, max_step=max_step,
                                          cooldown_ticks=cooldown_ticks)
    alloc = PredictiveAllocator(
        perf_model, base_constraints,
        deploy_vector(model_params_b=1.8, family="dense", mesh_model=16,
                      mesh_data=16, region_idx=0, slo_ms=slo_ms,
                      cost_weight=0.5),
        cfg=AllocatorConfig(mode=mode), seed=seed)
    # monitoring → adaptation feedback loop (paper §3.5.2): anomalies narrow
    # the target-utilization band (spike headroom); chronic SLO violations
    # lengthen the forecast horizon; flapping lengthens the cooldown.
    adapt = AdaptiveOptimizer(eval_window=32)
    adapt.state.cooldown = cooldown_ticks
    anom = AnomalyDetector(z_threshold=4.0, min_history=16)
    state = {"last_target": None, "anoms": 0}

    def decide(metrics, current, pm):
        holder["m"] = pm
        alloc.replicas = current
        alloc.observe(metrics)
        anomalies = anom.update(int(metrics.get("tick", 0)),
                                {"rps": metrics.get("rps", 0.0)})
        state["anoms"] += len(anomalies)
        d = alloc.decide(metrics)
        alloc.apply(d)
        if mode != "planner":
            alloc.learn(metrics, metrics.get("cost_per_tick", 0.0))
        flapped = (state["last_target"] is not None
                   and (d.delta > 0) and state["last_target"] < current)
        # cost normalized to the max-fleet cost so the adaptation objective
        # weighs utilization and cost on comparable scales
        max_cost = max_replicas * alloc.constraints.cost_per_replica
        adapt.push(metrics,
                   flapped=flapped,
                   violations=int(metrics.get("latency_p95", 0.0) > slo_ms),
                   cost=metrics.get("cost_per_tick", 0.0) / max_cost)
        st = adapt.maybe_adapt()
        if st is not None:
            # a burst of anomalies ⇒ keep extra headroom below the tuned band
            if state["anoms"] > 3:
                st.util_hi = max(0.65, st.util_hi - 0.05)
            state["anoms"] = 0
            alloc.constraints = adapt.constraints(base_constraints)
            alloc.scaler.horizon = st.horizon
        state["last_target"] = d.target_replicas
        return d.target_replicas

    decide.allocator = alloc
    decide.adapt = adapt
    return decide


def run_fleet(*, controller="traditional", arch=ARCH_1B, n_ticks=576,
              tick_s=300.0, seed=0, region="na", provider=None,
              base_rps_per_replica=0.8, n_replicas0=10, max_replicas=64,
              mode="planner", slo_ms=SLO_MS, trace=None,
              workload=None, fail_prob=0.0, collector=None,
              record_streams=None, max_step=8, burnin: int = 0) -> FleetResult:
    """Simulate `n_ticks` of fleet operation under one controller.

    base_rps_per_replica: mean trace load expressed as a fraction of one
    replica's request rate, scaled by n_replicas0 (so 0.8 ⇒ the initial fleet
    would run at 80% utilization at mean load — the regime where reactive
    scaling starts missing peaks, per the paper's motivation).
    """
    profile = make_profile(arch)
    w = workload or default_workload()
    cap1 = profile.requests_per_s(w)            # one replica's service rate
    if provider is None:
        provider = "aws" if controller == "traditional" else "gcp"
    if trace is None:
        trace = generate_trace(
            TraceConfig(base_rps=cap1 * n_replicas0 * base_rps_per_replica,
                        region=region, seed=seed), n_ticks)
    model = ServingModel(profile, w, slo_ms=slo_ms, tick_s=tick_s, seed=seed)
    cluster = Cluster(provider=provider, region=region,
                      chips_per_replica=profile.chips_per_replica,
                      tick_s=tick_s, seed=seed)
    cluster.scale_to(n_replicas0)
    cluster.tick = 10 ** 9                      # initial fleet starts warm
    # scale-down cooldown must exceed the provisioning delay, or the fleet
    # flaps: a down-then-up cycle swaps a warm replica for a cold one
    cooldown = max(3, int(np.ceil(240.0 / tick_s)))
    decide = make_controller(controller, profile, w, slo_ms=slo_ms,
                             max_replicas=max_replicas, mode=mode, seed=seed,
                             static_sized_for=float(np.mean(trace)) * 1.25,
                             max_step=max_step, cooldown_ticks=cooldown)
    coll = collector or MetricsCollector()

    utils, p95s, p50s, reps = [], [], [], []
    served = errs = replica_ticks = 0
    spend0 = served0 = 0.0           # snapshot at burn-in end
    import time as _time
    t_decide = 0.0
    for t in range(n_ticks):
        if t == burnin:
            utils, p95s, p50s, reps = [], [], [], []
            spend0, served0 = cluster.spend_usd, float(served)
            served = errs = replica_ticks = 0
        ready = max(cluster.ready_replicas(), 1)
        r = model.tick(ready, trace[t])
        coll.submit(ReplicaReport(
            replica_id=0, tick=t, latency_ms_samples=list(r.latency_ms_samples),
            n_requests=r.served, n_errors=r.errors, flop_util=r.utilization,
            hbm_util=r.utilization * 0.9, ici_util=r.utilization * 0.5,
            mem_frac=0.5, queue_depth=int(r.queue_depth)))
        rec = coll.aggregate(t, n_replicas=cluster.total_replicas(),
                             max_replicas=max_replicas)
        metrics = {
            **rec,
            "rps": float(trace[t]),
            "rps_window": list(trace[max(0, t - 8):t + 1]),
            "cost_per_tick": cluster.cost_per_tick(),
        }
        t0 = _time.perf_counter()
        target = decide(metrics, cluster.total_replicas(),
                        lambda rr, rps: model.latency_util(rr, rps))
        t_decide += _time.perf_counter() - t0
        cluster.scale_to(target)
        cluster.advance(fail_prob=fail_prob)
        utils.append(r.utilization)
        p95s.append(float(np.percentile(r.latency_ms_samples, 95)))
        p50s.append(float(np.percentile(r.latency_ms_samples, 50)))
        reps.append(cluster.total_replicas())
        served += r.served
        errs += r.errors
        replica_ticks += cluster.total_replicas()
        if record_streams is not None:
            record_streams.append((metrics, target))
    return FleetResult(
        utilization=float(np.mean(utils)),
        latency_p95_ms=float(np.mean(p95s)),
        latency_p50_ms=float(np.mean(p50s)),
        cost_per_1k=1000.0 * (cluster.spend_usd - spend0) / max(served, 1),
        error_rate=errs / max(served + errs, 1),
        spend_usd=cluster.spend_usd,
        served=served,
        replica_ticks=replica_ticks,
        utils=np.asarray(utils),
        lats=np.asarray(p95s),
        replicas=np.asarray(reps),
        decisions_per_s=n_ticks / max(t_decide, 1e-9),
    )
