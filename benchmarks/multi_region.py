"""Paper §4.1.2: multi-region analysis — consistent improvements across five
geographical regions (NA, EU, APAC, SA, AU), magnitude varying with regional
infrastructure (cost multipliers, demand scale, diurnal phase).
"""
import time

import numpy as np

from benchmarks.common import N_TICKS, run_fleet, traffic_weighted_p95
from repro.sim.workload import REGIONS


def run(n_ticks: int | None = None):
    t0 = time.perf_counter()
    per_region = {}
    if n_ticks is None:
        n_ticks = N_TICKS // 2                  # one simulated day per region
    for region in REGIONS:
        t = run_fleet(controller="traditional", region=region,
                      n_ticks=n_ticks, seed=0)
        d = run_fleet(controller="dnn", region=region, n_ticks=n_ticks, seed=0)
        per_region[region] = {
            "util_gain_rel": d.utilization / max(t.utilization, 1e-9) - 1,
            "cost_reduction": 1 - d.cost_per_1k / max(t.cost_per_1k, 1e-9),
            "latency_reduction": 1 - traffic_weighted_p95(d)
            / max(traffic_weighted_p95(t), 1e-9),
            "util_traditional": t.utilization,
            "util_dnn": d.utilization,
        }
    wall = time.perf_counter() - t0
    gains = [v["util_gain_rel"] for v in per_region.values()]
    costs = [v["cost_reduction"] for v in per_region.values()]
    all_improve = all(g > 0 for g in gains) and all(c > 0 for c in costs)
    return {
        "name": "multi_region",
        "us_per_call": wall * 1e6 / (len(REGIONS) * 2 * n_ticks),
        "derived": (f"util gain {min(gains)*100:.0f}%..{max(gains)*100:.0f}% "
                    f"cost -{min(costs)*100:.0f}%..-{max(costs)*100:.0f}% "
                    f"across {len(REGIONS)} regions "
                    f"({'all improve' if all_improve else 'MIXED'})"),
        "detail": {"per_region": per_region, "all_improve": bool(all_improve)},
    }


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="quarter-day per region (CI smoke scale)")
    ap.add_argument("--out", default=None,
                    help="write the result record as JSON")
    args = ap.parse_args()
    r = run(n_ticks=N_TICKS // 8 if args.smoke else None)
    print(r["derived"])
    for region, v in r["detail"]["per_region"].items():
        print(f"  {region:5s} util {v['util_traditional']:.2f}->"
              f"{v['util_dnn']:.2f}  cost -{v['cost_reduction']*100:.0f}%  "
              f"lat -{v['latency_reduction']*100:.0f}%")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(r, f, indent=2, sort_keys=True)
    if not r["detail"]["all_improve"]:
        raise SystemExit("multi-region bar failed: a region regressed on "
                         "utilization or cost")
