"""Paper §4.2.1: progressive load 1k → 100k RPS with response times held
under 200 ms at peak.

The ramp multiplies request volume 100× over the run; the DNN allocator must
ride it (max_replicas is sized so capacity exists — the paper's point is that
the *controller* finds it, proactively).  The static baseline, sized for the
initial load, collapses early in the ramp.
"""
import time

import numpy as np

from benchmarks.common import (
    SLO_MS, default_workload, make_profile, run_fleet,
)

LEVELS = (1_000, 5_000, 10_000, 25_000, 50_000, 100_000)   # RPS


def ramp_trace(n_ticks: int) -> np.ndarray:
    """Piecewise ramp through the paper's load levels."""
    per = n_ticks // len(LEVELS)
    out = np.concatenate([np.full(per, float(l)) for l in LEVELS])
    return np.pad(out, (0, n_ticks - len(out)), edge_mode := "edge",
                  ) if len(out) < n_ticks else out[:n_ticks]


def run():
    profile = make_profile()
    w = default_workload()
    cap1 = profile.requests_per_s(w)
    n_per = 12
    n_ticks = n_per * len(LEVELS)
    trace = np.concatenate([np.full(n_per, float(l)) for l in LEVELS])
    max_replicas = int(np.ceil(100_000 / cap1 / 0.7))      # capacity exists

    t0 = time.perf_counter()
    # at fleet scale the per-decision step is relative (grow to whatever the
    # optimizer deems feasible), not an absolute ±8 — the provisioning delay,
    # not the controller, is the physical limit
    res = run_fleet(controller="dnn", trace=trace, n_ticks=n_ticks,
                    tick_s=300.0, max_replicas=max_replicas,
                    max_step=max_replicas,
                    n_replicas0=int(np.ceil(1000 / cap1 / 0.7)), seed=0)
    base = run_fleet(controller="traditional", trace=trace, n_ticks=n_ticks,
                     tick_s=300.0, max_replicas=max_replicas,
                     n_replicas0=int(np.ceil(1000 / cap1 / 0.7)), seed=0)
    wall = time.perf_counter() - t0

    # per-level p95 (skip each level's first 2 ticks: scaling transient)
    lvl_p95 = {}
    for i, lvl in enumerate(LEVELS):
        seg = res.lats[i * n_per + 2:(i + 1) * n_per]
        lvl_p95[lvl] = float(np.mean(seg))
    peak_ok = lvl_p95[100_000] < SLO_MS
    return {
        "name": "load_testing",
        "us_per_call": wall * 1e6 / (2 * n_ticks),
        "derived": (f"p95@100kRPS {lvl_p95[100_000]:.0f}ms "
                    f"({'<' if peak_ok else '>='}200ms SLO, paper <200ms); "
                    f"static baseline err {base.error_rate:.1%} vs dnn "
                    f"{res.error_rate:.1%}"),
        "detail": {"per_level_p95_ms": {str(k): v for k, v in lvl_p95.items()},
                   "dnn_error_rate": res.error_rate,
                   "static_error_rate": base.error_rate,
                   "max_replicas": max_replicas,
                   "peak_under_slo": bool(peak_ok)},
    }


if __name__ == "__main__":
    r = run()
    print(r["derived"])
    for k, v in r["detail"]["per_level_p95_ms"].items():
        print(f"  {int(k):>7,} rps  p95 {v:6.1f} ms")
