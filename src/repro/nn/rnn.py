"""GRU recurrence for the performance-indicator stream (paper §3.2.1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.init import lecun_normal


class GRU:
    @staticmethod
    def init(key, in_dim: int, hidden: int, *, param_dtype=jnp.float32):
        k1, k2, k3 = jax.random.split(key, 3)
        init = lecun_normal(in_axis=0)
        return {
            "wi": init(k1, (in_dim, 3 * hidden), param_dtype),   # input → r,z,n
            "wh": init(k2, (hidden, 3 * hidden), param_dtype),   # hidden → r,z,n
            "b": jnp.zeros((3 * hidden,), param_dtype),
        }

    @staticmethod
    def cell(params, h, x):
        hidden = h.shape[-1]
        gi = x @ params["wi"] + params["b"]
        gh = h @ params["wh"]
        i_r, i_z, i_n = jnp.split(gi, 3, axis=-1)
        h_r, h_z, h_n = jnp.split(gh, 3, axis=-1)
        r = jax.nn.sigmoid(i_r + h_r)
        z = jax.nn.sigmoid(i_z + h_z)
        n = jnp.tanh(i_n + r * h_n)
        del hidden
        return (1.0 - z) * n + z * h

    @staticmethod
    def apply(params, xs, h0=None):
        """xs: (batch, time, in_dim) → (hidden_final, all_hidden (B,T,H))."""
        batch = xs.shape[0]
        hidden = params["wh"].shape[0]
        if h0 is None:
            h0 = jnp.zeros((batch, hidden), xs.dtype)

        def step(h, x_t):
            h = GRU.cell(params, h, x_t)
            return h, h

        h_final, hs = jax.lax.scan(step, h0, jnp.swapaxes(xs, 0, 1))
        return h_final, jnp.swapaxes(hs, 0, 1)
