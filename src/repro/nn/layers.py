"""Core functional layers.

Each layer is a namespace of pure functions:
  ``Layer.init(key, **dims) -> params``  and  ``Layer.apply(params, x) -> y``.
Params are plain dicts so they compose into model pytrees and shard with
jax.sharding.NamedSharding via the partition rules in repro.sharding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.init import lecun_normal, normal_init, ones_init, zeros_init


class Linear:
    @staticmethod
    def init(key, in_dim: int, out_dim: int, *, use_bias: bool = True,
             param_dtype=jnp.float32, w_init=None):
        w_init = w_init or lecun_normal(in_axis=0)
        kw, kb = jax.random.split(key)
        params = {"w": w_init(kw, (in_dim, out_dim), param_dtype)}
        if use_bias:
            params["b"] = zeros_init()(kb, (out_dim,), param_dtype)
        return params

    @staticmethod
    def apply(params, x, *, dtype=None):
        w = params["w"]
        if dtype is not None:
            w = w.astype(dtype)
            x = x.astype(dtype)
        y = x @ w
        if "b" in params:
            b = params["b"].astype(y.dtype)
            y = y + b
        return y


class Embedding:
    @staticmethod
    def init(key, vocab: int, dim: int, *, param_dtype=jnp.float32, scale: float = 1.0):
        return {"table": normal_init(0.02 * scale)(key, (vocab, dim), param_dtype)}

    @staticmethod
    def apply(params, ids, *, dtype=None):
        table = params["table"]
        if dtype is not None:
            table = table.astype(dtype)
        return jnp.take(table, ids, axis=0)

    @staticmethod
    def attend(params, x):
        """Tied readout: logits = x @ table.T (fp32 accumulation)."""
        table = params["table"]
        return jnp.einsum("...d,vd->...v", x, table,
                          preferred_element_type=jnp.float32)


class RMSNorm:
    @staticmethod
    def init(key, dim: int, *, param_dtype=jnp.float32):
        return {"scale": ones_init()(key, (dim,), param_dtype)}

    @staticmethod
    def apply(params, x, *, eps: float = 1e-6):
        orig_dtype = x.dtype
        x = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(var + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(orig_dtype)


class LayerNorm:
    @staticmethod
    def init(key, dim: int, *, param_dtype=jnp.float32):
        return {
            "scale": jnp.ones((dim,), param_dtype),
            "bias": jnp.zeros((dim,), param_dtype),
        }

    @staticmethod
    def apply(params, x, *, eps: float = 1e-5):
        orig_dtype = x.dtype
        x = x.astype(jnp.float32)
        mean = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        y = (x - mean) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
        return y.astype(orig_dtype)


class BatchNorm:
    """Batch norm with externally threaded running stats (used by the MLOps
    DNN's deployment-parameter stream, per paper §3.2.1)."""

    @staticmethod
    def init(key, dim: int, *, param_dtype=jnp.float32):
        del key
        return {
            "scale": jnp.ones((dim,), param_dtype),
            "bias": jnp.zeros((dim,), param_dtype),
        }

    @staticmethod
    def init_state(dim: int):
        return {"mean": jnp.zeros((dim,), jnp.float32),
                "var": jnp.ones((dim,), jnp.float32),
                "count": jnp.zeros((), jnp.float32)}

    @staticmethod
    def apply(params, state, x, *, training: bool, momentum: float = 0.9,
              eps: float = 1e-5):
        if training:
            mean = jnp.mean(x, axis=tuple(range(x.ndim - 1)))
            var = jnp.var(x, axis=tuple(range(x.ndim - 1)))
            new_state = {
                "mean": momentum * state["mean"] + (1 - momentum) * mean,
                "var": momentum * state["var"] + (1 - momentum) * var,
                "count": state["count"] + 1.0,
            }
        else:
            mean, var = state["mean"], state["var"]
            new_state = state
        y = (x - mean) * jax.lax.rsqrt(var + eps)
        return y * params["scale"] + params["bias"], new_state


class Conv1D:
    """NLC 1-D convolution (used by the resource-metrics stream and by the
    Mamba short conv). ``causal=True`` left-pads so output length == input."""

    @staticmethod
    def init(key, in_ch: int, out_ch: int, kernel: int, *, use_bias: bool = True,
             param_dtype=jnp.float32, groups: int = 1):
        kw, kb = jax.random.split(key)
        fan_in = in_ch // groups * kernel
        std = (1.0 / max(fan_in, 1)) ** 0.5
        params = {"w": (std * jax.random.normal(kw, (kernel, in_ch // groups, out_ch))
                        ).astype(param_dtype)}
        if use_bias:
            params["b"] = jnp.zeros((out_ch,), param_dtype)
        return params

    @staticmethod
    def apply(params, x, *, stride: int = 1, causal: bool = False,
              groups: int = 1, dtype=None):
        w = params["w"]
        if dtype is not None:
            w = w.astype(dtype)
            x = x.astype(dtype)
        k = w.shape[0]
        padding = [(k - 1, 0)] if causal else "SAME"
        y = jax.lax.conv_general_dilated(
            x, w,
            window_strides=(stride,),
            padding=padding,
            dimension_numbers=("NLC", "LIO", "NLC"),
            feature_group_count=groups,
        )
        if "b" in params:
            y = y + params["b"].astype(y.dtype)
        return y


class MLP:
    """Plain dense stack with activation, used by the control-plane DNN."""

    @staticmethod
    def init(key, dims, *, use_bias: bool = True, param_dtype=jnp.float32):
        layers = []
        keys = jax.random.split(key, len(dims) - 1)
        for i, k in enumerate(keys):
            layers.append(Linear.init(k, dims[i], dims[i + 1], use_bias=use_bias,
                                      param_dtype=param_dtype))
        return {"layers": layers}

    @staticmethod
    def apply(params, x, *, act=jax.nn.relu, final_act=None):
        n = len(params["layers"])
        for i, layer in enumerate(params["layers"]):
            x = Linear.apply(layer, x)
            if i < n - 1:
                x = act(x)
            elif final_act is not None:
                x = final_act(x)
        return x
