"""Minimal functional NN substrate (flax is unavailable in this environment).

Conventions:
  * params are nested dicts (pytrees) of jnp arrays;
  * every layer exposes ``init(key, ...) -> params`` and a pure ``apply``;
  * dtype policy: params kept in ``param_dtype``, compute in ``dtype``.
"""
from repro.nn.init import (
    lecun_normal,
    normal_init,
    truncated_normal,
    zeros_init,
    ones_init,
)
from repro.nn.layers import (
    Linear,
    Embedding,
    RMSNorm,
    LayerNorm,
    BatchNorm,
    Conv1D,
    MLP,
)
from repro.nn.rnn import GRU

__all__ = [
    "lecun_normal",
    "normal_init",
    "truncated_normal",
    "zeros_init",
    "ones_init",
    "Linear",
    "Embedding",
    "RMSNorm",
    "LayerNorm",
    "BatchNorm",
    "Conv1D",
    "MLP",
    "GRU",
]
