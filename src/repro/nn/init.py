"""Parameter initializers (callable(key, shape, dtype) -> array)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def normal_init(stddev: float = 0.02):
    def init(key, shape, dtype=jnp.float32):
        return (stddev * jax.random.normal(key, shape)).astype(dtype)

    return init


def truncated_normal(stddev: float = 0.02, lower: float = -2.0, upper: float = 2.0):
    def init(key, shape, dtype=jnp.float32):
        u = jax.random.truncated_normal(key, lower, upper, shape)
        return (stddev * u).astype(dtype)

    return init


def lecun_normal(in_axis: int = 0):
    """Fan-in scaled normal — the default for projection weights."""

    def init(key, shape, dtype=jnp.float32):
        fan_in = shape[in_axis]
        std = (1.0 / max(fan_in, 1)) ** 0.5
        u = jax.random.truncated_normal(key, -2.0, 2.0, shape)
        # correct the truncated normal's variance shrinkage (~0.87962)
        return (std / 0.87962566103423978 * u).astype(dtype)

    return init


def zeros_init():
    def init(key, shape, dtype=jnp.float32):
        del key
        return jnp.zeros(shape, dtype)

    return init


def ones_init():
    def init(key, shape, dtype=jnp.float32):
        del key
        return jnp.ones(shape, dtype)

    return init
