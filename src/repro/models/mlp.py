"""SwiGLU / GeGLU feed-forward blocks."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import Linear
from repro.sharding import constrain


class SwiGLU:
    @staticmethod
    def init(key, d_model: int, d_ff: int, *, param_dtype=jnp.float32,
             d_out: int | None = None):
        kg, ku, kd = jax.random.split(key, 3)
        d_out = d_out or d_model
        params = {
            "gate": Linear.init(kg, d_model, d_ff, use_bias=False, param_dtype=param_dtype),
            "up": Linear.init(ku, d_model, d_ff, use_bias=False, param_dtype=param_dtype),
            "down": Linear.init(kd, d_ff, d_out, use_bias=False, param_dtype=param_dtype),
        }
        axes = {
            "gate": {"w": ("embed", "ff")},
            "up": {"w": ("embed", "ff")},
            "down": {"w": ("ff", "embed")},
        }
        return params, axes

    @staticmethod
    def apply(params, x, *, dtype=None, act=jax.nn.silu):
        g = Linear.apply(params["gate"], x, dtype=dtype)
        u = Linear.apply(params["up"], x, dtype=dtype)
        h = act(g) * u
        h = constrain(h, ("batch", None, "ff"))
        y = Linear.apply(params["down"], h, dtype=dtype)
        return constrain(y, ("batch", None, "embed_act"))
