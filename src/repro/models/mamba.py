"""Mamba1 (falcon-mamba) and Mamba2/SSD (zamba2) state-space blocks.

Full-sequence paths run a jax.lax.scan over time with a small carried state —
this is the memory-sane lowering used by the CPU dry-run (HLO stays compact;
the scan body is counted once by cost_analysis, an ≤5% FLOP undercount vs the
projection matmuls that is corrected analytically in the roofline harness —
see EXPERIMENTS.md §Roofline).  The TPU performance path is the chunked SSD
Pallas kernel (kernels/ssm_scan.py), selected with cfg.use_pallas.

Decode paths are single-step recurrences over (ssm_state, conv_state) — O(1)
in sequence length, which is what makes long_500k runnable for the SSM and
hybrid archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import Linear, RMSNorm, Conv1D
from repro.sharding import constrain
from repro.models.config import ModelConfig


def _softplus(x):
    return jax.nn.softplus(x)


# ---------------------------------------------------------------------------
# Mamba1 (selective scan; per-(channel, state) decay)
# ---------------------------------------------------------------------------

class Mamba1:
    @staticmethod
    def init(key, cfg: ModelConfig):
        di, N, R = cfg.d_inner, cfg.ssm.d_state, cfg.dt_rank
        k = cfg.ssm.d_conv
        pd = cfg.pdtype
        keys = jax.random.split(key, 6)
        params = {
            "in_proj": Linear.init(keys[0], cfg.d_model, 2 * di, use_bias=False,
                                   param_dtype=pd),
            "conv": Conv1D.init(keys[1], di, di, k, param_dtype=pd, groups=di),
            "x_proj": Linear.init(keys[2], di, R + 2 * N, use_bias=False,
                                  param_dtype=pd),
            "dt_proj": Linear.init(keys[3], R, di, use_bias=True, param_dtype=pd),
            "A_log": jnp.log(jnp.broadcast_to(
                jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))).astype(pd),
            "D": jnp.ones((di,), pd),
            "out_proj": Linear.init(keys[4], di, cfg.d_model, use_bias=False,
                                    param_dtype=pd),
        }
        axes = {
            "in_proj": {"w": ("embed", "d_inner")},
            "conv": {"w": (None, None, "d_inner"), "b": ("d_inner",)},
            "x_proj": {"w": ("d_inner", None)},
            "dt_proj": {"w": (None, "d_inner"), "b": ("d_inner",)},
            "A_log": ("d_inner", "d_state"),
            "D": ("d_inner",),
            "out_proj": {"w": ("d_inner", "embed")},
        }
        return params, axes

    @staticmethod
    def _dbc(params, x_conv, cfg):
        """x_conv: (..., di) → dt (..., di) fp32, B/C (..., N) fp32."""
        N, R = cfg.ssm.d_state, cfg.dt_rank
        dbc = Linear.apply(params["x_proj"], x_conv, dtype=cfg.cdtype)
        dt_r, Bc, Cc = jnp.split(dbc.astype(jnp.float32), [R, R + N], axis=-1)
        dt = _softplus(Linear.apply(params["dt_proj"], dt_r))
        return dt.astype(jnp.float32), Bc, Cc

    @staticmethod
    def apply(params, x, cfg: ModelConfig):
        """x: (B, L, d) → y: (B, L, d)."""
        Bsz, L, _ = x.shape
        di, N = cfg.d_inner, cfg.ssm.d_state
        xz = Linear.apply(params["in_proj"], x, dtype=cfg.cdtype)
        x_in, z = jnp.split(xz, 2, axis=-1)
        x_in = constrain(x_in, ("batch", None, "d_inner"))
        x_conv = jax.nn.silu(Conv1D.apply(params["conv"], x_in, causal=True,
                                          groups=di, dtype=cfg.cdtype))
        dt, Bc, Cc = Mamba1._dbc(params, x_conv, cfg)
        A = -jnp.exp(params["A_log"].astype(jnp.float32))            # (di, N)
        xf = x_conv.astype(jnp.float32)

        def step(h, inp):
            dt_t, x_t, B_t, C_t = inp                                # (B,di),(B,di),(B,N),(B,N)
            decay = jnp.exp(dt_t[..., None] * A[None])               # (B, di, N)
            h = decay * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
            y = jnp.einsum("bdn,bn->bd", h, C_t)
            return h, y

        h0 = jnp.zeros((Bsz, di, N), jnp.float32)
        xs = (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(xf, 1, 0),
              jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
        _, ys = jax.lax.scan(step, h0, xs)
        y = jnp.moveaxis(ys, 0, 1) + xf * params["D"].astype(jnp.float32)[None, None]
        y = (y.astype(cfg.cdtype)) * jax.nn.silu(z)
        y = constrain(y, ("batch", None, "d_inner"))
        out = Linear.apply(params["out_proj"], y, dtype=cfg.cdtype)
        return constrain(out, ("batch", None, "embed_act"))

    @staticmethod
    def decode(params, x, cfg: ModelConfig, state):
        """x: (B, 1, d); state = {"h": (B, di, N) fp32,
        "conv": (B, d_conv-1, di)} → (y, new_state)."""
        di, N = cfg.d_inner, cfg.ssm.d_state
        xz = Linear.apply(params["in_proj"], x, dtype=cfg.cdtype)
        x_in, z = jnp.split(xz, 2, axis=-1)                          # (B,1,di)
        window = jnp.concatenate([state["conv"], x_in], axis=1)      # (B,k,di)
        w = params["conv"]["w"].astype(x_in.dtype)                   # (k,1,di)
        xc = jnp.sum(window * jnp.moveaxis(w, 1, 0), axis=1, keepdims=True)
        if "b" in params["conv"]:
            xc = xc + params["conv"]["b"].astype(xc.dtype)
        x_conv = jax.nn.silu(xc)                                     # (B,1,di)
        dt, Bc, Cc = Mamba1._dbc(params, x_conv, cfg)
        A = -jnp.exp(params["A_log"].astype(jnp.float32))
        dt_t, x_t = dt[:, 0], x_conv[:, 0].astype(jnp.float32)
        decay = jnp.exp(dt_t[..., None] * A[None])
        h = decay * state["h"] + (dt_t * x_t)[..., None] * Bc[:, 0][:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0])
        y = y + x_t * params["D"].astype(jnp.float32)[None]
        y = (y[:, None].astype(cfg.cdtype)) * jax.nn.silu(z)
        out = Linear.apply(params["out_proj"], y, dtype=cfg.cdtype)
        new_state = {"h": h, "conv": window[:, 1:]}
        return out, new_state

    @staticmethod
    def state_shape(cfg: ModelConfig, batch: int):
        di, N, k = cfg.d_inner, cfg.ssm.d_state, cfg.ssm.d_conv
        return {
            "h": ((batch, di, N), jnp.float32, ("batch", "d_inner", None)),
            "conv": ((batch, k - 1, di), cfg.cdtype, ("batch", None, "d_inner")),
        }


# ---------------------------------------------------------------------------
# Mamba2 / SSD (scalar per-head decay; MXU-friendly chunked form in the kernel)
# ---------------------------------------------------------------------------

class Mamba2:
    @staticmethod
    def init(key, cfg: ModelConfig):
        di, N = cfg.d_inner, cfg.ssm.d_state
        H, G = cfg.ssm_heads, cfg.ssm.n_groups
        k = cfg.ssm.d_conv
        conv_ch = di + 2 * G * N
        pd = cfg.pdtype
        keys = jax.random.split(key, 4)
        d_in_proj = 2 * di + 2 * G * N + H
        params = {
            "in_proj": Linear.init(keys[0], cfg.d_model, d_in_proj,
                                   use_bias=False, param_dtype=pd),
            "conv": Conv1D.init(keys[1], conv_ch, conv_ch, k, param_dtype=pd,
                                groups=conv_ch),
            "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(pd),
            "dt_bias": jnp.zeros((H,), pd),
            "D": jnp.ones((H,), pd),
            "norm": RMSNorm.init(keys[2], di, param_dtype=pd),
            "out_proj": Linear.init(keys[3], di, cfg.d_model, use_bias=False,
                                    param_dtype=pd),
        }
        axes = {
            "in_proj": {"w": ("embed", "d_inner")},
            "conv": {"w": (None, None, "d_inner"), "b": ("d_inner",)},
            "A_log": (None,),
            "dt_bias": (None,),
            "D": (None,),
            "norm": {"scale": ("d_inner",)},
            "out_proj": {"w": ("d_inner", "embed")},
        }
        return params, axes

    @staticmethod
    def _split(cfg, zxbcdt):
        di, N = cfg.d_inner, cfg.ssm.d_state
        G, H = cfg.ssm.n_groups, cfg.ssm_heads
        z, x, Bc, Cc, dt = jnp.split(
            zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1)
        return z, x, Bc, Cc, dt

    @staticmethod
    def apply(params, x, cfg: ModelConfig):
        """x: (B, L, d) → (B, L, d) (includes out_proj — full block inner)."""
        Bsz, L, _ = x.shape
        di, N = cfg.d_inner, cfg.ssm.d_state
        G, H, hd = cfg.ssm.n_groups, cfg.ssm_heads, cfg.ssm.headdim
        zxbcdt = Linear.apply(params["in_proj"], x, dtype=cfg.cdtype)
        z, xs_, Bc, Cc, dt = Mamba2._split(cfg, zxbcdt)
        conv_in = jnp.concatenate([xs_, Bc, Cc], axis=-1)
        conv_in = constrain(conv_in, ("batch", None, "d_inner"))
        conv_out = jax.nn.silu(Conv1D.apply(params["conv"], conv_in, causal=True,
                                            groups=conv_in.shape[-1],
                                            dtype=cfg.cdtype))
        xs_, Bc, Cc = jnp.split(conv_out, [di, di + G * N], axis=-1)
        dt = _softplus(dt.astype(jnp.float32) +
                       params["dt_bias"].astype(jnp.float32))        # (B,L,H)
        A = -jnp.exp(params["A_log"].astype(jnp.float32))            # (H,)
        xh = xs_.reshape(Bsz, L, H, hd).astype(jnp.float32)
        Bg = Bc.reshape(Bsz, L, G, N).astype(jnp.float32)
        Cg = Cc.reshape(Bsz, L, G, N).astype(jnp.float32)
        rep = H // G
        Bh = jnp.repeat(Bg, rep, axis=2)                             # (B,L,H,N)
        Ch = jnp.repeat(Cg, rep, axis=2)

        if cfg.use_pallas:
            from repro.kernels import ops as kops
            y = kops.ssm_scan(xh, dt, A, Bh, Ch, chunk=cfg.ssm.chunk)
        else:
            def step(h, inp):
                x_t, dt_t, B_t, C_t = inp                            # (B,H,hd),(B,H),(B,H,N),(B,H,N)
                a = jnp.exp(dt_t * A[None])                          # (B,H)
                h = a[..., None, None] * h + \
                    (dt_t[..., None] * x_t)[..., None] * B_t[:, :, None, :]
                y_t = jnp.einsum("bhdn,bhn->bhd", h, C_t)
                return h, y_t

            h0 = jnp.zeros((Bsz, H, hd, N), jnp.float32)
            xs_t = (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(dt, 1, 0),
                    jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
            _, ys = jax.lax.scan(step, h0, xs_t)
            y = jnp.moveaxis(ys, 0, 1)                               # (B,L,H,hd)

        y = y + xh * params["D"].astype(jnp.float32)[None, None, :, None]
        y = y.reshape(Bsz, L, di).astype(cfg.cdtype)
        y = RMSNorm.apply(params["norm"], y * jax.nn.silu(z))
        y = constrain(y, ("batch", None, "d_inner"))
        out = Linear.apply(params["out_proj"], y, dtype=cfg.cdtype)
        return constrain(out, ("batch", None, "embed_act"))

    @staticmethod
    def decode(params, x, cfg: ModelConfig, state):
        """x: (B, 1, d); state = {"h": (B,H,hd,N) fp32, "conv": (B,k-1,conv_ch)}."""
        Bsz = x.shape[0]
        di, N = cfg.d_inner, cfg.ssm.d_state
        G, H, hd = cfg.ssm.n_groups, cfg.ssm_heads, cfg.ssm.headdim
        zxbcdt = Linear.apply(params["in_proj"], x, dtype=cfg.cdtype)
        z, xs_, Bc, Cc, dt = Mamba2._split(cfg, zxbcdt)
        conv_in = jnp.concatenate([xs_, Bc, Cc], axis=-1)            # (B,1,ch)
        window = jnp.concatenate([state["conv"], conv_in], axis=1)   # (B,k,ch)
        w = params["conv"]["w"].astype(conv_in.dtype)                # (k,1,ch)
        co = jnp.sum(window * jnp.moveaxis(w, 1, 0), axis=1, keepdims=True)
        if "b" in params["conv"]:
            co = co + params["conv"]["b"].astype(co.dtype)
        conv_out = jax.nn.silu(co)
        xs_, Bc, Cc = jnp.split(conv_out, [di, di + G * N], axis=-1)
        dt = _softplus(dt.astype(jnp.float32) +
                       params["dt_bias"].astype(jnp.float32))[:, 0]  # (B,H)
        A = -jnp.exp(params["A_log"].astype(jnp.float32))
        x_t = xs_[:, 0].reshape(Bsz, H, hd).astype(jnp.float32)
        B_t = jnp.repeat(Bc[:, 0].reshape(Bsz, G, N), H // G, axis=1).astype(jnp.float32)
        C_t = jnp.repeat(Cc[:, 0].reshape(Bsz, G, N), H // G, axis=1).astype(jnp.float32)
        a = jnp.exp(dt * A[None])
        h = a[..., None, None] * state["h"] + \
            (dt[..., None] * x_t)[..., None] * B_t[:, :, None, :]
        y = jnp.einsum("bhdn,bhn->bhd", h, C_t)
        y = y + x_t * params["D"].astype(jnp.float32)[None, :, None]
        y = y.reshape(Bsz, 1, di).astype(cfg.cdtype)
        y = RMSNorm.apply(params["norm"], y * jax.nn.silu(z))
        out = Linear.apply(params["out_proj"], y, dtype=cfg.cdtype)
        return out, {"h": h, "conv": window[:, 1:]}

    @staticmethod
    def state_shape(cfg: ModelConfig, batch: int):
        di, N, k = cfg.d_inner, cfg.ssm.d_state, cfg.ssm.d_conv
        H, hd, G = cfg.ssm_heads, cfg.ssm.headdim, cfg.ssm.n_groups
        conv_ch = di + 2 * G * N
        return {
            "h": ((batch, H, hd, N), jnp.float32, ("batch", None, None, None)),
            "conv": ((batch, k - 1, conv_ch), cfg.cdtype, ("batch", None, "d_inner")),
        }
