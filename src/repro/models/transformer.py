"""Full language-model assembly for every assigned architecture family.

Parameters for the repeated blocks are stacked on a leading "layers" axis and
applied with jax.lax.scan (policy-controlled remat), so the 80-layer Qwen2-72B
config lowers and compiles in seconds with a compact HLO.  cfg.use_scan=False
switches to a python loop over the same stacked params — used by the roofline
cost-probe, which compiles 2- and 4-layer unrolled variants to recover
per-layer HLO FLOPs that scan bodies hide (see launch/dryrun.py).

Entry points (all pure):
  LM.init(key, cfg)                        -> (params, axes)
  LM.apply(params, inputs, cfg)            -> (logits, aux)   # train / full fwd
  LM.prefill(params, inputs, cfg, max_seq) -> (logits_last, cache)
  LM.decode(params, tokens, cfg, cache)    -> (logits, cache) # one token
  LM.cache_spec(cfg, batch, max_seq)       -> pytree of (shape, dtype, axes)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn import Embedding, LayerNorm, Linear, RMSNorm
from repro.sharding import constrain
from repro.models.attention import Attention
from repro.models.blocks import (
    CrossDecoderBlock,
    DecoderBlock,
    EncoderBlock,
    SSMBlock,
    SharedAttnBlock,
)
from repro.models.config import ModelConfig
from repro.models.rotary import mrope_positions, rope_angles, text_positions

ZERO_AUX = lambda: {"lb_loss": jnp.zeros((), jnp.float32),
                    "z_loss": jnp.zeros((), jnp.float32),
                    "drop_frac": jnp.zeros((), jnp.float32)}


def _aux_of(aux):
    out = ZERO_AUX()
    if aux:
        for k in out:
            if k in aux:
                out[k] = aux[k].astype(jnp.float32)
    return out


def _stack_init(block_init, key, n: int, cfg):
    """vmap a block init over n layer keys; returns (stacked params, axes with
    a leading 'layers' dim)."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: block_init(k, cfg)[0])(keys)
    _, axes = block_init(keys[0], cfg)
    axes = _prefix_axes(axes, "layers")
    return params, axes


def _prefix_axes(axes, name: str):
    def is_leaf(x):
        return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)
    return jax.tree.map(lambda ax: (name,) + ax, axes, is_leaf=is_leaf)


def _angles(cfg: ModelConfig, batch: int, seq: int, start=0):
    if cfg.ssm is not None and cfg.hybrid is None:
        return None
    if cfg.m_rope:
        pos = mrope_positions(batch, seq, cfg.n_vision_patches if seq > 1 else 0,
                              start)
        return rope_angles(pos, cfg.hd, cfg.rope_theta, cfg.m_rope_sections)
    pos = text_positions(batch, seq, start)
    return rope_angles(pos, cfg.hd, cfg.rope_theta)


def _hybrid_groups(cfg: ModelConfig) -> int:
    assert cfg.hybrid is not None
    return cfg.n_layers // cfg.hybrid.attn_every


def _index_tree(tree, i):
    return jax.tree.map(lambda p: p[i], tree)


class LM:
    # ------------------------------------------------------------- init

    @staticmethod
    def init(key, cfg: ModelConfig):
        keys = jax.random.split(key, 8)
        params: dict[str, Any] = {}
        axes: dict[str, Any] = {}

        params["embed"] = Embedding.init(keys[0], cfg.vocab, cfg.d_model,
                                         param_dtype=cfg.pdtype)
        axes["embed"] = {"table": ("vocab", "embed")}

        if cfg.enc_dec:
            params["enc_blocks"], axes["enc_blocks"] = _stack_init(
                EncoderBlock.init, keys[1], cfg.n_enc_layers, cfg)
            params["dec_blocks"], axes["dec_blocks"] = _stack_init(
                CrossDecoderBlock.init, keys[2], cfg.n_layers, cfg)
            params["ln_enc"] = LayerNorm.init(keys[3], cfg.d_model,
                                              param_dtype=cfg.pdtype)
            axes["ln_enc"] = jax.tree.map(lambda _: ("embed_act",), params["ln_enc"])
        elif cfg.hybrid is not None:
            G, A = _hybrid_groups(cfg), cfg.hybrid.attn_every
            mp, max_ = _stack_init(SSMBlock.init, keys[1], cfg.n_layers, cfg)
            # reshape stacked (L, ...) → (G, A, ...)
            params["blocks"] = jax.tree.map(
                lambda p: p.reshape((G, A) + p.shape[1:]), mp)
            axes["blocks"] = _prefix_axes(max_, "layers")  # (layers, layers, ...)
            sp, sax = _stack_init(SharedAttnBlock.init, keys[2],
                                  cfg.hybrid.n_shared_blocks, cfg)
            params["shared"], axes["shared"] = sp, sax
            kd = jax.random.split(keys[3], G)
            params["down"] = jax.vmap(
                lambda k: Linear.init(k, 2 * cfg.d_model, cfg.d_model,
                                      use_bias=False, param_dtype=cfg.pdtype))(kd)
            axes["down"] = {"w": ("layers", "embed", "embed")}
        elif cfg.ssm is not None:
            params["blocks"], axes["blocks"] = _stack_init(
                SSMBlock.init, keys[1], cfg.n_layers, cfg)
        else:
            params["blocks"], axes["blocks"] = _stack_init(
                DecoderBlock.init, keys[1], cfg.n_layers, cfg)

        norm = LayerNorm if cfg.family == "audio" else RMSNorm
        params["ln_f"] = norm.init(keys[4], cfg.d_model, param_dtype=cfg.pdtype)
        axes["ln_f"] = jax.tree.map(lambda _: ("embed_act",), params["ln_f"])

        if not cfg.tie_embeddings:
            params["lm_head"] = Linear.init(keys[5], cfg.d_model, cfg.vocab,
                                            use_bias=False, param_dtype=cfg.pdtype)
            axes["lm_head"] = {"w": ("embed", "vocab")}
        return params, axes

    # ------------------------------------------------------------- shared bits

    @staticmethod
    def _embed(params, tokens, cfg, inputs=None):
        h = Embedding.apply(params["embed"], tokens, dtype=cfg.cdtype)
        if cfg.family == "vlm" and inputs is not None and "patches" in inputs:
            P = inputs["patches"].shape[1]
            h = jnp.concatenate(
                [inputs["patches"].astype(cfg.cdtype), h[:, P:]], axis=1)
        return constrain(h, ("batch", None, "embed_act"))

    @staticmethod
    def _logits(params, h, cfg):
        if cfg.tie_embeddings:
            logits = Embedding.attend(params["embed"], h)
        else:
            w = params["lm_head"]["w"]
            logits = jnp.einsum("...d,dv->...v", h, w,
                                preferred_element_type=jnp.float32)
        return constrain(logits, ("batch", None, "vocab"))

    @staticmethod
    def _scan_blocks(block_apply, blocks, x, cfg, extra=None):
        """Scan (or unrolled loop) over stacked layer params.  ``block_apply``
        maps (layer_params, x) -> (x, aux_dict)."""
        def body(carry, layer_params):
            y, aux = block_apply(layer_params, carry)
            return y, _aux_of(aux)

        if cfg.remat != "none":
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.use_scan:
            x, auxs = jax.lax.scan(body, x, blocks)
            aux = jax.tree.map(jnp.sum, auxs)
        else:
            n = jax.tree.leaves(blocks)[0].shape[0]
            aux = ZERO_AUX()
            for i in range(n):
                x, a = body(x, _index_tree(blocks, i))
                aux = jax.tree.map(lambda u, v: u + v, aux, a)
        return x, aux

    # ------------------------------------------------------------- forward

    @staticmethod
    def apply(params, inputs, cfg: ModelConfig, *, return_hidden: bool = False):
        """Full-sequence forward.  inputs: {"tokens": (B, S)} plus
        family extras ("patches" for vlm, "frames" for audio).
        return_hidden=True returns the final-norm hidden states instead of
        logits — the chunked-CE train path computes per-chunk logits itself
        so the (B, S, V) fp32 tensor never materializes."""
        if cfg.enc_dec:
            return LM._apply_encdec(params, inputs, cfg,
                                    return_hidden=return_hidden)
        tokens = inputs["tokens"]
        B, S = tokens.shape
        h = LM._embed(params, tokens, cfg, inputs)
        angles = _angles(cfg, B, S)

        if cfg.hybrid is not None:
            h, aux = LM._apply_hybrid(params, h, cfg, angles)
        elif cfg.ssm is not None:
            h, aux = LM._scan_blocks(
                lambda p, x: SSMBlock.apply(p, x, cfg), params["blocks"], h, cfg)
        else:
            h, aux = LM._scan_blocks(
                lambda p, x: DecoderBlock.apply(p, x, cfg, angles=angles),
                params["blocks"], h, cfg)

        norm = LayerNorm if cfg.family == "audio" else RMSNorm
        h = norm.apply(params["ln_f"], h, eps=cfg.norm_eps)
        if return_hidden:
            return h, aux
        return LM._logits(params, h, cfg), aux

    @staticmethod
    def _apply_hybrid(params, h, cfg, angles):
        """Zamba2: groups of attn_every SSM layers, each followed by the
        shared attention block over concat(h, emb0) + per-group down-proj."""
        emb0 = h
        A = cfg.hybrid.attn_every
        n_shared = cfg.hybrid.n_shared_blocks
        shared = params["shared"]

        def group_body(carry, xs):
            x, g = carry
            mamba_g, down_g = xs
            for i in range(A):
                x, _ = SSMBlock.apply(_index_tree(mamba_g, i), x, cfg)
            sel = _index_tree(shared, jax.lax.rem(g, n_shared))
            x2 = jnp.concatenate([x, emb0], axis=-1)
            x2 = SharedAttnBlock.apply(sel, x2, cfg, angles=angles)
            x = x + Linear.apply(down_g, x2, dtype=cfg.cdtype)
            return (x, g + 1), ZERO_AUX()

        if cfg.remat != "none":
            group_body = jax.checkpoint(
                group_body, policy=jax.checkpoint_policies.nothing_saveable)
        G = _hybrid_groups(cfg)
        if cfg.use_scan:
            (h, _), auxs = jax.lax.scan(
                group_body, (h, jnp.zeros((), jnp.int32)),
                (params["blocks"], params["down"]))
            aux = jax.tree.map(jnp.sum, auxs)
        else:
            carry = (h, jnp.zeros((), jnp.int32))
            aux = ZERO_AUX()
            for gi in range(G):
                carry, a = group_body(
                    carry, (_index_tree(params["blocks"], gi),
                            _index_tree(params["down"], gi)))
            h = carry[0]
        return h, aux

    @staticmethod
    def _apply_encdec(params, inputs, cfg, *, return_hidden: bool = False):
        frames, tokens = inputs["frames"], inputs["tokens"]
        B, Se = frames.shape[:2]
        Sd = tokens.shape[1]
        enc_ang = _angles(cfg, B, Se)
        x = constrain(frames.astype(cfg.cdtype), ("batch", None, "embed_act"))
        x, _ = LM._scan_blocks(
            lambda p, h: (EncoderBlock.apply(p, h, cfg, angles=enc_ang), None),
            params["enc_blocks"], x, cfg)
        enc_out = LayerNorm.apply(params["ln_enc"], x, eps=cfg.norm_eps)

        dec_ang = _angles(cfg, B, Sd)
        h = LM._embed(params, tokens, cfg)
        h, aux = LM._scan_blocks(
            lambda p, x_: (CrossDecoderBlock.apply(p, x_, cfg, enc_out=enc_out,
                                                   angles=dec_ang), None),
            params["dec_blocks"], h, cfg)
        h = LayerNorm.apply(params["ln_f"], h, eps=cfg.norm_eps)
        if return_hidden:
            return h, aux
        return LM._logits(params, h, cfg), aux

    # ------------------------------------------------------------- cache

    @staticmethod
    def cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
        """Pytree of (shape, dtype, logical_axes) describing the decode state."""
        L = cfg.n_layers
        spec: dict[str, Any] = {"index": ((), jnp.int32, ())}
        if cfg.enc_dec:
            kv = Attention.cache_shape(cfg, batch, max_seq)
            spec["self"] = {
                n: ((L,) + s, cfg.cdtype, ("layers",) + ax)
                for n, (s, ax) in kv.items()}
            ce_shape = (L, batch, max_seq, cfg.n_kv_heads, cfg.hd)
            ce_ax = ("layers", "batch", "enc_seq", "kv_heads", None)
            spec["cross"] = {"k": (ce_shape, cfg.cdtype, ce_ax),
                             "v": (ce_shape, cfg.cdtype, ce_ax)}
            # per-row encoder length: cross K/V past it are masked at decode
            spec["cross_len"] = ((batch,), jnp.int32, ("batch",))
        elif cfg.hybrid is not None:
            G, A = _hybrid_groups(cfg), cfg.hybrid.attn_every
            ss = SSMBlock.state_shape(cfg, batch)
            spec["mamba"] = {n: ((G, A) + s, dt, ("layers", "layers") + ax)
                             for n, (s, dt, ax) in ss.items()}
            kv = Attention.cache_shape(cfg, batch, max_seq)
            spec["attn"] = {n: ((G,) + s, cfg.cdtype, ("layers",) + ax)
                            for n, (s, ax) in kv.items()}
        elif cfg.ssm is not None:
            ss = SSMBlock.state_shape(cfg, batch)
            spec["layers"] = {n: ((L,) + s, dt, ("layers",) + ax)
                              for n, (s, dt, ax) in ss.items()}
        else:
            kv = Attention.cache_shape(cfg, batch, max_seq)
            spec["layers"] = {n: ((L,) + s, cfg.cdtype, ("layers",) + ax)
                              for n, (s, ax) in kv.items()}
        return spec

    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
        spec = LM.cache_spec(cfg, batch, max_seq)
        return jax.tree.map(lambda s: jnp.zeros(s[0], s[1]), spec,
                            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
                            and isinstance(x[0], tuple))

    # ------------------------------------------------------------- prefill

    @staticmethod
    def prefill(params, inputs, cfg: ModelConfig, max_seq: int):
        """Forward over the prompt, building the decode cache.  Returns
        (last-position logits, cache)."""
        cache = LM.init_cache(cfg, inputs["tokens"].shape[0], max_seq)
        if cfg.enc_dec:
            return LM._prefill_encdec(params, inputs, cfg, cache, max_seq)
        tokens = inputs["tokens"]
        B, S = tokens.shape
        h = LM._embed(params, tokens, cfg, inputs)
        angles = _angles(cfg, B, S)

        if cfg.hybrid is not None:
            logits, cache = LM._prefill_hybrid(params, h, cfg, angles, cache, S, max_seq)
        elif cfg.ssm is not None:
            # full-state prefill: run layer-by-layer, capturing final states
            h, states = LM._ssm_prefill_states(params["blocks"], h, cfg)
            cache = {**cache, "layers": states}
            h = RMSNorm.apply(params["ln_f"], h, eps=cfg.norm_eps)
            logits = LM._logits(params, h[:, -1:], cfg)
        else:
            def body(x, layer_params):
                y, kv = LM._decoder_prefill_block(layer_params, x, cfg, angles, max_seq)
                return y, kv
            if cfg.use_scan:
                h, kvs = jax.lax.scan(body, h, params["blocks"])
            else:
                n = jax.tree.leaves(params["blocks"])[0].shape[0]
                kv_list = []
                for i in range(n):
                    h, kv = body(h, _index_tree(params["blocks"], i))
                    kv_list.append(kv)
                kvs = jax.tree.map(lambda *xs: jnp.stack(xs), *kv_list)
            cache = {**cache, "layers": kvs}
            norm = LayerNorm if cfg.family == "audio" else RMSNorm
            h = norm.apply(params["ln_f"], h, eps=cfg.norm_eps)
            logits = LM._logits(params, h[:, -1:], cfg)
        cache["index"] = jnp.asarray(S, jnp.int32)
        return logits, cache

    @staticmethod
    def _decoder_prefill_block(layer_params, x, cfg, angles, max_seq):
        norm = LayerNorm if cfg.family == "audio" else RMSNorm
        h = norm.apply(layer_params["ln1"], x, eps=cfg.norm_eps)
        h, (k, v) = Attention.apply(layer_params["attn"], h, cfg, angles=angles,
                                    causal=True, window=cfg.sliding_window,
                                    return_kv=True)
        x = x + h
        h = norm.apply(layer_params["ln2"], x, eps=cfg.norm_eps)
        h, _ = DecoderBlock._ffn(layer_params, h, cfg)
        return x + h, LM._kv_to_ring(k, v, cfg, max_seq)

    @staticmethod
    def _kv_to_ring(k, v, cfg, max_seq):
        """Arrange full-sequence K/V into the ring-buffer cache layout sized
        for ``max_seq`` (position p lives at slot p % W)."""
        S = k.shape[1]
        W = Attention.cache_len(cfg, max_seq)
        if W < S:
            k, v = k[:, S - W:], v[:, S - W:]
            shift = (S - W) % W
            k = jnp.roll(k, shift, axis=1)
            v = jnp.roll(v, shift, axis=1)
        elif W > S:
            pad = [(0, 0), (0, W - S), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return {"k": k, "v": v}

    @staticmethod
    def _ssm_prefill_states(blocks, h, cfg):
        """Run stacked SSM blocks, returning output and final per-layer states
        (h_state fp32, conv tail)."""
        from repro.models.mamba import Mamba1, Mamba2
        impl = Mamba1 if cfg.ssm.version == 1 else Mamba2

        def body(x, layer_params):
            hn = RMSNorm.apply(layer_params["ln"], x, eps=cfg.norm_eps)
            y, state = LM._mamba_apply_with_state(layer_params["mamba"], hn, cfg,
                                                  impl)
            return x + y, state

        if cfg.use_scan:
            h, states = jax.lax.scan(body, h, blocks)
        else:
            n = jax.tree.leaves(blocks)[0].shape[0]
            st_list = []
            for i in range(n):
                h, st = body(h, _index_tree(blocks, i))
                st_list.append(st)
            states = jax.tree.map(lambda *xs: jnp.stack(xs), *st_list)
        return h, states

    @staticmethod
    def _mamba_apply_with_state(params, x, cfg, impl):
        """Full-sequence mamba forward that also returns the final recurrent
        state — the prefill path.  Implemented by replaying the last d_conv-1
        inputs for the conv state and running the scan with a carried state."""
        # Reuse apply() for y; recover the final state by re-running the scan
        # carry on the projected sequence (cheap relative to projections).
        from repro.models.mamba import Mamba1, Mamba2
        if impl is Mamba1:
            y = Mamba1.apply(params, x, cfg)
            state = LM._mamba1_final_state(params, x, cfg)
        else:
            y = Mamba2.apply(params, x, cfg)
            state = LM._mamba2_final_state(params, x, cfg)
        return y, state

    @staticmethod
    def _mamba1_final_state(params, x, cfg):
        from repro.models.mamba import Mamba1
        from repro.nn import Conv1D
        di, N = cfg.d_inner, cfg.ssm.d_state
        xz = Linear.apply(params["in_proj"], x, dtype=cfg.cdtype)
        x_in, _ = jnp.split(xz, 2, axis=-1)
        x_conv = jax.nn.silu(Conv1D.apply(params["conv"], x_in, causal=True,
                                          groups=di, dtype=cfg.cdtype))
        dt, Bc, _ = Mamba1._dbc(params, x_conv, cfg)
        A = -jnp.exp(params["A_log"].astype(jnp.float32))
        xf = x_conv.astype(jnp.float32)

        def step(h, inp):
            dt_t, x_t, B_t = inp
            decay = jnp.exp(dt_t[..., None] * A[None])
            h = decay * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
            return h, None

        h0 = jnp.zeros((x.shape[0], di, N), jnp.float32)
        hf, _ = jax.lax.scan(step, h0, (jnp.moveaxis(dt, 1, 0),
                                        jnp.moveaxis(xf, 1, 0),
                                        jnp.moveaxis(Bc, 1, 0)))
        k = cfg.ssm.d_conv
        return {"h": hf, "conv": x_in[:, -(k - 1):, :]}

    @staticmethod
    def _mamba2_final_state(params, x, cfg):
        from repro.models.mamba import Mamba2
        from repro.nn import Conv1D
        di, N = cfg.d_inner, cfg.ssm.d_state
        G, H, hd = cfg.ssm.n_groups, cfg.ssm_heads, cfg.ssm.headdim
        zxbcdt = Linear.apply(params["in_proj"], x, dtype=cfg.cdtype)
        _, xs_, Bc, Cc, dt = Mamba2._split(cfg, zxbcdt)
        conv_in = jnp.concatenate([xs_, Bc, Cc], axis=-1)
        conv_out = jax.nn.silu(Conv1D.apply(params["conv"], conv_in, causal=True,
                                            groups=conv_in.shape[-1],
                                            dtype=cfg.cdtype))
        xs_, Bc, _ = jnp.split(conv_out, [di, di + G * N], axis=-1)
        dt = jax.nn.softplus(dt.astype(jnp.float32) +
                             params["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(params["A_log"].astype(jnp.float32))
        B_, L = x.shape[0], x.shape[1]
        xh = xs_.reshape(B_, L, H, hd).astype(jnp.float32)
        Bh = jnp.repeat(Bc.reshape(B_, L, G, N), H // G, axis=2).astype(jnp.float32)

        def step(h, inp):
            x_t, dt_t, B_t = inp
            a = jnp.exp(dt_t * A[None])
            h = a[..., None, None] * h + \
                (dt_t[..., None] * x_t)[..., None] * B_t[:, :, None, :]
            return h, None

        h0 = jnp.zeros((B_, H, hd, N), jnp.float32)
        hf, _ = jax.lax.scan(step, h0, (jnp.moveaxis(xh, 1, 0),
                                        jnp.moveaxis(dt, 1, 0),
                                        jnp.moveaxis(Bh, 1, 0)))
        k = cfg.ssm.d_conv
        return {"h": hf, "conv": conv_in[:, -(k - 1):, :]}

    @staticmethod
    def _prefill_hybrid(params, h, cfg, angles, cache, S, max_seq):
        emb0 = h
        A = cfg.hybrid.attn_every
        n_shared = cfg.hybrid.n_shared_blocks
        shared = params["shared"]
        G = _hybrid_groups(cfg)

        def group_body(carry, xs):
            x, g = carry
            mamba_g, down_g = xs
            sts = []
            for i in range(A):
                lp = _index_tree(mamba_g, i)
                hn = RMSNorm.apply(lp["ln"], x, eps=cfg.norm_eps)
                from repro.models.mamba import Mamba2
                y, st = LM._mamba_apply_with_state(lp["mamba"], hn, cfg, Mamba2)
                x = x + y
                sts.append(st)
            states = jax.tree.map(lambda *xs_: jnp.stack(xs_), *sts)
            sel = _index_tree(shared, jax.lax.rem(g, n_shared))
            x2 = jnp.concatenate([x, emb0], axis=-1)
            hh = RMSNorm.apply(sel["ln1"], x2, eps=cfg.norm_eps)
            hh, (k, v) = Attention.apply(sel["attn"], hh, cfg, angles=angles,
                                         causal=True, return_kv=True)
            x2 = x2 + hh
            hh = RMSNorm.apply(sel["ln2"], x2, eps=cfg.norm_eps)
            from repro.models.mlp import SwiGLU
            x2 = x2 + SwiGLU.apply(sel["mlp"], hh, dtype=cfg.cdtype)
            x = x + Linear.apply(down_g, x2, dtype=cfg.cdtype)
            kv = LM._kv_to_ring(k, v, cfg, max_seq)
            return (x, g + 1), (states, kv)

        if cfg.use_scan:
            (h, _), (mamba_states, kvs) = jax.lax.scan(
                group_body, (h, jnp.zeros((), jnp.int32)),
                (params["blocks"], params["down"]))
        else:
            carry = (h, jnp.zeros((), jnp.int32))
            outs = []
            for gi in range(G):
                carry, out = group_body(
                    carry, (_index_tree(params["blocks"], gi),
                            _index_tree(params["down"], gi)))
                outs.append(out)
            mamba_states = jax.tree.map(lambda *xs: jnp.stack(xs),
                                        *[o[0] for o in outs])
            kvs = jax.tree.map(lambda *xs: jnp.stack(xs), *[o[1] for o in outs])
            h = carry[0]
        cache = {**cache, "mamba": mamba_states, "attn": kvs}
        h = RMSNorm.apply(params["ln_f"], h, eps=cfg.norm_eps)
        return LM._logits(params, h[:, -1:], cfg), cache

    @staticmethod
    def _prefill_encdec(params, inputs, cfg, cache, max_seq):
        frames, tokens = inputs["frames"], inputs["tokens"]
        B, Se = frames.shape[:2]
        Sd = tokens.shape[1]
        enc_ang = _angles(cfg, B, Se)
        x = constrain(frames.astype(cfg.cdtype), ("batch", None, "embed_act"))
        x, _ = LM._scan_blocks(
            lambda p, h_: (EncoderBlock.apply(p, h_, cfg, angles=enc_ang), None),
            params["enc_blocks"], x, cfg)
        enc_out = LayerNorm.apply(params["ln_enc"], x, eps=cfg.norm_eps)

        dec_ang = _angles(cfg, B, Sd)
        h = LM._embed(params, tokens, cfg)

        def body(x_, layer_params):
            hh = LayerNorm.apply(layer_params["ln1"], x_, eps=cfg.norm_eps)
            hh, (k, v) = Attention.apply(layer_params["self_attn"], hh, cfg,
                                         angles=dec_ang, causal=True,
                                         return_kv=True)
            x_ = x_ + hh
            hh = LayerNorm.apply(layer_params["ln2"], x_, eps=cfg.norm_eps)
            ckv = CrossDecoderBlock.cross_kv(layer_params, enc_out, cfg)
            hh = Attention.apply(layer_params["cross_attn"], hh, cfg,
                                 cross_kv=ckv, causal=False)
            x_ = x_ + hh
            hh = LayerNorm.apply(layer_params["ln3"], x_, eps=cfg.norm_eps)
            from repro.models.mlp import SwiGLU
            x_ = x_ + SwiGLU.apply(layer_params["mlp"], hh, dtype=cfg.cdtype)
            return x_, (LM._kv_to_ring(k, v, cfg, max_seq), {"k": ckv[0], "v": ckv[1]})

        if cfg.use_scan:
            h, (self_kv, cross_kv) = jax.lax.scan(body, h, params["dec_blocks"])
        else:
            n = jax.tree.leaves(params["dec_blocks"])[0].shape[0]
            outs = []
            for i in range(n):
                h, out = body(h, _index_tree(params["dec_blocks"], i))
                outs.append(out)
            self_kv = jax.tree.map(lambda *xs: jnp.stack(xs), *[o[0] for o in outs])
            cross_kv = jax.tree.map(lambda *xs: jnp.stack(xs), *[o[1] for o in outs])
        cache = {**cache, "self": self_kv, "cross": cross_kv}
        cache["index"] = jnp.asarray(Sd, jnp.int32)
        cache["cross_len"] = jnp.full((B,), Se, jnp.int32)
        h = LayerNorm.apply(params["ln_f"], h, eps=cfg.norm_eps)
        return LM._logits(params, h[:, -1:], cfg), cache

    # ------------------------------------------------------------- decode

    @staticmethod
    def decode(params, tokens, cfg: ModelConfig, cache):
        """tokens: (B, 1) → (logits (B, 1, V), new cache).  cache["index"] is
        the absolute position of this token.  A "block_tbl" cache entry
        ((B, nk) int32) switches attention K/V leaves to the paged block-pool
        layout — the table is shared by all layers (one allocation per slot)
        and rides the cache pytree unchanged."""
        index = cache["index"]
        tbl = cache.get("block_tbl")
        B = tokens.shape[0]
        h = LM._embed(params, tokens, cfg)
        angles = _angles(cfg, B, 1, start=index)

        if cfg.enc_dec:
            cross_len = cache.get("cross_len")

            def body(x, xs):
                lp, st = xs
                y, st2 = CrossDecoderBlock.decode(lp, x, cfg, st, index,
                                                  angles=angles,
                                                  cross_len=cross_len,
                                                  block_tbl=tbl)
                return y, st2
            h, new_state = LM._decode_scan(
                body, h, params["dec_blocks"],
                {"self": cache["self"], "cross": cache["cross"]}, cfg)
            new_cache = {**cache, **new_state}
        elif cfg.hybrid is not None:
            h, new_cache = LM._decode_hybrid(params, h, cfg, cache, index,
                                             angles, block_tbl=tbl)
        elif cfg.ssm is not None:
            def body(x, xs):
                lp, st = xs
                return SSMBlock.decode(lp, x, cfg, st, index)
            h, states = LM._decode_scan(body, h, params["blocks"],
                                        cache["layers"], cfg)
            new_cache = {**cache, "layers": states}
        else:
            def body(x, xs):
                lp, st = xs
                return DecoderBlock.decode(lp, x, cfg, st, index,
                                           angles=angles, block_tbl=tbl)
            h, states = LM._decode_scan(body, h, params["blocks"],
                                        cache["layers"], cfg)
            new_cache = {**cache, "layers": states}

        norm = LayerNorm if cfg.family == "audio" else RMSNorm
        h = norm.apply(params["ln_f"], h, eps=cfg.norm_eps)
        logits = LM._logits(params, h, cfg)
        new_cache["index"] = index + 1
        return logits, new_cache

    @staticmethod
    def _decode_scan(body, h, blocks, states, cfg):
        if cfg.use_scan:
            h, new_states = jax.lax.scan(lambda c, xs: body(c, xs), h,
                                         (blocks, states))
            return h, new_states
        n = jax.tree.leaves(blocks)[0].shape[0]
        outs = []
        for i in range(n):
            st_i = _index_tree(states, i)
            h, st2 = body(h, (_index_tree(blocks, i), st_i))
            outs.append(st2)
        return h, jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    @staticmethod
    def _decode_hybrid(params, h, cfg, cache, index, angles, *,
                       block_tbl=None):
        emb0 = h
        A = cfg.hybrid.attn_every
        n_shared = cfg.hybrid.n_shared_blocks
        shared = params["shared"]

        def group_body(carry, xs):
            x, g = carry
            mamba_g, down_g, m_state, kv = xs
            new_m = []
            for i in range(A):
                x, st = SSMBlock.decode(_index_tree(mamba_g, i), x, cfg,
                                        _index_tree(m_state, i), index)
                new_m.append(st)
            m_states = jax.tree.map(lambda *xs_: jnp.stack(xs_), *new_m)
            sel = _index_tree(shared, jax.lax.rem(g, n_shared))
            x2 = jnp.concatenate([x, emb0], axis=-1)
            x2, kv2 = SharedAttnBlock.decode(sel, x2, cfg, kv, index,
                                             angles=angles,
                                             block_tbl=block_tbl)
            x = x + Linear.apply(down_g, x2, dtype=cfg.cdtype)
            return (x, g + 1), (m_states, kv2)

        if cfg.use_scan:
            (h, _), (m_states, kvs) = jax.lax.scan(
                group_body, (h, jnp.zeros((), jnp.int32)),
                (params["blocks"], params["down"], cache["mamba"], cache["attn"]))
        else:
            G = _hybrid_groups(cfg)
            carry = (h, jnp.zeros((), jnp.int32))
            outs = []
            for gi in range(G):
                carry, out = group_body(
                    carry, (_index_tree(params["blocks"], gi),
                            _index_tree(params["down"], gi),
                            _index_tree(cache["mamba"], gi),
                            _index_tree(cache["attn"], gi)))
                outs.append(out)
            m_states = jax.tree.map(lambda *xs: jnp.stack(xs), *[o[0] for o in outs])
            kvs = jax.tree.map(lambda *xs: jnp.stack(xs), *[o[1] for o in outs])
            h = carry[0]
        return h, {**cache, "mamba": m_states, "attn": kvs}
