"""GQA attention with RoPE/M-RoPE, causal/sliding-window/bidirectional masks,
cross-attention, and a decode path over a preallocated KV cache.

The jnp reference path is what the CPU dry-run lowers; when
``cfg.use_pallas`` the prefill/train path dispatches to the Pallas flash
kernel and decode to the split-K decode kernel (kernels/ops.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import Linear
from repro.sharding import constrain, current_ctx, no_shard_ctx
from repro.models.rotary import apply_rope

NEG_INF = -1e9


def _mask_bias(q_pos, k_pos, *, causal: bool, window=None, valid_upto=None):
    """Additive (…, S_q, S_k) bias from position comparisons.

    q_pos: (B, S_q) int32; k_pos: (S_k,) int32 broadcast over batch.
    valid_upto: (B,) or scalar — keys at positions > valid_upto are masked
    (decode over a partially-filled cache)."""
    q = q_pos[:, :, None].astype(jnp.int32)
    k = k_pos[None, None, :].astype(jnp.int32)
    ok = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if causal:
        ok &= k <= q
    if window is not None:
        ok &= k > q - window
    if valid_upto is not None:
        v = jnp.asarray(valid_upto, jnp.int32)
        v = v.reshape(-1, 1, 1) if v.ndim else v[None, None, None]
        ok &= k <= v
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def sdpa_ref(q, k, v, bias=None):
    """q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd) — grouped-query attention, fp32
    softmax.  bias: (B, Sq, Sk) additive or None."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, Sq, KV, G, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k,
                        preferred_element_type=jnp.float32) * scale
    if bias is not None:
        scores = scores + bias[:, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


class Attention:
    """Projection weights + the attention math.  ``d_in``/``d_out`` let the
    Zamba2 shared block attend over concat(hidden, embed) (2·d_model)."""

    @staticmethod
    def init(key, cfg, *, d_in=None, d_out=None):
        d_in = d_in or cfg.d_model
        d_out = d_out or cfg.d_model
        hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
        kq, kk, kv, ko = jax.random.split(key, 4)
        pd = cfg.pdtype
        params = {
            "wq": Linear.init(kq, d_in, H * hd, use_bias=cfg.qkv_bias, param_dtype=pd),
            "wk": Linear.init(kk, d_in, KV * hd, use_bias=cfg.qkv_bias, param_dtype=pd),
            "wv": Linear.init(kv, d_in, KV * hd, use_bias=cfg.qkv_bias, param_dtype=pd),
            "wo": Linear.init(ko, H * hd, d_out, use_bias=False, param_dtype=pd),
        }
        axes = {
            "wq": {"w": ("embed", "heads")},
            "wk": {"w": ("embed", "kv_heads")},
            "wv": {"w": ("embed", "kv_heads")},
            "wo": {"w": ("heads", "embed")},
        }
        if cfg.qkv_bias:
            axes["wq"]["b"] = ("heads",)
            axes["wk"]["b"] = ("kv_heads",)
            axes["wv"]["b"] = ("kv_heads",)
        return params, axes

    @staticmethod
    def qkv(params, x, x_kv, cfg, *, pad_hp=None):
        """pad_hp: project q through per-group zero-padded wq columns so q
        leaves the matmul with Hp heads ALREADY aligned to the mesh — padding
        the activation after a misaligned projection re-gathers ~GiB of q
        (and its gradients) per layer (EXPERIMENTS.md §Perf C5)."""
        B, S = x.shape[:2]
        hd = cfg.hd
        dt = cfg.cdtype
        if pad_hp is not None:
            KV = cfg.n_kv_heads
            G, Gp = cfg.n_heads // KV, pad_hp // KV
            wq = params["wq"]["w"]
            wq = wq.reshape(-1, KV, G, hd)
            wq = jnp.pad(wq, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))
            q = x.astype(dt) @ wq.reshape(-1, KV * Gp * hd).astype(dt)
            if "b" in params["wq"]:
                b = params["wq"]["b"].reshape(KV, G, hd)
                b = jnp.pad(b, ((0, 0), (0, Gp - G), (0, 0))).reshape(-1)
                q = q + b.astype(q.dtype)
            q = q.reshape(B, S, pad_hp, hd)
        else:
            q = Linear.apply(params["wq"], x, dtype=dt).reshape(
                B, S, cfg.n_heads, hd)
        Skv = x_kv.shape[1]
        k = Linear.apply(params["wk"], x_kv, dtype=dt).reshape(B, Skv, cfg.n_kv_heads, hd)
        v = Linear.apply(params["wv"], x_kv, dtype=dt).reshape(B, Skv, cfg.n_kv_heads, hd)
        q = constrain(q, ("batch", None, "heads", None))
        k = constrain(k, ("batch", None, "kv_heads", None))
        v = constrain(v, ("batch", None, "kv_heads", None))
        return q, k, v

    # ---------------- full-sequence (train / prefill / encoder) ----------------

    @staticmethod
    def apply(params, x, cfg, *, angles=None, causal=True, window=None,
              cross_kv=None, return_kv=False):
        """x: (B, S, d_in).  cross_kv: (k, v) precomputed for cross-attention
        (angles are not applied to cross K)."""
        B, S = x.shape[:2]
        if cross_kv is not None:
            q = Linear.apply(params["wq"], x, dtype=cfg.cdtype)
            q = q.reshape(B, S, cfg.n_heads, cfg.hd)
            if angles is not None:
                q = apply_rope(q, angles)
            k, v = cross_kv
            out = Attention._sdpa_masked(q, k, v, causal=False, window=None)
            kv = None
        else:
            pad_hp = None
            if not cfg.use_pallas:
                info = Attention._padded_heads(
                    (0, 0, cfg.n_heads, cfg.hd), cfg.n_kv_heads)
                if info is not None:
                    pad_hp = info[0]
            q, k, v = Attention.qkv(params, x, x, cfg, pad_hp=pad_hp)
            if angles is not None:
                q = apply_rope(q, angles)
                k = apply_rope(k, angles)
            if cfg.use_pallas and causal and cross_kv is None:
                from repro.kernels import ops as kops
                out = kops.flash_attention(q, k, v, causal=True, window=window)
            else:
                out = Attention._sdpa_masked(q, k, v, causal=causal,
                                             window=window)
            kv = (k, v)
            if pad_hp is not None:
                KV = cfg.n_kv_heads
                G, Gp = cfg.n_heads // KV, pad_hp // KV
                w_eff = Attention._wo_padded(params, KV, G, Gp, cfg.hd)
                out = constrain(out, ("batch", None, "heads", None))
                y = out.reshape(B, S, -1) @ w_eff.astype(cfg.cdtype)
                y = constrain(y, ("batch", None, "embed_act"))
                return (y, kv) if return_kv else y
        out = constrain(out, ("batch", None, "heads", None))
        y = Linear.apply(params["wo"], out.reshape(B, S, -1), dtype=cfg.cdtype)
        y = constrain(y, ("batch", None, "embed_act"))
        return (y, kv) if return_kv else y

    # ---------------- chunked (flash-style) masked attention --------------
    #
    # The naive jnp path materializes the (B, H, S, S) score tensor — at 32k
    # prefill that is the whole memory term of every train/prefill cell.
    # Chunking the q dim with lax.map keeps only a (B, H, chunk, S_k) working
    # set live, which is exactly the HBM-traffic shape of the Pallas flash
    # kernel on TPU (scores never round-trip HBM).  Numerics are identical to
    # the full path (per-chunk full softmax, not an online approximation).

    CHUNK_Q = 1024

    @staticmethod
    def _sdpa_masked(q, k, v, *, causal, window):
        B, S, H, hd = q.shape
        chunk = Attention.CHUNK_Q
        if S > chunk and S % chunk == 0:
            return Attention._sdpa_chunked(q, k, v, causal=causal,
                                           window=window, chunk=chunk)
        q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        k_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
        bias = (_mask_bias(q_pos, k_pos, causal=causal, window=window)
                if (causal or window is not None) else None)
        return sdpa_ref(q, k, v, bias)

    @staticmethod
    def _sdpa_chunked(q, k, v, *, causal, window, chunk):
        B, S, H, hd = q.shape
        Sk = k.shape[1]
        n = S // chunk
        qc = jnp.moveaxis(q.reshape(B, n, chunk, H, hd), 1, 0)
        k_pos = jnp.arange(Sk, dtype=jnp.int32)

        def one(args):
            i, qi = args
            if causal or window is not None:
                q_pos = i * chunk + jnp.arange(chunk, dtype=jnp.int32)
                bias = _mask_bias(jnp.broadcast_to(q_pos[None], (B, chunk)),
                                  k_pos, causal=causal, window=window)
            else:
                bias = None
            return sdpa_ref(qi, k, v, bias)

        outs = jax.lax.map(one, (jnp.arange(n, dtype=jnp.int32), qc))
        return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)

    # ---------------- padded-head sharding ------------------------------
    #
    # When n_heads does not divide the "model" axis (qwen2.5-14b: 40 heads on
    # a 16-wide axis), the rule table falls back to replication and every
    # model rank computes the FULL attention — measured 3.3× total-FLOP
    # inflation on train_4k (EXPERIMENTS.md §Perf).  Fix: pad the q heads
    # *per kv-group* up to the next count divisible by both the mesh axis
    # and n_kv_heads, shard the padded heads, and slice the pad away before
    # the output projection.  Pad waste (48/40 = 20% of attention FLOPs)
    # replaces 16× replication.

    @staticmethod
    def _padded_heads(q_shape, kv_heads):
        """→ (Hp, G, Gp) when padding applies under the current ctx, else
        None.  Hp is the smallest head count ≥ H divisible by both the
        "model" axis and n_kv_heads."""
        ctx = current_ctx()
        if ctx is None:
            return None
        _, mesh = ctx
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        m = sizes.get("model", 1)
        H = q_shape[2]
        if m <= 1 or H % m == 0 or kv_heads <= 0 or H % kv_heads != 0:
            return None
        Hp = H
        while Hp % m or Hp % kv_heads:
            Hp += kv_heads
        return Hp, H // kv_heads, Hp // kv_heads

    @staticmethod
    def _wo_padded(params, kv_heads, G, Gp, hd):
        """wo rows re-laid to match Hp padded heads: (KV·Gp·hd, d) with zero
        rows in the pad positions — padded-head outputs contribute exactly 0."""
        w = params["wo"]["w"]                   # (H·hd, d)
        d_out = w.shape[-1]
        w4 = w.reshape(kv_heads, G, hd, d_out)
        w4 = jnp.pad(w4, ((0, 0), (0, Gp - G), (0, 0), (0, 0)))
        return w4.reshape(kv_heads * Gp * hd, d_out)

    # ---------------- single-token decode over a KV cache ----------------
    #
    # The cache is a RING BUFFER of Smax slots.  For full-attention archs
    # Smax = seq_len and slot == absolute position; for sliding-window archs
    # Smax = window, so the cache (and therefore long_500k decode memory) is
    # bounded by the window — keys carry RoPE applied at their absolute
    # position before caching, so slot order is irrelevant (attention is
    # permutation-invariant over keys) and the only mask is slot validity.

    @staticmethod
    def decode(params, x, cfg, cache, index, *, angles=None, cross_kv=None,
               cross_len=None, block_tbl=None):
        """x: (B, 1, d_in); cache: {"k","v"}: (B, Smax, KV, hd); index: the
        absolute position being written — scalar int32, or a (B,) vector when
        each batch row sits at its own position (continuous batching: the
        serving engine's slots are admitted at different times, so their ring
        slots and validity horizons differ per row).  cross_len: optional
        scalar or (B,) encoder length for the cross_kv branch — key positions
        >= cross_len are masked, so a max_seq-sized cross-K/V pool can hold
        shorter encodings per slot.  block_tbl: optional (B, nk) int32 block
        table — when given, cache leaves are a PHYSICAL BLOCK POOL
        (NB, bk, KV, hd) shared by all rows, row b's logical sequence is the
        concatenation of blocks ``block_tbl[b]``, and the write lands at
        (block_tbl[b, pos//bk], pos%bk) instead of a private ring slot
        (paged KV: slots share prefix blocks, so the pool's leading dim is
        block-count, not batch).  Returns (y, new_cache)."""
        B = x.shape[0]
        index = jnp.asarray(index, jnp.int32)
        if cross_kv is not None:
            q = Linear.apply(params["wq"], x, dtype=cfg.cdtype)
            q = q.reshape(B, 1, cfg.n_heads, cfg.hd)
            if angles is not None:
                q = apply_rope(q, angles)
            bias = None
            if cross_len is not None:
                Se = cross_kv[0].shape[1]
                cl = jnp.asarray(cross_len, jnp.int32)
                cl = cl.reshape(-1, 1, 1) if cl.ndim else cl[None, None, None]
                k_pos = jnp.arange(Se, dtype=jnp.int32)[None, None, :]
                bias = jnp.broadcast_to(
                    jnp.where(k_pos < cl, 0.0, NEG_INF).astype(jnp.float32),
                    (B, 1, Se))
            out = sdpa_ref(q, cross_kv[0], cross_kv[1], bias)
            y = Linear.apply(params["wo"], out.reshape(B, 1, -1), dtype=cfg.cdtype)
            return y, cache
        q, k, v = Attention.qkv(params, x, x, cfg)
        if angles is not None:
            q = apply_rope(q, angles)
            k = apply_rope(k, angles)
        if block_tbl is not None:
            out, new_cache = Attention._decode_paged(q, k, v, cfg, cache,
                                                     index, block_tbl)
            y = Linear.apply(params["wo"], out.reshape(B, 1, -1),
                             dtype=cfg.cdtype)
            y = constrain(y, ("batch", None, "embed_act"))
            return y, new_cache
        Smax = cache["k"].shape[1]
        sk = Attention._splitk_ctx(Smax) if index.ndim == 0 else None
        if sk is not None:
            out, new_cache = Attention._decode_splitk(q, k, v, cache, index,
                                                      *sk)
        elif index.ndim:
            # per-row positions: scatter each row's K/V into its own ring
            # slot, mask each row against its own validity horizon
            slot = jax.lax.rem(index, Smax)
            if cfg.use_pallas:
                from repro.kernels import ops as kops
                k_cache = kops.cache_ring_update(cache["k"], k[:, 0], slot)
                v_cache = kops.cache_ring_update(cache["v"], v[:, 0], slot)
                out = kops.decode_attention(q, k_cache, v_cache, index)
            else:
                rows = jnp.arange(B)
                k_cache = cache["k"].at[rows, slot].set(
                    k[:, 0].astype(cache["k"].dtype))
                v_cache = cache["v"].at[rows, slot].set(
                    v[:, 0].astype(cache["v"].dtype))
                slots = jnp.arange(Smax, dtype=jnp.int32)
                bias = jnp.where(slots[None, None, :] <= index[:, None, None],
                                 0.0, NEG_INF).astype(jnp.float32)
                out = sdpa_ref(q, k_cache, v_cache, bias)
            new_cache = {"k": k_cache, "v": v_cache}
        else:
            slot = jax.lax.rem(index, Smax)
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
            k_cache = constrain(k_cache,
                                ("batch", "cache_seq", "kv_heads", None))
            v_cache = constrain(v_cache,
                                ("batch", "cache_seq", "kv_heads", None))
            if cfg.use_pallas:
                from repro.kernels import ops as kops
                out = kops.decode_attention(q, k_cache, v_cache, index)
            else:
                # valid slots: all <= index (ring: once wrapped, all valid)
                slots = jnp.arange(Smax, dtype=jnp.int32)
                bias = jnp.where(slots[None, None, :] <= index, 0.0, NEG_INF
                                 ).astype(jnp.float32)
                bias = jnp.broadcast_to(bias, (B, 1, Smax))
                out = sdpa_ref(q, k_cache, v_cache, bias)
            new_cache = {"k": k_cache, "v": v_cache}
        y = Linear.apply(params["wo"], out.reshape(B, 1, -1), dtype=cfg.cdtype)
        y = constrain(y, ("batch", None, "embed_act"))
        return y, new_cache

    # ---------------- paged decode (block-table KV pool) ------------------
    #
    # The cache leaves are a pool of NB fixed-size blocks shared by every
    # slot; each row's (nk,) table row names the physical blocks that make
    # up its logical sequence.  Shared prefix blocks appear in several
    # tables at once — the attention gather reads them read-only, and the
    # engine's allocator guarantees the write target (pos // bk) is always
    # a private block, so no kernel-level copy-on-write is needed.

    @staticmethod
    def _decode_paged(q, k_new, v_new, cfg, cache, index, block_tbl):
        """q/k_new/v_new: (B, 1, ·, hd); cache leaves (NB, bk, KV, hd);
        block_tbl (B, nk) int32; index (B,) or scalar int32."""
        B = q.shape[0]
        NB, bks = cache["k"].shape[0], cache["k"].shape[1]
        nk = block_tbl.shape[1]
        Smax = nk * bks
        index = jnp.broadcast_to(
            jnp.asarray(index, jnp.int32).reshape(-1), (B,))
        # under a shard_map decode the pool is split over the batch/mesh
        # axes and the engine hands out GLOBAL block ids — rem() maps them
        # into this shard's local pool (the allocator pins a slot's blocks
        # to its own partition, so the fold is exact); unsharded, ids are
        # already < NB and rem() is the identity
        tbl = jax.lax.rem(jnp.asarray(block_tbl, jnp.int32), NB)
        rpos = jax.lax.rem(index, Smax)
        rows = jnp.arange(B)
        blk = tbl[rows, rpos // bks]
        off = rpos % bks
        if cfg.use_pallas:
            from repro.kernels import ops as kops
            k_cache = kops.cache_paged_update(cache["k"], k_new[:, 0], blk, off)
            v_cache = kops.cache_paged_update(cache["v"], v_new[:, 0], blk, off)
            out = kops.decode_attention_paged(q, k_cache, v_cache, tbl, index)
        else:
            k_cache = cache["k"].at[blk, off].set(
                k_new[:, 0].astype(cache["k"].dtype))
            v_cache = cache["v"].at[blk, off].set(
                v_new[:, 0].astype(cache["v"].dtype))
            kg = k_cache[tbl].reshape(B, Smax, *k_cache.shape[2:])
            vg = v_cache[tbl].reshape(B, Smax, *v_cache.shape[2:])
            slots = jnp.arange(Smax, dtype=jnp.int32)
            bias = jnp.where(slots[None, None, :] <= index[:, None, None],
                             0.0, NEG_INF).astype(jnp.float32)
            out = sdpa_ref(q, kg, vg, bias)
        return out, {"k": k_cache, "v": v_cache}

    # ---------------- split-K decode (flash-decoding over the model axis) --
    #
    # With the KV cache sequence-sharded over "model" (SERVE_RULES — required
    # for the big decode cells to fit HBM), letting the SPMD partitioner
    # handle the ring-buffer update + attention forces replicate-then-
    # repartition of the whole cache every layer (~3 cache-sized transfers,
    # measured on qwen2-72b decode_32k — EXPERIMENTS.md §Perf).  Instead:
    # each model rank updates ITS slot locally and computes a partial
    # attention over its sequence block; partials combine with the
    # log-sum-exp trick — pmax(m) + psum(l·scale) + psum(o·scale), a few
    # hundred KB per layer instead of hundreds of MB.

    @staticmethod
    def _splitk_ctx(Smax: int):
        """→ (mesh, batch_axes, m) when the split-K path applies, else None."""
        ctx = current_ctx()
        if ctx is None:
            return None
        rules, mesh = ctx
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        m = sizes.get("model", 1)
        if m <= 1 or "model" not in rules.get("cache_seq"):
            return None
        if Smax % m != 0:
            return None
        batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
        return mesh, batch_axes, m

    @staticmethod
    def _decode_splitk(q, k_new, v_new, cache, index, mesh, batch_axes, m):
        B, _, H, hd = q.shape
        Smax, KV = cache["k"].shape[1], cache["k"].shape[2]
        bsh = 1
        for a in batch_axes:
            bsh *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
        if B % max(bsh, 1) != 0:
            bsh = 1
            batch_axes = ()
        S_loc = Smax // m
        bspec = (batch_axes if len(batch_axes) != 1 else batch_axes[0]) \
            if batch_axes else None

        def body(qb, kb, vb, k_blk, v_blk, idx):
            with no_shard_ctx():
                rank = jax.lax.axis_index("model")
                slot = jax.lax.rem(idx, Smax)
                ls = slot - rank * S_loc
                in_rng = (ls >= 0) & (ls < S_loc)
                lsc = jnp.clip(ls, 0, S_loc - 1)
                # in-place slot write: non-owner ranks rewrite the existing
                # row (a (B,1,KV,hd) temp) instead of select-copying the
                # whole cache block — keeps the update donation-friendly
                old_k = jax.lax.dynamic_slice_in_dim(k_blk, lsc, 1, axis=1)
                old_v = jax.lax.dynamic_slice_in_dim(v_blk, lsc, 1, axis=1)
                new_k = jnp.where(in_rng, kb[:, None].astype(k_blk.dtype),
                                  old_k)
                new_v = jnp.where(in_rng, vb[:, None].astype(v_blk.dtype),
                                  old_v)
                k_blk = jax.lax.dynamic_update_slice_in_dim(k_blk, new_k,
                                                            lsc, axis=1)
                v_blk = jax.lax.dynamic_update_slice_in_dim(v_blk, new_v,
                                                            lsc, axis=1)
                # partial attention over my block, fp32 accumulation
                Bl = qb.shape[0]
                G = H // KV
                qg = qb.reshape(Bl, KV, G, hd)
                s = jnp.einsum("bkgh,btkh->bkgt", qg,
                               k_blk.astype(qb.dtype),
                               preferred_element_type=jnp.float32
                               ) * (hd ** -0.5)
                pos = rank * S_loc + jnp.arange(S_loc, dtype=jnp.int32)
                s = s + jnp.where(pos <= idx, 0.0, NEG_INF
                                  )[None, None, None, :]
                m_loc = jnp.max(s, axis=-1)                     # (B, KV, G)
                m_glob = jax.lax.pmax(m_loc, "model")
                p = jnp.exp(s - m_glob[..., None])
                l_loc = jnp.sum(p, axis=-1)
                o_loc = jnp.einsum("bkgt,btkh->bkgh",
                                   p.astype(v_blk.dtype), v_blk,
                                   preferred_element_type=jnp.float32)
                l_glob = jax.lax.psum(l_loc, "model")
                o_glob = jax.lax.psum(o_loc, "model")
                out = (o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
                       ).reshape(Bl, 1, H, hd).astype(qb.dtype)
                return out, k_blk, v_blk

        from repro.sharding import shard_map
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(P(bspec, None, None),            # q (B,H,hd)
                      P(bspec, None, None),            # k_new (B,KV,hd)
                      P(bspec, None, None),            # v_new (B,KV,hd)
                      P(bspec, "model", None, None),   # k cache block
                      P(bspec, "model", None, None),   # v cache block
                      P()),                            # index
            out_specs=(P(bspec, None, None, None),
                       P(bspec, "model", None, None),
                       P(bspec, "model", None, None)),
            check_vma=False)
        out, k_cache, v_cache = fn(q[:, 0], k_new[:, 0, :, :],
                                   v_new[:, 0, :, :],
                                   cache["k"], cache["v"],
                                   jnp.asarray(index, jnp.int32))
        return out, {"k": k_cache, "v": v_cache}

    @staticmethod
    def cache_len(cfg, max_seq: int) -> int:
        if cfg.sliding_window is not None:
            return min(max_seq, cfg.sliding_window)
        return max_seq

    @staticmethod
    def cache_shape(cfg, batch: int, max_seq: int):
        Smax = Attention.cache_len(cfg, max_seq)
        kv_shape = (batch, Smax, cfg.n_kv_heads, cfg.hd)
        axes = ("batch", "cache_seq", "kv_heads", None)
        return {"k": (kv_shape, axes), "v": (kv_shape, axes)}
