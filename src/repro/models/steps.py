"""Step functions (train / prefill / decode) and ShapeDtypeStruct input specs.

These are the units the launcher jits: ``jax.jit(train_step, in_shardings=…)
.lower(**input_specs(...)).compile()`` is exactly what the multi-pod dry-run
exercises for all 40 (arch × shape) cells.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ShapeCfg
from repro.models.transformer import LM
from repro.models.attention import Attention
from repro.optim import adamw, apply_updates, clip_by_global_norm
from repro.sharding import constrain


class TrainState(NamedTuple):
    params: object
    opt_state: object
    step: jax.Array


def cross_entropy(logits, labels, ignore_id: int = -1):
    """logits: (B, S, V) fp32 (possibly vocab-sharded); labels: (B, S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum((logz - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


CE_CHUNK = 1024


def chunked_cross_entropy(params, h, labels, cfg, *, ignore_id: int = -1,
                          chunk: int = CE_CHUNK):
    """CE from hidden states with per-seq-chunk logits (lax.map), so the
    (B, S, V) fp32 logits tensor never materializes — at 4k×256 with a 152k
    vocab that tensor alone is ~40 GB/device (EXPERIMENTS.md §Perf C4).
    Numerically identical to cross_entropy(LM._logits(h))."""
    B, S, d = h.shape
    n = S // chunk
    hc = jnp.moveaxis(h.reshape(B, n, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)

    def one(args):
        hi, li = args
        logits = LM._logits(params, hi, cfg).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, li[..., None].astype(jnp.int32),
                                 axis=-1)[..., 0]
        mask = (li != ignore_id).astype(jnp.float32)
        return jnp.sum((logz - ll) * mask), jnp.sum(mask)

    num, den = jax.lax.map(one, (hc, lc))
    return jnp.sum(num) / jnp.maximum(jnp.sum(den), 1.0)


def model_inputs(cfg: ModelConfig, batch: int, seq: int, *, with_labels: bool):
    """Concrete-input template as (shape, dtype) dicts; family-aware."""
    specs = {"tokens": ((batch, seq), jnp.int32)}
    if cfg.family == "vlm" and seq > 1:
        specs["patches"] = ((batch, cfg.n_vision_patches, cfg.d_model), cfg.cdtype)
    if cfg.enc_dec:
        specs["frames"] = ((batch, seq, cfg.d_model), cfg.cdtype)
    if with_labels:
        specs["labels"] = ((batch, seq), jnp.int32)
    return specs


def input_sharding_axes(cfg: ModelConfig, *, with_labels: bool):
    axes = {"tokens": ("batch", "seq")}
    if cfg.family == "vlm":
        axes["patches"] = ("batch", None, "embed_act")
    if cfg.enc_dec:
        axes["frames"] = ("batch", "seq", "embed_act")
    if with_labels:
        axes["labels"] = ("batch", "seq")
    return axes


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, *, lr=3e-4, weight_decay: float = 0.1,
                    grad_clip: float = 1.0):
    opt_init, opt_update = adamw(lr, weight_decay=weight_decay)
    params_axes = None
    if cfg.cdtype != cfg.pdtype:
        params_axes, _ = params_axes_and_structs(cfg)

    def cast_params_sharded(params):
        """Mixed-precision FSDP: cast fp32 masters to the compute dtype WITH
        the sharded layout pinned, so the per-layer weight all-gathers move
        bf16 instead of fp32 — halves the dominant training collective
        (EXPERIMENTS.md §Perf A2).  No-op when pdtype == cdtype."""
        if params_axes is None:
            return params
        def one(p, ax):
            if p.dtype == jnp.float32:
                return constrain(p.astype(cfg.cdtype), ax)
            return p
        return jax.tree.map(one, params, params_axes)

    def train_step(state: TrainState, batch):
        def loss_fn(params):
            p_c = cast_params_sharded(params)
            S = batch["labels"].shape[1]
            if S > CE_CHUNK and S % CE_CHUNK == 0:
                h, aux = LM.apply(p_c, batch, cfg, return_hidden=True)
                ce = chunked_cross_entropy(p_c, h, batch["labels"], cfg)
            else:
                logits, aux = LM.apply(p_c, batch, cfg)
                ce = cross_entropy(logits, batch["labels"])
            loss = ce
            if cfg.moe is not None:
                loss = (loss + cfg.moe.router_aux_coef * aux["lb_loss"]
                        + cfg.moe.router_z_coef * aux["z_loss"])
            return loss, (ce, aux)

        (loss, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        updates, opt_state = opt_update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        metrics = {"loss": loss, "ce": ce, "grad_norm": gnorm,
                   "lb_loss": aux["lb_loss"], "drop_frac": aux["drop_frac"]}
        return TrainState(params, opt_state, state.step + 1), metrics

    return train_step, (opt_init, opt_update)


def init_train_state(key, cfg: ModelConfig, opt_init):
    params, _ = LM.init(key, cfg)
    return TrainState(params=params, opt_state=opt_init(params),
                      step=jnp.zeros((), jnp.int32))


def params_axes_and_structs(cfg: ModelConfig):
    """(logical-axes pytree, ShapeDtypeStruct pytree) for the params — built
    under eval_shape so nothing is allocated (the 72B config included)."""
    captured = {}

    def f(key):
        params, axes = LM.init(key, cfg)
        captured["axes"] = axes
        return params

    structs = jax.eval_shape(f, jax.random.PRNGKey(0))
    return captured["axes"], structs


def train_state_axes(cfg: ModelConfig):
    """Logical-axes pytree mirroring TrainState (params + AdamW moments)."""
    params_axes, _ = params_axes_and_structs(cfg)
    from repro.optim.adamw import AdamWState
    return TrainState(
        params=params_axes,
        opt_state=AdamWState(step=(), mu=params_axes, nu=params_axes),
        step=())


# ---------------------------------------------------------------------------
# serve (prefill + decode)
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, batch):
        return LM.prefill(params, batch, cfg, max_seq)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, tokens, cache):
        return LM.decode(params, tokens, cfg, cache)
    return decode_step


def make_verify_step(cfg: ModelConfig):
    """Speculative-decode verify: feed a (B, W) window of tokens — per row,
    the committed next input token followed by up to W-1 draft proposals —
    through W chained decode steps in ONE jitted program, returning the
    per-position greedy tokens (B, W) int32, the per-position logits
    (B, W, V), and the advanced cache.

    This is ``make_chunked_prefill_step``'s scan body promoted to a
    standalone step: each position runs the SAME ``LM.decode`` the plain
    tick runs, so the logits at position j (given the same fed prefix) are
    bit-identical to the non-speculative path's — acceptance is exact-match
    on sampled tokens, which is what makes speculative streams
    bit-identical by construction across families, pools, and topologies.
    The device cache advances W positions for every row; the engine rewinds
    each row to its true position afterwards (``pool.set_index``) — the
    same mechanism preemption/evacuation uses — so rejected-tail K/V is
    simply re-covered.  jit retraces per distinct W; an engine uses one W.
    """
    def verify_step(params, tokens, cache):
        tail = jnp.moveaxis(tokens[:, :, None], 1, 0)     # (W, B, 1)

        def body(cache, tok):
            logits, cache = LM.decode(params, tok, cfg, cache)
            return cache, logits[:, 0]                    # (B, V)

        # W is tiny (spec_k+1, single digits): full unroll removes the XLA
        # while-loop's per-iteration dispatch, which at serving batch sizes
        # costs more than the chained decodes themselves on CPU
        cache, ls = jax.lax.scan(body, cache, tail, unroll=True)
        ls = jnp.moveaxis(ls, 0, 1)                       # (B, W, V)
        toks = jnp.argmax(ls.astype(jnp.float32), axis=-1).astype(jnp.int32)
        return toks, ls, cache

    return verify_step


def make_fused_decode_step(cfg: ModelConfig):
    """One decode step with sampling fused into the tail: returns the
    per-row sampled tokens (B,) int32 ALONGSIDE the logits, so a greedy
    serving tick pulls B int32s instead of (B, 1, V) floats — the logits
    stay device-resident for the rows (temperature > 0) that still sample
    host-side with their stateful per-request RNG.

    seed/rid/pos are (B,) int32 stateless RNG counters (unused by greedy
    rows but threaded so device sampling is per-(request, position)
    reproducible); temperature is (B,) float32, 0 → greedy argmax,
    bit-compatible with the host ``sampling.sample_token``.  Dispatches to
    the Pallas fused-sample kernel under ``cfg.use_pallas`` and to the jnp
    oracle otherwise — the two are pinned bitwise-equal.
    """
    from repro.kernels import ops as kernel_ops
    from repro.kernels import ref as kernel_ref

    def fused_decode_step(params, tokens, cache, seed, rid, pos, temperature):
        logits, cache = LM.decode(params, tokens, cfg, cache)
        rows = logits[:, 0].astype(jnp.float32)
        if cfg.use_pallas:
            toks = kernel_ops.fused_sample(rows, seed, rid, pos, temperature)
        else:
            toks = kernel_ref.fused_sample_ref(rows, seed, rid, pos,
                                               temperature)
        return toks, logits, cache

    return fused_decode_step


def make_chunked_prefill_step(cfg: ModelConfig, max_seq: int, chunk: int):
    """Prefill with bounded per-step work: a one-shot prefill of the first
    ``chunk`` tokens builds the cache, then the remaining prompt streams
    through the decode path one token per step (lax.scan).  Produces the same
    (last-position logits, cache) as ``make_prefill_step``.

    This is the REFERENCE form of the equivalence the serving engine exploits
    (ServingEngine.admit/tick interleave the same per-token continuation with
    live decode slots, which a self-contained scan cannot express) — the
    engine test suite pins both implementations against one-shot prefill.

    Constraints: enc-dec prefills one-shot (the encoder needs every frame);
    vlm needs ``chunk > n_vision_patches`` so the patch prefix lands in the
    one-shot portion.
    """
    if cfg.family == "vlm" and chunk <= cfg.n_vision_patches:
        raise ValueError(
            f"vlm chunked prefill needs chunk > n_vision_patches "
            f"({chunk} <= {cfg.n_vision_patches})")

    def chunked_prefill(params, inputs):
        tokens = inputs["tokens"]
        S = tokens.shape[1]
        if S <= chunk or cfg.enc_dec:
            return LM.prefill(params, inputs, cfg, max_seq)
        first = dict(inputs)
        first["tokens"] = tokens[:, :chunk]
        logits, cache = LM.prefill(params, first, cfg, max_seq)
        tail = jnp.moveaxis(tokens[:, chunk:, None], 1, 0)  # (S-chunk, B, 1)

        def body(carry, tok):
            _, cache = carry
            logits, cache = LM.decode(params, tok, cfg, cache)
            return (logits, cache), None

        (logits, cache), _ = jax.lax.scan(body, (logits, cache), tail)
        return logits, cache

    return chunked_prefill


def cache_axes(cfg: ModelConfig, batch: int, max_seq: int):
    spec = LM.cache_spec(cfg, batch, max_seq)
    return jax.tree.map(lambda s: s[2], spec,
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
                        and isinstance(x[0], tuple))


def cache_structs(cfg: ModelConfig, batch: int, max_seq: int):
    spec = LM.cache_spec(cfg, batch, max_seq)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s[0], s[1]), spec,
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
                        and isinstance(x[0], tuple))


def input_structs(cfg: ModelConfig, shape: ShapeCfg):
    """ShapeDtypeStruct stand-ins for one dry-run cell (no allocation)."""
    if shape.kind == "train":
        t = model_inputs(cfg, shape.global_batch, shape.seq_len, with_labels=True)
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in t.items()}
    if shape.kind == "prefill":
        t = model_inputs(cfg, shape.global_batch, shape.seq_len, with_labels=False)
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in t.items()}
    # decode: one token + cache at seq_len
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    cache = cache_structs(cfg, shape.global_batch, shape.seq_len)
    return {"tokens": tokens, "cache": cache}
