from repro.models.config import (
    HybridCfg,
    ModelConfig,
    MoECfg,
    SHAPES,
    ShapeCfg,
    SSMCfg,
    applicable_shapes,
)
from repro.models.transformer import LM

__all__ = [
    "ModelConfig",
    "MoECfg",
    "SSMCfg",
    "HybridCfg",
    "ShapeCfg",
    "SHAPES",
    "applicable_shapes",
    "LM",
]
