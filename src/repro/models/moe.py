"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

TPU adaptation (DESIGN.md §3): instead of the GShard one-hot dispatch einsum
(whose (tokens × experts × capacity) one-hot is infeasible at LLM batch sizes)
we sort token→expert assignments and gather fixed-capacity per-expert slabs,
so every tensor has static shape and the expert matmuls are dense
(E, C, d)×(E, d, ff) einsums that map straight onto the MXU.

Two execution paths:

  * dense/global (``_apply_global``) — pure jnp, used on a single device and
    as the semantic reference.  Under SPMD this path is catastrophic: the
    data-dependent sort/gather between batch-sharded tokens and
    expert-sharded slabs forces the partitioner into replicate-then-
    repartition (≈280 GB/device/layer of collectives on olmoe train_4k —
    measured, see EXPERIMENTS.md §Perf).

  * expert-parallel shard_map (``_apply_ep``) — selected automatically when
    an ambient shard context is present and the expert count divides the
    "model" axis.  Activations are batch-sharded over ("pod","data") and
    REPLICATED over "model", so dispatch needs no communication at all: each
    model rank selects, from its local tokens, the assignments routed to its
    own E/model experts (local sort, local capacity), runs its expert FFNs,
    and the only collective is one psum over "model" to combine expert
    outputs (+ a psum for the data-sharded router stats).  Capacity is
    enforced per (data-shard × expert) — the standard EP relaxation; with no
    drops the two paths are numerically identical (tested).

Aux losses: switch-style load-balance loss and router z-loss.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import Linear
from repro.sharding import constrain, current_ctx, no_shard_ctx, shard_map
from repro.models.config import MoECfg


class MoE:
    @staticmethod
    def init(key, d_model: int, mcfg: MoECfg, *, param_dtype=jnp.float32):
        kr, kg, ku, kd = jax.random.split(key, 4)
        E, dff = mcfg.n_experts, mcfg.d_ff_expert
        std = (1.0 / d_model) ** 0.5

        def w(k, shape):
            return (std * jax.random.truncated_normal(k, -2.0, 2.0, shape)
                    ).astype(param_dtype)

        params = {
            "router": Linear.init(kr, d_model, E, use_bias=False,
                                  param_dtype=param_dtype),
            "gate": w(kg, (E, d_model, dff)),
            "up": w(ku, (E, d_model, dff)),
            "down": (std * (dff / d_model) ** -0.5
                     * jax.random.truncated_normal(kd, -2.0, 2.0, (E, dff, d_model))
                     ).astype(param_dtype),
        }
        axes = {
            "router": {"w": ("embed", "experts")},
            "gate": ("experts", "embed", "expert_ff"),
            "up": ("experts", "embed", "expert_ff"),
            "down": ("experts", "expert_ff", "embed"),
        }
        return params, axes

    @staticmethod
    def apply(params, x, mcfg: MoECfg, *, dtype=None):
        """x: (B, S, d) → (y, aux) with aux = {"lb_loss", "z_loss", ...}.

        Picks the expert-parallel shard_map path when a shard context is
        active and E divides the "model" axis; falls back to the global
        reference path otherwise (single device, tests, probes)."""
        ctx = current_ctx()
        if ctx is not None:
            _, mesh = ctx
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            m = sizes.get("model", 1)
            batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
            bsh = 1
            for a in batch_axes:
                bsh *= sizes[a]
            if (m > 1 and mcfg.n_experts % m == 0
                    and x.shape[0] % max(bsh, 1) == 0):
                return MoE._apply_ep(params, x, mcfg, mesh, batch_axes,
                                     dtype=dtype)
        return MoE._apply_global(params, x, mcfg, dtype=dtype)

    # ------------------------------------------------------------------
    # shared sort-based dispatch core (operates on whatever token set /
    # expert set it is given — global or per-shard-local)
    # ------------------------------------------------------------------

    @staticmethod
    def _dispatch_compute_combine(xf, flat_e, gate_flat, gate_w, up_w, down_w,
                                  *, n_buckets, C, w_dt, K):
        """xf: (N, d) tokens; flat_e: (N·K,) bucket ids in [0, n_buckets]
        (== n_buckets ⇒ foreign/ignored); gate_flat: (N·K,) combine weights.
        Expert weights: (n_buckets, d, f) / (n_buckets, f, d).
        → (y (N, d) in w_dt, n_dropped scalar, counts (n_buckets,))."""
        N, d = xf.shape
        NK = flat_e.shape[0]
        order = jnp.argsort(flat_e)                      # stable
        sorted_e = flat_e[order]
        token_of = order // K
        counts_all = jnp.bincount(flat_e, length=n_buckets + 1)
        counts = counts_all[:n_buckets]
        offsets_all = jnp.concatenate([jnp.zeros((1,), counts_all.dtype),
                                       jnp.cumsum(counts_all)[:-1]])
        rank_in_e = jnp.arange(NK) - offsets_all[sorted_e]
        slab_idx = offsets_all[:n_buckets, None] + jnp.arange(C)[None, :]
        slab_valid = jnp.arange(C)[None, :] < counts[:, None]
        slab_idx = jnp.clip(slab_idx, 0, NK - 1)
        slab_tok = token_of[slab_idx]                    # (n_buckets, C)

        x_e = jnp.take(xf, slab_tok.reshape(-1), axis=0
                       ).reshape(n_buckets, C, d)
        x_e = x_e * slab_valid[..., None].astype(x_e.dtype)
        x_e = constrain(x_e, ("experts", None, "embed_act"))

        g = jnp.einsum("ecd,edf->ecf", x_e.astype(w_dt), gate_w.astype(w_dt))
        u = jnp.einsum("ecd,edf->ecf", x_e.astype(w_dt), up_w.astype(w_dt))
        h = jax.nn.silu(g) * u
        h = constrain(h, ("experts", None, "expert_ff"))
        y_e = jnp.einsum("ecf,efd->ecd", h, down_w.astype(w_dt))
        y_e = constrain(y_e, ("experts", None, "embed_act"))

        foreign = sorted_e >= n_buckets
        dropped = (rank_in_e >= C) & ~foreign
        dead = dropped | foreign
        src = jnp.where(dead, 0,
                        sorted_e * C + jnp.minimum(rank_in_e, C - 1))
        y_sorted = jnp.take(y_e.reshape(n_buckets * C, d), src, axis=0)
        y_sorted = jnp.where(dead[:, None], 0.0, y_sorted)
        y_sorted = y_sorted * gate_flat[order][:, None].astype(y_sorted.dtype)
        y = jnp.zeros((N, d), y_sorted.dtype).at[token_of].add(y_sorted)
        n_dropped = jnp.sum(jnp.where(dropped, 1.0, 0.0))
        return y, n_dropped, counts

    @staticmethod
    def _router(params, xf, mcfg: MoECfg):
        """→ (top_p (N,K), top_e (N,K), lb_loss, z_loss, mean_probs (E,))."""
        E, K = mcfg.n_experts, mcfg.top_k
        logits = Linear.apply(params, xf.astype(jnp.float32))        # (N, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, K)
        if mcfg.norm_topk:
            top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(
            jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=1),
            axis=0)
        lb_loss = E * jnp.sum(me * ce) / K
        z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
        return top_p, top_e, lb_loss, z_loss

    # ------------------------------------------------------------------
    # global reference path (single device / probes)
    # ------------------------------------------------------------------

    @staticmethod
    def _apply_global(params, x, mcfg: MoECfg, *, dtype=None):
        B, S, d = x.shape
        N = B * S
        E, K = mcfg.n_experts, mcfg.top_k
        xf = x.reshape(N, d)
        top_p, top_e, lb_loss, z_loss = MoE._router(params["router"], xf, mcfg)

        C = int(max(8, round(N * K * mcfg.capacity_factor / E)))
        C = min(C, N * K)
        w_dt = dtype or x.dtype
        y, n_dropped, counts = MoE._dispatch_compute_combine(
            xf, top_e.reshape(-1), top_p.reshape(-1),
            params["gate"], params["up"], params["down"],
            n_buckets=E, C=C, w_dt=w_dt, K=K)

        aux = {
            "lb_loss": lb_loss,
            "z_loss": z_loss,
            "expert_load": counts.astype(jnp.float32) / max(N * K, 1),
            "drop_frac": n_dropped / max(N * K, 1),
        }
        y = constrain(y.reshape(B, S, d), ("batch", None, "embed_act"))
        return y.astype(x.dtype), aux

    # ------------------------------------------------------------------
    # expert-parallel shard_map path
    # ------------------------------------------------------------------

    @staticmethod
    def _apply_ep(params, x, mcfg: MoECfg, mesh, batch_axes, *, dtype=None):
        """Experts sharded over "model"; tokens batch-sharded and model-
        replicated ⇒ dispatch is LOCAL (zero communication) and combine is a
        single psum over "model".  Capacity is per (data-shard × expert)."""
        B, S, d = x.shape
        E, K = mcfg.n_experts, mcfg.top_k
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        m = sizes["model"]
        E_loc = E // m
        bsh = 1
        for a in batch_axes:
            bsh *= sizes[a]
        N_loc = (B // bsh) * S
        C = int(max(8, round(N_loc * K * mcfg.capacity_factor / E)))
        C = min(C, N_loc * K)
        w_dt = dtype or x.dtype
        bspec = batch_axes if len(batch_axes) != 1 else batch_axes[0]

        def body(router_w, gate_w, up_w, down_w, xb):
            with no_shard_ctx():        # body works on explicit blocks
                Bl, Sl, _ = xb.shape
                xf = xb.reshape(Bl * Sl, d)
                top_p, top_e, lb_loss, z_loss = MoE._router(
                    {"w": router_w}, xf, mcfg)
                # my experts: contiguous block [rank·E_loc, (rank+1)·E_loc)
                rank = jax.lax.axis_index("model")
                first = rank * E_loc
                flat_e = top_e.reshape(-1)
                local_e = jnp.where(
                    (flat_e >= first) & (flat_e < first + E_loc),
                    flat_e - first, E_loc)                   # E_loc = foreign
                y_part, n_dropped, counts_loc = MoE._dispatch_compute_combine(
                    xf, local_e, top_p.reshape(-1),
                    gate_w, up_w, down_w, n_buckets=E_loc, C=C, w_dt=w_dt, K=K)
                # combine expert contributions across model ranks (bf16 wire)
                y = jax.lax.psum(y_part, "model")
                # aux: identical across model ranks pre-axis_index → mean
                # over batch shards only; load/drop need both reductions
                nk = N_loc * K * max(bsh, 1)
                load = counts_loc.astype(jnp.float32)
                if batch_axes:
                    lb_loss = jax.lax.pmean(lb_loss, batch_axes)
                    z_loss = jax.lax.pmean(z_loss, batch_axes)
                    load = jax.lax.psum(load, batch_axes)
                    n_dropped = jax.lax.psum(n_dropped, batch_axes)
                # (E_loc,) per model rank → full (E,) everywhere
                load_full = jax.lax.all_gather(load, "model", tiled=True)
                drop = jax.lax.psum(n_dropped, "model") / nk
                aux = {"lb_loss": lb_loss, "z_loss": z_loss,
                       "expert_load": load_full / nk, "drop_frac": drop}
                return y.reshape(Bl, Sl, d).astype(xb.dtype), aux

        fn = shard_map(
            body, mesh=mesh,
            in_specs=(P(), P("model", None, None), P("model", None, None),
                      P("model", None, None), P(bspec, None, None)),
            out_specs=(P(bspec, None, None),
                       {"lb_loss": P(), "z_loss": P(),
                        "expert_load": P(), "drop_frac": P()}),
            check_vma=False)
        router_w = params["router"]["w"]
        y, aux = fn(router_w, params["gate"], params["up"], params["down"], x)
        y = constrain(y, ("batch", None, "embed_act"))
        return y.astype(x.dtype), aux
