"""Model configuration covering all assigned architecture families.

One frozen dataclass describes dense GQA transformers (w/ QKV bias, sliding
window, M-RoPE), MoE transformers, Mamba1/Mamba2 SSMs, the Zamba2 hybrid
(Mamba2 backbone + shared attention blocks), and the Seamless enc-dec
backbone.  Family-specific sub-configs are optional fields.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3
    # True → normalize the top-k probabilities to sum to 1 (OLMoE / Mixtral);
    # False → use raw softmax values (Switch-style).
    norm_topk: bool = True


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int
    version: int = 1            # 1 = Mamba (falcon-mamba), 2 = Mamba2/SSD (zamba2)
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64           # mamba2 only
    n_groups: int = 1           # mamba2 only (B/C groups)
    dt_rank: int = 0            # mamba1; 0 → ceil(d_model / 16)
    chunk: int = 128            # SSD chunk length (kernel + ref chunked path)


@dataclasses.dataclass(frozen=True)
class HybridCfg:
    """Zamba2-style: SSM backbone with a shared attention+MLP block applied
    every ``attn_every`` layers; ``n_shared_blocks`` parameter sets alternate
    round-robin across applications.  The shared block consumes
    concat(hidden, initial_embedding) (2·d_model) as in Zamba."""
    attn_every: int = 6
    n_shared_blocks: int = 2
    first_attn_layer: int = 5   # 0-based index of first layer followed by attn


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0           # 0 → d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    rope_theta: float = 1e6
    m_rope: bool = False        # Qwen2-VL multimodal RoPE
    # fractions of head_dim//2 rotary freqs assigned to (t, h, w) position
    # streams; only used when m_rope=True.
    m_rope_sections: tuple[int, ...] = (16, 24, 24)
    n_vision_patches: int = 0   # vlm: prefix length of precomputed patch embeds

    sliding_window: Optional[int] = None

    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    hybrid: Optional[HybridCfg] = None

    # enc-dec (seamless): n_layers = decoder layers; encoder is bidirectional
    # over precomputed frames (audio frontend stub).
    enc_dec: bool = False
    n_enc_layers: int = 0

    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    use_scan: bool = True
    remat: str = "full"         # none | full
    use_pallas: bool = False    # select Pallas kernels (TPU) vs jnp reference

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        assert self.ssm is not None and self.ssm.version == 2
        return self.d_inner // self.ssm.headdim

    @property
    def dt_rank(self) -> int:
        assert self.ssm is not None
        return self.ssm.dt_rank or math.ceil(self.d_model / 16)

    @property
    def cdtype(self):
        return DTYPES[self.dtype]

    @property
    def pdtype(self):
        return DTYPES[self.param_dtype]

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context with bounded state?"""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None)

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.n_layers
        n = self.vocab * d                       # embedding
        if not self.tie_embeddings:
            n += self.vocab * d                  # lm head
        per_layer = 0
        if self.ssm is not None:
            di, N = self.d_inner, self.ssm.d_state
            if self.ssm.version == 1:
                per_layer += d * 2 * di                       # in_proj
                per_layer += self.ssm.d_conv * di             # conv
                per_layer += di * (self.dt_rank + 2 * N)      # x_proj
                per_layer += self.dt_rank * di + di           # dt_proj
                per_layer += di * N + 2 * di                  # A, D, etc
                per_layer += di * d                           # out_proj
            else:
                H, G = self.ssm_heads, self.ssm.n_groups
                per_layer += d * (2 * di + 2 * G * N + H)     # in_proj
                per_layer += self.ssm.d_conv * (di + 2 * G * N)
                per_layer += 2 * H + di                       # A_log, dt_bias, D
                per_layer += di * d                           # out_proj
            per_layer += d                                    # norm
        attn_params = 0
        if self.n_heads and self.family != "ssm":
            hd = self.hd
            attn_params = d * (self.n_heads * hd) * 2         # q, o
            attn_params += d * (self.n_kv_heads * hd) * 2     # k, v
        if self.family in ("dense", "vlm", "moe", "audio"):
            per_layer += attn_params + 2 * d                  # + norms
            if self.moe is not None:
                per_layer += d * self.moe.n_experts           # router
                per_layer += self.moe.n_experts * 3 * d * self.moe.d_ff_expert
            else:
                per_layer += 3 * d * self.d_ff
        n += L * per_layer
        if self.hybrid is not None:
            n_apps = self.hybrid.n_shared_blocks
            d2 = 2 * d
            shared = d2 * (self.n_heads * self.hd) * 2
            shared += d2 * (self.n_kv_heads * self.hd) * 2
            shared += 3 * d2 * self.d_ff + self.d_ff * 0
            shared += d2 * d                                  # down proj to d
            n += n_apps * shared
        if self.enc_dec:
            # encoder layers: self-attn + mlp
            enc = (attn_params + 3 * 0 + 2 * d * self.d_ff + d * self.d_ff
                   + 2 * d)
            # decoder adds cross-attn per layer
            n += self.n_enc_layers * enc + L * attn_params
        return int(n)

    def active_params(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        dense = self.n_params() - L * self.moe.n_experts * 3 * d * self.moe.d_ff_expert
        return int(dense + L * self.moe.top_k * 3 * d * self.moe.d_ff_expert)


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """Which of the four assigned shapes run for this arch.

    long_500k requires sub-quadratic attention (SSM/hybrid/SWA); pure
    full-attention archs skip it per the assignment rule (recorded in
    DESIGN.md §5)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out
