"""Layer blocks per architecture family.

Every block exposes:
  init(key, cfg) -> (params, axes)            # axes mirrors params
  apply(params, x, cfg, **kw) -> y [, aux]    # full-sequence
  decode(params, x, cfg, state, index, **kw)  # single-token, threaded state
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import Linear, RMSNorm, LayerNorm
from repro.sharding import constrain
from repro.models.attention import Attention
from repro.models.mlp import SwiGLU
from repro.models.moe import MoE
from repro.models.mamba import Mamba1, Mamba2


def _norm_cls(cfg):
    return LayerNorm if cfg.family == "audio" else RMSNorm


class DecoderBlock:
    """Pre-norm attention + (SwiGLU | MoE) — dense, moe, and vlm families."""

    @staticmethod
    def init(key, cfg):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        norm = _norm_cls(cfg)
        attn_p, attn_ax = Attention.init(k1, cfg)
        params = {
            "ln1": norm.init(k2, cfg.d_model, param_dtype=cfg.pdtype),
            "attn": attn_p,
            "ln2": norm.init(k3, cfg.d_model, param_dtype=cfg.pdtype),
        }
        axes = {
            "ln1": jax.tree.map(lambda _: ("embed_act",), params["ln1"]),
            "attn": attn_ax,
            "ln2": jax.tree.map(lambda _: ("embed_act",), params["ln2"]),
        }
        if cfg.moe is not None:
            params["moe"], axes["moe"] = MoE.init(k4, cfg.d_model, cfg.moe,
                                                  param_dtype=cfg.pdtype)
        else:
            params["mlp"], axes["mlp"] = SwiGLU.init(k4, cfg.d_model, cfg.d_ff,
                                                     param_dtype=cfg.pdtype)
        return params, axes

    @staticmethod
    def _ffn(params, x, cfg):
        if cfg.moe is not None:
            return MoE.apply(params["moe"], x, cfg.moe, dtype=cfg.cdtype)
        return SwiGLU.apply(params["mlp"], x, dtype=cfg.cdtype), None

    @staticmethod
    def apply(params, x, cfg, *, angles=None, causal=True):
        norm = _norm_cls(cfg)
        h = norm.apply(params["ln1"], x, eps=cfg.norm_eps)
        h = Attention.apply(params["attn"], h, cfg, angles=angles, causal=causal,
                            window=cfg.sliding_window)
        x = x + h
        h = norm.apply(params["ln2"], x, eps=cfg.norm_eps)
        h, aux = DecoderBlock._ffn(params, h, cfg)
        return x + h, aux

    @staticmethod
    def decode(params, x, cfg, cache, index, *, angles=None, block_tbl=None):
        norm = _norm_cls(cfg)
        h = norm.apply(params["ln1"], x, eps=cfg.norm_eps)
        h, cache = Attention.decode(params["attn"], h, cfg, cache, index,
                                    angles=angles, block_tbl=block_tbl)
        x = x + h
        h = norm.apply(params["ln2"], x, eps=cfg.norm_eps)
        h, _ = DecoderBlock._ffn(params, h, cfg)
        return x + h, cache


class SSMBlock:
    """Pre-norm Mamba block — ssm family and the zamba2 backbone."""

    @staticmethod
    def _impl(cfg):
        return Mamba1 if cfg.ssm.version == 1 else Mamba2

    @staticmethod
    def init(key, cfg):
        k1, k2 = jax.random.split(key)
        m_p, m_ax = SSMBlock._impl(cfg).init(k1, cfg)
        params = {"ln": RMSNorm.init(k2, cfg.d_model, param_dtype=cfg.pdtype),
                  "mamba": m_p}
        axes = {"ln": {"scale": ("embed_act",)}, "mamba": m_ax}
        return params, axes

    @staticmethod
    def apply(params, x, cfg):
        h = RMSNorm.apply(params["ln"], x, eps=cfg.norm_eps)
        return x + SSMBlock._impl(cfg).apply(params["mamba"], h, cfg), None

    @staticmethod
    def decode(params, x, cfg, state, index):
        del index  # SSM state is position-free
        h = RMSNorm.apply(params["ln"], x, eps=cfg.norm_eps)
        y, state = SSMBlock._impl(cfg).decode(params["mamba"], h, cfg, state)
        return x + y, state

    @staticmethod
    def state_shape(cfg, batch):
        return SSMBlock._impl(cfg).state_shape(cfg, batch)


class SharedAttnBlock:
    """Zamba2 shared transformer block: attends over concat(hidden, embed₀)
    (2·d_model); attn + SwiGLU at 2d; a per-application down-projection
    (2d → d) is added to the residual stream (down projections are distinct
    per application, the attn/MLP weights are shared round-robin)."""

    @staticmethod
    def init(key, cfg):
        d2 = 2 * cfg.d_model
        k1, k2, k3, k4 = jax.random.split(key, 4)
        attn_p, attn_ax = Attention.init(k1, cfg, d_in=d2, d_out=d2)
        mlp_p, mlp_ax = SwiGLU.init(k2, d2, cfg.d_ff, param_dtype=cfg.pdtype,
                                    d_out=d2)
        params = {
            "ln1": RMSNorm.init(k3, d2, param_dtype=cfg.pdtype),
            "attn": attn_p,
            "ln2": RMSNorm.init(k4, d2, param_dtype=cfg.pdtype),
            "mlp": mlp_p,
        }
        axes = {
            "ln1": {"scale": ("embed_act",)},
            "attn": attn_ax,
            "ln2": {"scale": ("embed_act",)},
            "mlp": mlp_ax,
        }
        return params, axes

    @staticmethod
    def apply(params, x2, cfg, *, angles=None):
        """x2: (B, S, 2d) → (B, S, 2d)."""
        h = RMSNorm.apply(params["ln1"], x2, eps=cfg.norm_eps)
        h = Attention.apply(params["attn"], h, cfg, angles=angles, causal=True)
        x2 = x2 + h
        h = RMSNorm.apply(params["ln2"], x2, eps=cfg.norm_eps)
        h = SwiGLU.apply(params["mlp"], h, dtype=cfg.cdtype)
        return x2 + h

    @staticmethod
    def decode(params, x2, cfg, cache, index, *, angles=None, block_tbl=None):
        h = RMSNorm.apply(params["ln1"], x2, eps=cfg.norm_eps)
        h, cache = Attention.decode(params["attn"], h, cfg, cache, index,
                                    angles=angles, block_tbl=block_tbl)
        x2 = x2 + h
        h = RMSNorm.apply(params["ln2"], x2, eps=cfg.norm_eps)
        h = SwiGLU.apply(params["mlp"], h, dtype=cfg.cdtype)
        return x2 + h, cache


class EncoderBlock:
    """Bidirectional attention + SwiGLU (seamless encoder; LayerNorm)."""

    @staticmethod
    def init(key, cfg):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        attn_p, attn_ax = Attention.init(k1, cfg)
        mlp_p, mlp_ax = SwiGLU.init(k2, cfg.d_model, cfg.d_ff,
                                    param_dtype=cfg.pdtype)
        params = {
            "ln1": LayerNorm.init(k3, cfg.d_model, param_dtype=cfg.pdtype),
            "attn": attn_p,
            "ln2": LayerNorm.init(k4, cfg.d_model, param_dtype=cfg.pdtype),
            "mlp": mlp_p,
        }
        axes = {
            "ln1": jax.tree.map(lambda _: ("embed_act",), params["ln1"]),
            "attn": attn_ax,
            "ln2": jax.tree.map(lambda _: ("embed_act",), params["ln2"]),
            "mlp": mlp_ax,
        }
        return params, axes

    @staticmethod
    def apply(params, x, cfg, *, angles=None):
        h = LayerNorm.apply(params["ln1"], x, eps=cfg.norm_eps)
        h = Attention.apply(params["attn"], h, cfg, angles=angles, causal=False)
        x = x + h
        h = LayerNorm.apply(params["ln2"], x, eps=cfg.norm_eps)
        return x + SwiGLU.apply(params["mlp"], h, dtype=cfg.cdtype)


class CrossDecoderBlock:
    """Causal self-attn + cross-attn + SwiGLU (seamless decoder)."""

    @staticmethod
    def init(key, cfg):
        ks = jax.random.split(key, 6)
        self_p, self_ax = Attention.init(ks[0], cfg)
        cross_p, cross_ax = Attention.init(ks[1], cfg)
        mlp_p, mlp_ax = SwiGLU.init(ks[2], cfg.d_model, cfg.d_ff,
                                    param_dtype=cfg.pdtype)
        params = {
            "ln1": LayerNorm.init(ks[3], cfg.d_model, param_dtype=cfg.pdtype),
            "self_attn": self_p,
            "ln2": LayerNorm.init(ks[4], cfg.d_model, param_dtype=cfg.pdtype),
            "cross_attn": cross_p,
            "ln3": LayerNorm.init(ks[5], cfg.d_model, param_dtype=cfg.pdtype),
            "mlp": mlp_p,
        }
        ln_ax = lambda p: jax.tree.map(lambda _: ("embed_act",), p)
        axes = {
            "ln1": ln_ax(params["ln1"]), "self_attn": self_ax,
            "ln2": ln_ax(params["ln2"]), "cross_attn": cross_ax,
            "ln3": ln_ax(params["ln3"]), "mlp": mlp_ax,
        }
        return params, axes

    @staticmethod
    def cross_kv(params, enc_out, cfg):
        """Precompute cross K/V from encoder output: (B, S_enc, KV, hd)."""
        B, Se = enc_out.shape[:2]
        k = Linear.apply(params["cross_attn"]["wk"], enc_out, dtype=cfg.cdtype)
        v = Linear.apply(params["cross_attn"]["wv"], enc_out, dtype=cfg.cdtype)
        k = k.reshape(B, Se, cfg.n_kv_heads, cfg.hd)
        v = v.reshape(B, Se, cfg.n_kv_heads, cfg.hd)
        k = constrain(k, ("batch", "enc_seq", "kv_heads", None))
        v = constrain(v, ("batch", "enc_seq", "kv_heads", None))
        return k, v

    @staticmethod
    def apply(params, x, cfg, *, enc_out, angles=None):
        h = LayerNorm.apply(params["ln1"], x, eps=cfg.norm_eps)
        h = Attention.apply(params["self_attn"], h, cfg, angles=angles,
                            causal=True)
        x = x + h
        h = LayerNorm.apply(params["ln2"], x, eps=cfg.norm_eps)
        kv = CrossDecoderBlock.cross_kv(params, enc_out, cfg)
        h = Attention.apply(params["cross_attn"], h, cfg, cross_kv=kv,
                            causal=False)
        x = x + h
        h = LayerNorm.apply(params["ln3"], x, eps=cfg.norm_eps)
        return x + SwiGLU.apply(params["mlp"], h, dtype=cfg.cdtype)

    @staticmethod
    def decode(params, x, cfg, state, index, *, angles=None, cross_len=None,
               block_tbl=None):
        """state = {"self": kv-cache, "cross": precomputed (k, v)}.
        cross_len: optional scalar or (B,) encoder length — cross-K/V
        positions >= cross_len are masked (a max_seq-sized cross pool can
        hold per-slot encoder lengths).  block_tbl routes the SELF cache
        only — cross K/V is written once at admission and stays dense."""
        h = LayerNorm.apply(params["ln1"], x, eps=cfg.norm_eps)
        h, self_cache = Attention.decode(params["self_attn"], h, cfg,
                                         state["self"], index, angles=angles,
                                         block_tbl=block_tbl)
        x = x + h
        h = LayerNorm.apply(params["ln2"], x, eps=cfg.norm_eps)
        h, _ = Attention.decode(params["cross_attn"], h, cfg, None, index,
                                cross_kv=(state["cross"]["k"], state["cross"]["v"]),
                                cross_len=cross_len)
        x = x + h
        h = LayerNorm.apply(params["ln3"], x, eps=cfg.norm_eps)
        x = x + SwiGLU.apply(params["mlp"], h, dtype=cfg.cdtype)
        return x, {"self": self_cache, "cross": state["cross"]}
