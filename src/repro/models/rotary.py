"""Rotary position embeddings, including Qwen2-VL's multimodal M-RoPE.

M-RoPE splits the head_dim//2 rotary frequencies into sections assigned to
(temporal, height, width) position streams.  With the vision frontend stubbed
(assignment rule), patch positions come from a synthetic square grid and text
positions collapse to t=h=w, which is exactly Qwen2-VL's behaviour for
text-only segments.
"""
from __future__ import annotations

import jax.numpy as jnp


def inv_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def section_ids(head_dim: int, sections: tuple[int, ...]) -> jnp.ndarray:
    """Per-frequency stream index in {0..len(sections)-1}; sections sum to
    head_dim//2 (padded with the last stream if short)."""
    half = head_dim // 2
    ids = []
    for s, n in enumerate(sections):
        ids.extend([s] * n)
    while len(ids) < half:
        ids.append(len(sections) - 1)
    return jnp.asarray(ids[:half], jnp.int32)


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float,
                sections: tuple[int, ...] | None = None) -> jnp.ndarray:
    """positions: (B, S) or (B, n_streams, S) for M-RoPE → angles (B, S, hd//2)."""
    freqs = inv_freqs(head_dim, theta)                       # (half,)
    if positions.ndim == 2:
        return positions[..., None].astype(jnp.float32) * freqs
    assert sections is not None
    # (B, n_streams, S, half)
    all_angles = positions[..., None].astype(jnp.float32) * freqs
    ids = section_ids(head_dim, sections)                    # (half,)
    ids = jnp.broadcast_to(ids, all_angles.shape[:1] + all_angles.shape[2:])
    # select per-frequency stream: (B, S, half)
    return jnp.take_along_axis(
        jnp.moveaxis(all_angles, 1, -1),                     # (B, S, half, n_streams)
        ids[..., None], axis=-1)[..., 0]


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, n_heads, head_dim); angles: (B, S, head_dim//2).

    GPT-NeoX style half rotation (matches Llama/Qwen weights layout)."""
    orig_dtype = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(orig_dtype)


def text_positions(batch: int, seq: int, start) -> jnp.ndarray:
    """(B, S) int32 positions starting at ``start`` (scalar or (B,) array)."""
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :]
    start = jnp.asarray(start, jnp.int32)
    start = start.reshape(-1, 1) if start.ndim else start[None, None]
    return jnp.broadcast_to(pos + start, (batch, seq))


def mrope_positions(batch: int, seq: int, n_patches: int, start) -> jnp.ndarray:
    """(B, 3, S) positions: a synthetic √n_patches grid for the vision prefix
    (t=0, h=row, w=col), then t=h=w text positions for the remainder.

    ``start`` is a scalar or a (B,) vector — the serving engine decodes a
    slot-batch whose slots sit at different absolute positions."""
    side = max(int(round(n_patches ** 0.5)), 1)
    idx = jnp.arange(seq, dtype=jnp.int32)
    is_text = idx >= n_patches
    start = jnp.asarray(start, jnp.int32)
    if start.ndim:                                  # per-slot decode positions
        text_pos = start[:, None] + idx[None, :]    # (B, S)
        t = jnp.where(is_text[None], text_pos, 0)
        h = jnp.where(is_text[None], text_pos, (idx // side)[None])
        w = jnp.where(is_text[None], text_pos, (idx % side)[None])
        pos = jnp.stack([t, h, w], axis=1)          # (B, 3, S)
        return jnp.broadcast_to(pos, (batch, 3, seq))
    text_pos = start + idx                          # decode: start offsets all
    t = jnp.where(is_text, text_pos, 0)
    h = jnp.where(is_text, text_pos, idx // side)
    w = jnp.where(is_text, text_pos, idx % side)
    pos = jnp.stack([t, h, w], axis=0)[None]        # (1, 3, S)
    return jnp.broadcast_to(pos, (batch, 3, seq))
