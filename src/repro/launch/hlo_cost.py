"""Post-SPMD HLO analysis: collective wire-bytes and cost_analysis helpers.

collective_bytes() parses ``compiled.as_text()`` (per-device, post-partition
HLO) and estimates bytes moved over the interconnect per device for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
using ring-algorithm wire costs:

  all-gather        (n-1)   × operand      (= (n-1)/n × result)
  reduce-scatter    (n-1)/n × operand
  all-reduce        2(n-1)/n × operand     (ring RS + AG)
  all-to-all        (n-1)/n × operand
  collective-permute  1      × operand

Async pairs (…-start/…-done) are counted once, on the -start op.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<async>-start)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z]+\d*)\[(?P<dims>[\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # replica_groups=[G,n]<=[...] iota form: G groups of n
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).strip("{}")
        if first:
            return max(len(first.split(",")), 1)
    return 1


def collective_bytes(hlo_text: str):
    """→ (total wire bytes per device, per-op-kind breakdown dict).

    Post-SPMD HLO prints operands as bare %names, so wire bytes are derived
    from the RESULT shape and the group size n:
      all-gather      operand = result/n  → wire = (n-1)/n · result
      reduce-scatter  operand = n·result  → wire = (n-1) · result
      all-reduce      operand = result    → wire = 2(n-1)/n · result
      all-to-all      operand = result    → wire = (n-1)/n · result
      collective-permute                  → wire = result
    """
    per_kind: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    promoted_excess = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        result = _shape_bytes(m.group("result"))
        if op == "collective-permute":
            per_kind[op] += result
            counts[op] += 1
            continue
        n = _group_size(line)
        if n <= 1:
            continue
        if op == "all-gather":
            wire = (n - 1) / n * result
        elif op == "reduce-scatter":
            wire = (n - 1) * result
        elif op == "all-reduce":
            wire = 2 * (n - 1) / n * result
        else:  # all-to-all
            wire = (n - 1) / n * result
        # XLA:CPU promotes bf16 reductions to f32 on the wire
        # (to_apply=…_promoted); TPU reduces native bf16.  Raw totals keep
        # the promoted width (comparable across runs on this backend); the
        # detail reports how much a TPU would shave off.
        if "_promoted" in line and "f32[" in m.group("result"):
            promoted_excess += wire / 2
        per_kind[op] += wire
        counts[op] += 1
    total = float(sum(per_kind.values()))
    return total, {"bytes": dict(per_kind), "counts": dict(counts),
                   "tpu_corrected_total": total - promoted_excess}


def cost_summary(compiled) -> dict:
    """Extract flops / bytes from compiled.cost_analysis(), tolerating
    backend differences in key naming."""
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"flops": 0.0, "bytes": 0.0, "error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
    return {"flops": flops, "bytes": byts}


def memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out
