"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (smoke tests see 1 device; only dryrun.py forces
512 host devices).

Axes:
  pod   — data parallelism across pods; gradient all-reduce crosses DCI,
          which is why it is the *last* axis collectives are scheduled on
          (launch/train.py hierarchical all-reduce).
  data  — within-pod data parallelism / FSDP.
  model — tensor / expert parallelism (highest-bandwidth ICI dimension).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Elastic variant: any (shape, axes) pair — used by launch/elastic.py to
    re-mesh after node loss/gain and by tests for small device counts."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_chips(mesh) -> int:
    return int(mesh.devices.size)
