"""Production mesh construction — single-host and multi-process.

FUNCTIONS, not module-level constants, so importing this module never
touches jax device state (smoke tests see 1 device; only dryrun.py forces
512 host devices).

Axes:
  pod   — data parallelism across pods; gradient all-reduce crosses DCI,
          which is why it is the *last* axis collectives are scheduled on
          (launch/train.py hierarchical all-reduce).
  data  — within-pod data parallelism / FSDP.
  model — tensor / expert parallelism (highest-bandwidth ICI dimension).

Multi-process (one serving pod spanning hosts):

  ``init_distributed`` wraps ``jax.distributed.initialize`` idempotently —
  coordinator address, process count, and rank are plumbed from config
  (``worker.py --pod-rank/--coordinator``), never discovered ambiently.
  After it runs, ``jax.devices()`` is the GLOBAL device list and
  ``make_pod_mesh`` lays a ("data", "model") mesh over it with the "model"
  axis varying across processes — one logical replica whose weights and KV
  cache span hosts.

  Not every backend can place one program across processes (the CPU
  backend forms the cluster but raises at dispatch); ``spmd_across_
  processes`` probes this ONCE with a tiny cross-process computation so
  callers can degrade deterministically (every rank reaches the same
  verdict — same backend everywhere) instead of dying mid-serve.
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Elastic variant: any (shape, axes) pair — used by launch/elastic.py to
    re-mesh after node loss/gain and by tests for small device counts."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_chips(mesh) -> int:
    return int(mesh.devices.size)


# ---------------------------------------------------------------------------
# multi-process pods (jax.distributed)
# ---------------------------------------------------------------------------

_DIST = {"initialized": False}


def init_distributed(coordinator: str, num_processes: int, process_id: int,
                     *, timeout_s: int = 120) -> int:
    """Join (or form) a jax.distributed cluster; returns this process's
    rank.  Idempotent: a pod worker re-initialized by a second router
    attach must not crash on "already initialized" — the cluster outlives
    any one control connection."""
    if _DIST["initialized"]:
        return int(jax.process_index())
    try:
        jax.distributed.initialize(
            coordinator_address=coordinator,
            num_processes=int(num_processes),
            process_id=int(process_id),
            initialization_timeout=int(timeout_s))
    except RuntimeError as e:
        # another caller on this process won the race — that is fine; any
        # other failure (coordinator unreachable, rank clash) is not
        if "already initialized" not in str(e).lower():
            raise
    _DIST["initialized"] = True
    return int(jax.process_index())


def shutdown_distributed():
    if not _DIST["initialized"]:
        return
    _DIST["initialized"] = False
    try:
        jax.distributed.shutdown()
    except RuntimeError:
        pass


def make_pod_mesh(*, data: int = 1, devices=None):
    """The serving-pod mesh: ("data", "model") over every visible device —
    after ``init_distributed`` that is the whole cluster, and the device
    list is process-major, so with ``data=1`` the "model" axis runs across
    process boundaries (the multi-host tensor-parallel dimension).  Built
    with an explicit device arrangement, NOT ``jax.make_mesh`` — the
    performance-driven reordering there could fold the model axis back
    inside one host."""
    from jax.sharding import Mesh

    devices = list(jax.devices()) if devices is None else list(devices)
    n = len(devices)
    if data < 1 or n % data != 0:
        raise ValueError(f"{n} devices do not divide over data={data}")
    arr = np.array(devices, dtype=object).reshape(data, n // data)
    return Mesh(arr, ("data", "model"))


def local_pod_mesh(*, axis: str = "model"):
    """This process's share of a pod as a one-axis mesh over its LOCAL
    devices — the degraded (mirror) layout used when the backend cannot
    place one program across processes: every rank runs the full replica
    in lockstep on its own devices (see worker.py pod mode)."""
    from jax.sharding import Mesh

    arr = np.array(jax.local_devices(), dtype=object)
    return Mesh(arr, (axis,))


_SPMD_PROBE = {}


def spmd_across_processes() -> bool:
    """Can one jitted computation span every process of the cluster?

    True trivially for a single-process cluster.  Otherwise probe with a
    tiny addition over the global mesh: backends without cross-process
    dispatch (CPU as of jax 0.4.x) raise at compile/dispatch time, on
    every rank, deterministically — which is exactly the property that
    lets each rank pick the same pod mode without a vote."""
    if jax.process_count() == 1:
        return True
    if "ok" in _SPMD_PROBE:
        return _SPMD_PROBE["ok"]
    from jax.sharding import NamedSharding, PartitionSpec as P

    try:
        mesh = make_pod_mesh()
        n = mesh.devices.size
        sh = NamedSharding(mesh, P(None, "model"))
        x = jax.make_array_from_callback(
            (1, n), sh, lambda idx: np.ones((1, 1), np.float32))
        jax.jit(lambda v: v + 1, out_shardings=sh)(x)
        _SPMD_PROBE["ok"] = True
    except Exception:
        _SPMD_PROBE["ok"] = False
    return _SPMD_PROBE["ok"]
