"""Serving driver: batched prefill + decode with a KV-cache pool.

Demonstrates the data plane the MLOps control plane manages: requests arrive
(Poisson), a continuous batcher admits them into fixed decode slots, prefill
fills each slot's cache region, and the decode step advances all active slots
one token per tick.  Per-request latency (p50/p95), throughput, and slot
utilization are reported — the same metrics the paper's monitoring stream
consumes (core/monitoring).

Runnable at CPU scale:  PYTHONPATH=src python -m repro.launch.serve \
    --arch qwen2.5-3b --smoke --requests 24 --max-seq 96
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import LM
from repro.models.steps import make_decode_step, make_prefill_step


class ServingEngine:
    """Single-replica engine with S decode slots over one shared cache pytree.

    Slot-batched decode: every tick decodes a (S, 1) token batch; finished
    slots are refilled from the queue via per-slot prefill.  (Real multi-host
    serving shards the same cache via SERVE_RULES — see launch/dryrun.py's
    decode cells; this driver exercises the logic end to end on CPU.)
    """

    def __init__(self, cfg, *, slots: int, max_seq: int, seed: int = 0):
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        params, _ = LM.init(jax.random.PRNGKey(seed), cfg)
        self.params = params
        self.prefill = jax.jit(make_prefill_step(cfg, max_seq))
        self.decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))
        self.cache = LM.init_cache(cfg, slots, max_seq)
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self.pos = np.zeros(slots, np.int64)        # per-slot position
        self.remaining = np.zeros(slots, np.int64)  # tokens left to generate
        self.active = np.zeros(slots, bool)

    def admit(self, slot: int, prompt: np.ndarray, gen_len: int):
        """Prefill one slot.  Single-slot prefill then merged into the pool
        cache at this slot index (per-slot cache update)."""
        inputs = {"tokens": jnp.asarray(prompt[None])}
        if self.cfg.family == "vlm":
            inputs["patches"] = jnp.zeros(
                (1, self.cfg.n_vision_patches, self.cfg.d_model), self.cfg.cdtype)
        if self.cfg.enc_dec:
            inputs["frames"] = jnp.zeros(
                (1, len(prompt), self.cfg.d_model), self.cfg.cdtype)
        logits, cache1 = self.prefill(self.params, inputs)
        # write slot: every cache leaf has batch at a known axis per family
        self.cache = jax.tree.map(
            lambda pool, one: _write_slot(pool, one, slot), self.cache, cache1)
        tok = jnp.argmax(logits[:, -1], axis=-1)
        self.tokens = self.tokens.at[slot, 0].set(tok[0].astype(jnp.int32))
        self.pos[slot] = len(prompt)
        self.remaining[slot] = gen_len
        self.active[slot] = True

    def tick(self):
        """One decode step for all slots (inactive slots decode garbage that
        is simply ignored — the fixed-shape batch is the TPU-friendly form)."""
        logits, self.cache = self.decode(self.params, self.tokens, self.cache)
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
        self.tokens = nxt
        self.pos[self.active] += 1
        self.remaining[self.active] -= 1
        done = self.active & (self.remaining <= 0)
        self.active &= ~done
        return list(np.nonzero(done)[0])


def _write_slot(pool, one, slot):
    if pool.ndim == 0:      # index scalar: keep pool's (max over slots)
        return jnp.maximum(pool, one)
    # find the batch axis: the axis where pool == slots and one == 1
    for ax in range(pool.ndim):
        if one.shape[ax] == 1 and pool.shape[ax] != one.shape[ax]:
            idx = [slice(None)] * pool.ndim
            idx[ax] = slice(slot, slot + 1)
            return pool.at[tuple(idx)].set(one.astype(pool.dtype))
    return pool


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--arrival-rps", type=float, default=100.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    eng = ServingEngine(cfg, slots=args.slots, max_seq=args.max_seq,
                        seed=args.seed)
    rng = np.random.default_rng(args.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / args.arrival_rps, args.requests))
    prompts = [rng.integers(3, cfg.vocab, size=args.prompt_len) for _ in range(args.requests)]

    t0 = time.time()
    submitted = 0
    lat = {}
    t_start = {}
    finished = 0
    queue = []
    while finished < args.requests:
        now = time.time() - t0
        while submitted < args.requests and arrivals[submitted] <= now:
            queue.append(submitted)
            submitted += 1
        free = [s for s in range(args.slots) if not eng.active[s]]
        while queue and free:
            rid, slot = queue.pop(0), free.pop(0)
            t_start[rid] = arrivals[rid]
            eng.admit(slot, prompts[rid].astype(np.int32), args.gen_len)
            eng.slot_owner = getattr(eng, "slot_owner", {})
            eng.slot_owner[slot] = rid
        if eng.active.any():
            for slot in eng.tick():
                rid = eng.slot_owner[slot]
                lat[rid] = (time.time() - t0) - t_start[rid]
                finished += 1
        else:
            time.sleep(0.001)

    total = time.time() - t0
    lats = np.array(sorted(lat.values()))
    toks = args.requests * args.gen_len
    print(f"requests={args.requests} gen_tokens={toks} wall={total:.2f}s "
          f"throughput={toks / total:.1f} tok/s")
    print(f"latency p50={np.percentile(lats, 50) * 1e3:.0f}ms "
          f"p95={np.percentile(lats, 95) * 1e3:.0f}ms")
    return 0


if __name__ == "__main__":
    sys.exit(main())
