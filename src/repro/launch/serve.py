"""Serving driver CLI over the repro.serving subsystem.

The engine itself lives in repro/serving/ (continuous batching, chunked
prefill, per-slot ring positions, seeded sampling); this module keeps the
seed's CLI surface and re-exports ServingEngine/_write_slot for backward
compatibility.  Per-request latency (p50/p95), throughput, and slot
utilization are reported — the same metrics the paper's monitoring stream
consumes (core/monitoring).

Runnable at CPU scale:  PYTHONPATH=src python -m repro.launch.serve \
    --arch qwen2.5-3b --smoke --requests 24 --max-seq 96
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.serving import SamplingParams, ServingEngine, synthetic_requests
from repro.serving.slots import write_slot as _write_slot  # noqa: F401 (compat)
from repro.sim.serving import WorkloadSpec

__all__ = ["ServingEngine", "_write_slot", "main"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="stream prompts through the decode tick in chunks")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--arrival-rps", type=float, default=100.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    eng = ServingEngine(cfg, slots=args.slots, max_seq=args.max_seq,
                        seed=args.seed, prefill_chunk=args.prefill_chunk)
    rng = np.random.default_rng(args.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / args.arrival_rps,
                                         args.requests))
    spec = WorkloadSpec(prompt_len=args.prompt_len, gen_len=args.gen_len)
    sampling = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                              seed=args.seed)
    requests = synthetic_requests(spec, args.requests, cfg.vocab, rng=rng,
                                  sampling=sampling)

    t0 = time.time()
    submitted = 0
    finished: list = []
    while len(finished) < args.requests:
        now = time.time() - t0
        while submitted < args.requests and arrivals[submitted] <= now:
            eng.submit(requests[submitted], now=arrivals[submitted])
            submitted += 1
        if eng.idle:
            time.sleep(0.001)
            continue
        finished.extend(eng.step(now=time.time() - t0))

    total = time.time() - t0
    lats = np.array(sorted(r.latency_s for r in finished))
    toks = sum(len(r.tokens_out) for r in finished)
    print(f"requests={args.requests} gen_tokens={toks} wall={total:.2f}s "
          f"throughput={toks / total:.1f} tok/s")
    print(f"latency p50={np.percentile(lats, 50) * 1e3:.0f}ms "
          f"p95={np.percentile(lats, 95) * 1e3:.0f}ms "
          f"slot_util={eng.stats.slot_utilization:.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
