"""Elastic re-mesh: restore a checkpoint onto a different mesh topology.

When the control plane's allocator grows/shrinks a training job (or a node
fails and the slice is rebuilt smaller), the data axis extent changes:
(data=16, model=16) → (data=12, model=16).  Because every parameter's
placement is derived from *logical* axis rules (repro/sharding), re-meshing
is: build the new mesh → recompute NamedShardings from the same rules →
CheckpointManager.restore(..., shardings=new) → rebuild the jitted step.
Nothing about the model or step code changes.

This module is also the programmatic surface the MLOps control plane calls:
its scaling actions (core/scaling) emit ReMesh(data_axis=N) events which map
1:1 onto `elastic_restore`.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.checkpoint import CheckpointManager
from repro.launch.mesh import make_mesh
from repro.models.steps import (
    TrainState, make_train_step, params_axes_and_structs, train_state_axes,
)
from repro.optim.adamw import AdamWState
from repro.sharding import TRAIN_RULES, shard_ctx, tree_shardings


@dataclasses.dataclass(frozen=True)
class ReMesh:
    """A control-plane scaling action on a training job."""
    data_axis: int
    model_axis: int
    pods: int = 1

    def mesh(self):
        if self.pods > 1:
            return make_mesh((self.pods, self.data_axis, self.model_axis),
                             ("pod", "data", "model"))
        return make_mesh((self.data_axis, self.model_axis), ("data", "model"))


def state_shardings(cfg, mesh, rules=TRAIN_RULES):
    """NamedShardings for the full TrainState on ``mesh``."""
    import jax.numpy as jnp
    _, params_structs = params_axes_and_structs(cfg)
    state_structs = TrainState(
        params=params_structs,
        opt_state=AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                            params_structs),
            nu=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                            params_structs)),
        step=jax.ShapeDtypeStruct((), jnp.int32))
    return tree_shardings(train_state_axes(cfg), rules, mesh,
                          shapes_tree=state_structs), state_structs


def elastic_restore(ckpt_root: str, cfg, action: ReMesh, *, lr=3e-4,
                    rules=TRAIN_RULES, step: int | None = None):
    """→ (state on the new mesh, jitted train_step, mesh)."""
    mesh = action.mesh()
    shardings, structs = state_shardings(cfg, mesh, rules)
    mgr = CheckpointManager(ckpt_root)
    state, manifest = mgr.restore(structs, step=step, shardings=shardings)

    step_fn, _ = make_train_step(cfg, lr=lr)

    def sharded_step(st, batch):
        with shard_ctx(rules, mesh):
            return step_fn(st, batch)

    jitted = jax.jit(sharded_step, in_shardings=(shardings, None),
                     out_shardings=(shardings, None), donate_argnums=(0,))
    return state, jitted, mesh
