"""End-to-end trainer: data pipeline → sharded train step → async checkpoints.

Runnable at CPU scale with the reduced configs (--smoke) and at production
scale on a real pod (same code path, bigger mesh).  Fault tolerance:
  * async checkpoint every --ckpt-every steps (atomic commit),
  * SIGTERM/SIGINT (preemption) triggers a final checkpoint before exit,
  * resume restores params/optimizer/step and fast-forwards the counted data
    pipeline — byte-identical batches after restart,
  * optional error-feedback int8 gradient compression for the cross-pod
    all-reduce (--compress-grads; see repro/optim/compression.py).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 30 --seq 128 --batch 8 --ckpt-dir /tmp/ckpt
  DRYRUN_DEVICES=8 PYTHONPATH=src python -m repro.launch.train --arch \
      olmoe-1b-7b --smoke --steps 10 --mesh 2,4
"""
from __future__ import annotations

import argparse
import json
import signal
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, TokenPipeline, extra_inputs
from repro.launch.mesh import make_mesh
from repro.models.steps import (
    TrainState, init_train_state, make_train_step, train_state_axes,
)
from repro.sharding import TRAIN_RULES, shard_ctx, tree_shardings


def build(cfg, *, lr, mesh=None):
    step_fn, (opt_init, opt_update) = make_train_step(cfg, lr=lr)
    if mesh is None:
        return jax.jit(step_fn), opt_init, None

    rules = TRAIN_RULES

    def sharded_step(state, batch):
        with shard_ctx(rules, mesh):
            return step_fn(state, batch)

    state_axes = train_state_axes(cfg)

    def make_shardings(state):
        return tree_shardings(state_axes, rules, mesh, shapes_tree=state)

    return (lambda st_sh: jax.jit(
        sharded_step, in_shardings=(st_sh, None), out_shardings=(st_sh, None),
        donate_argnums=(0,))), opt_init, make_shardings


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None, help="e.g. 2,4 → (data, model)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log", default=None)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    mesh = None
    if args.mesh:
        shp = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_mesh(shp, ("data", "model")[:len(shp)])

    data = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch, seed=args.seed))

    step_builder, opt_init, make_shardings = build(cfg, lr=args.lr, mesh=mesh)
    state = init_train_state(jax.random.PRNGKey(args.seed), cfg, opt_init)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and args.resume and ckpt.latest_step() is not None:
        state, manifest = ckpt.restore(state)
        print(f"resumed from step {manifest['step']}", flush=True)

    if mesh is not None:
        shardings = make_shardings(state)
        state = jax.device_put(state, shardings)
        train_step = step_builder(shardings)
    else:
        train_step = step_builder

    stop = {"flag": False}

    def _on_signal(sig, frame):
        print(f"signal {sig}: checkpoint + exit", flush=True)
        stop["flag"] = True

    signal.signal(signal.SIGTERM, _on_signal)
    signal.signal(signal.SIGINT, _on_signal)

    logf = open(args.log, "a") if args.log else None
    start_step = int(jax.device_get(state.step))
    t_prev = time.time()
    for step in range(start_step, args.steps):
        batch_np = extra_inputs(cfg, data.batch(step))
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        state, metrics = train_step(state, batch)
        if stop["flag"]:
            break
        if step % 10 == 0 or step == args.steps - 1:
            m = {k: float(jax.device_get(v)) for k, v in metrics.items()}
            dt = time.time() - t_prev
            t_prev = time.time()
            rec = {"step": step + 1, **m, "sec": round(dt, 3)}
            print(json.dumps(rec), flush=True)
            if logf:
                logf.write(json.dumps(rec) + "\n")
                logf.flush()
        if ckpt and ((step + 1) % args.ckpt_every == 0):
            ckpt.save(step + 1, state)
    final_step = int(jax.device_get(state.step))
    if ckpt:
        ckpt.save(final_step, state, blocking=True)
        print(f"checkpointed step {final_step}", flush=True)
    if logf:
        logf.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
