import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           (os.environ.get("DRYRUN_DEVICES") or "512")).strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is how the framework proves its distribution config is coherent without
real hardware: for each assigned architecture and each of its input shapes,
the step function (train_step / prefill_step / decode_step) is jitted with
explicit NamedShardings on the production mesh — 16×16 ("data","model")
single-pod and 2×16×16 ("pod","data","model") multi-pod — lowered from
ShapeDtypeStruct stand-ins (no allocation), and compiled.  Failures here
(sharding mismatch, unsupported collective) are bugs in the system.

Outputs per cell (JSON, resumable): memory_analysis, cost_analysis
(FLOPs/bytes), and the per-device collective wire-bytes parsed from the
post-SPMD HLO — the inputs to EXPERIMENTS.md §Roofline.

Because jax.lax.scan bodies are counted ONCE by cost_analysis, --probe
additionally compiles python-unrolled 2- and 4-layer variants (full width,
full batch, single-pod) and linear-fits  total = base + L · per_layer  for
FLOPs / bytes / collective bytes — the numbers the roofline table uses.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both --probe
  DRYRUN_DEVICES=8 python -m repro.launch.dryrun --arch qwen2.5-3b \
      --shape train_4k --mesh single --mesh-shape 2,4 --probe
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.hlo_cost import collective_bytes, cost_summary, memory_summary
from repro.launch.mesh import make_mesh, make_production_mesh, mesh_chips
from repro.models import SHAPES, applicable_shapes
from repro.models.steps import (
    TrainState,
    cache_axes,
    cache_structs,
    input_sharding_axes,
    input_structs,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    params_axes_and_structs,
    train_state_axes,
)
from repro.optim.adamw import AdamWState
from repro.sharding import (
    SERVE_RULES, TRAIN_RULES, serve_rules, shard_ctx, spec_for,
    tree_shardings,
)


def _shardings(axes_tree, rules, mesh, structs):
    return tree_shardings(axes_tree, rules, mesh, shapes_tree=structs)


def lower_cell(cfg, shape_name: str, mesh, *, donate: bool = True):
    """Build + jit + lower one cell; returns (lowered, structs kwargs)."""
    shape = SHAPES[shape_name]
    rules = (TRAIN_RULES if shape.kind == "train"
             else serve_rules(shape.global_batch))
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        step, (opt_init, _) = make_train_step(cfg)

        def train_step(state, batch):
            with shard_ctx(rules, mesh):
                return step(state, batch)

        params_axes, params_structs = params_axes_and_structs(cfg)
        state_structs = TrainState(
            params=params_structs,
            opt_state=AdamWState(
                step=jax.ShapeDtypeStruct((), jnp.int32),
                mu=jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                    params_structs),
                nu=jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                    params_structs)),
            step=jax.ShapeDtypeStruct((), jnp.int32))
        state_sh = _shardings(train_state_axes(cfg), rules, mesh, state_structs)
        batch_structs = input_structs(cfg, shape)
        batch_sh = _shardings(
            {k: v for k, v in input_sharding_axes(cfg, with_labels=True).items()
             if k in batch_structs},
            rules, mesh, batch_structs)
        fn = jax.jit(train_step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, repl),
                     donate_argnums=(0,) if donate else ())
        return fn.lower(state_structs, batch_structs)

    params_axes, params_structs = params_axes_and_structs(cfg)
    # serving deployments stream bf16 weights (fp32 masters live with the
    # trainer) — halves both HBM footprint and any weight movement
    params_structs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, cfg.cdtype), params_structs)
    params_sh = _shardings(params_axes, rules, mesh, params_structs)

    if shape.kind == "prefill":
        pstep = make_prefill_step(cfg, max_seq=shape.seq_len)

        def prefill_step(params, batch):
            with shard_ctx(rules, mesh):
                return pstep(params, batch)

        batch_structs = input_structs(cfg, shape)
        batch_sh = _shardings(
            {k: v for k, v in input_sharding_axes(cfg, with_labels=False).items()
             if k in batch_structs},
            rules, mesh, batch_structs)
        c_structs = cache_structs(cfg, shape.global_batch, shape.seq_len)
        cache_sh = _shardings(cache_axes(cfg, shape.global_batch, shape.seq_len),
                              rules, mesh, c_structs)
        fn = jax.jit(prefill_step, in_shardings=(params_sh, batch_sh),
                     out_shardings=(repl, cache_sh))
        return fn.lower(params_structs, batch_structs)

    # decode
    dstep = make_decode_step(cfg)

    def decode_step(params, tokens, cache):
        with shard_ctx(rules, mesh):
            return dstep(params, tokens, cache)

    structs = input_structs(cfg, shape)
    c_structs = structs["cache"]
    cache_sh = _shardings(cache_axes(cfg, shape.global_batch, shape.seq_len),
                          rules, mesh, c_structs)
    tok_sh = NamedSharding(
        mesh, spec_for(("batch", "seq"), rules, mesh, (shape.global_batch, 1)))
    fn = jax.jit(decode_step, in_shardings=(params_sh, tok_sh, cache_sh),
                 out_shardings=(repl, cache_sh),
                 donate_argnums=(2,) if donate else ())
    return fn.lower(params_structs, structs["tokens"], c_structs)


def analyze_cell(cfg, shape_name: str, mesh) -> dict:
    t0 = time.time()
    lowered = lower_cell(cfg, shape_name, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    hlo = compiled.as_text()
    coll, coll_detail = collective_bytes(hlo)
    out = {
        "arch": cfg.name,
        "shape": shape_name,
        "mesh": list(mesh.devices.shape),
        "chips": mesh_chips(mesh),
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "cost": cost_summary(compiled),
        "memory": memory_summary(compiled),
        "collective_bytes": coll,
        "collective_detail": coll_detail,
        "hlo_bytes": len(hlo),
    }
    return out


def probe_cfgs(cfg):
    """(L_small, L_big, cfg_small, cfg_big, unit_count_full): unrolled no-remat
    variants for the linear FLOP fit.  Unit = layer (dense/moe/ssm), group
    (hybrid), or enc+dec layer pair (enc-dec)."""
    if cfg.hybrid is not None:
        a = cfg.hybrid.attn_every
        mk = lambda g: dataclasses.replace(cfg, n_layers=g * a, use_scan=False,
                                           remat="none")
        return 1, 2, mk(1), mk(2), cfg.n_layers // a
    if cfg.enc_dec:
        mk = lambda L: dataclasses.replace(cfg, n_layers=L, n_enc_layers=L,
                                           use_scan=False, remat="none")
        return 1, 2, mk(1), mk(2), cfg.n_layers
    mk = lambda L: dataclasses.replace(cfg, n_layers=L, use_scan=False,
                                       remat="none")
    return 2, 4, mk(2), mk(4), cfg.n_layers


def probe_cell(cfg, shape_name: str, mesh) -> dict:
    """Linear-fit per-unit flops/bytes/collectives from unrolled compiles."""
    n_small, n_big, cfg_small, cfg_big, units = probe_cfgs(cfg)
    res = {}
    for tag, c, n in (("small", cfg_small, n_small), ("big", cfg_big, n_big)):
        lowered = lower_cell(c, shape_name, mesh, donate=False)
        compiled = lowered.compile()
        coll, _ = collective_bytes(compiled.as_text())
        cost = cost_summary(compiled)
        res[tag] = {"n": n, "flops": cost["flops"], "bytes": cost["bytes"],
                    "coll": coll}
    fit = {}
    for key in ("flops", "bytes", "coll"):
        per = (res["big"][key] - res["small"][key]) / (n_big - n_small)
        base = res["small"][key] - n_small * per
        fit[key] = {"per_unit": per, "base": base,
                    "total": base + units * per}
    fit["units"] = units
    fit["raw"] = res
    return fit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--mesh-shape", default=None,
                    help="override mesh, e.g. 2,4 (single) or 2,2,2 (multi)")
    ap.add_argument("--probe", action="store_true",
                    help="also compile unrolled L-probes (single-pod only)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    meshes = {}
    if args.mesh in ("single", "both"):
        if args.mesh_shape and args.mesh != "multi":
            shp = tuple(int(x) for x in args.mesh_shape.split(","))
            meshes["single"] = make_mesh(shp, ("data", "model")[:len(shp)])
        else:
            meshes["single"] = make_production_mesh(multi_pod=False)
    if args.mesh in ("multi", "both"):
        if args.mesh_shape and args.mesh == "multi":
            shp = tuple(int(x) for x in args.mesh_shape.split(","))
            meshes["multi"] = make_mesh(shp, ("pod", "data", "model"))
        else:
            meshes["multi"] = make_production_mesh(multi_pod=True)

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = applicable_shapes(cfg)
        if args.shape != "all":
            shapes = [s for s in args.shape.split(",") if s in shapes]
        for shape_name in shapes:
            for mesh_tag, mesh in meshes.items():
                cell_id = f"{arch}__{shape_name}__{mesh_tag}"
                path = outdir / f"{cell_id}.json"
                if path.exists() and not args.force:
                    n_skip += 1
                    continue
                print(f"=== {cell_id} ===", flush=True)
                try:
                    rec = analyze_cell(cfg, shape_name, mesh)
                    if args.probe and mesh_tag == "single":
                        rec["probe"] = probe_cell(cfg, shape_name, mesh)
                    path.write_text(json.dumps(rec, indent=1))
                    print(f"  ok: compile={rec['compile_s']}s "
                          f"flops={rec['cost']['flops']:.3e} "
                          f"coll={rec['collective_bytes']:.3e}B", flush=True)
                    n_ok += 1
                except Exception:
                    n_fail += 1
                    err = traceback.format_exc()
                    (outdir / f"{cell_id}.FAILED").write_text(err)
                    print(f"  FAILED:\n{err}", flush=True)
    print(f"done: ok={n_ok} fail={n_fail} skip={n_skip}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
