from repro.data.pipeline import DataConfig, TokenPipeline, extra_inputs

__all__ = ["DataConfig", "TokenPipeline", "extra_inputs"]
