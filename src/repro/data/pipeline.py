"""Deterministic, counted token pipeline → preemption-safe resume.

The stream is a pure function of (seed, step): after restart, setting the
step counter reproduces exactly the batches that would have followed — no
data-loader state needs checkpointing beyond the integer step (stored in the
train state).  Synthetic text is drawn from a Zipf distribution with document
structure (BOS/EOS segmentation) so the CE loss has realistic token
statistics; a memory-mapped token file can be substituted for real corpora.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    mean_doc_len: int = 512
    bos_id: int = 1
    eos_id: int = 2
    token_file: str | None = None


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._mm = None
        if cfg.token_file:
            self._mm = np.memmap(cfg.token_file, dtype=np.int32, mode="r")

    def batch(self, step: int):
        """→ {"tokens", "labels"}: (B, S) int32.  Pure in (seed, step)."""
        cfg = self.cfg
        if self._mm is not None:
            return self._file_batch(step)
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        toks = rng.zipf(cfg.zipf_a, size=(B, S + 1)).astype(np.int64)
        toks = (toks - 1) % (cfg.vocab - 3) + 3          # reserve 0,1,2
        # document boundaries: geometric lengths
        n_docs = max(2, (S + 1) // cfg.mean_doc_len + 2)
        for b in range(B):
            cuts = rng.geometric(1.0 / cfg.mean_doc_len, size=n_docs).cumsum()
            cuts = cuts[cuts < S]
            toks[b, cuts] = cfg.eos_id
        toks[:, 0] = cfg.bos_id
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :S], "labels": toks[:, 1:S + 1]}

    def _file_batch(self, step: int):
        cfg = self.cfg
        B, S = cfg.global_batch, cfg.seq_len
        n = len(self._mm) - (S + 1)
        rng = np.random.default_rng((cfg.seed, step))
        starts = rng.integers(0, n, size=B)
        toks = np.stack([self._mm[s:s + S + 1] for s in starts]).astype(np.int32)
        return {"tokens": toks[:, :S], "labels": toks[:, 1:S + 1]}


def extra_inputs(cfg_model, batch_np):
    """Family-specific extras (vision patches / audio frames) as synthetic
    embeddings, deterministic in the token content."""
    import numpy as np
    out = dict(batch_np)
    B, S = batch_np["tokens"].shape
    if cfg_model.family == "vlm":
        rng = np.random.default_rng(int(batch_np["tokens"][0, 0]))
        out["patches"] = rng.standard_normal(
            (B, cfg_model.n_vision_patches, cfg_model.d_model)).astype(np.float32)
    if cfg_model.enc_dec:
        rng = np.random.default_rng(int(batch_np["tokens"][0, 0]) + 1)
        out["frames"] = rng.standard_normal(
            (B, S, cfg_model.d_model)).astype(np.float32)
    return out
