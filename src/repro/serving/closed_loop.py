"""The closed control loop as one reusable driver.

examples/serve_autoscale.py (the demo) and benchmarks/serving_latency.py
--engine (the static-vs-autoscaled measurement) run EXACTLY this code — one
implementation of the loop, one arrival pattern, one perf model — so the
numbers the benchmark reports describe the same system the demo shows.

Each control tick: Poisson arrivals spread uniformly over the tick enter the
router only once the virtual clock passes their arrival time (submitting
early would let a request be served before it "arrived", biasing latency
low); the router runs ``steps_per_tick`` decode rounds; per-replica reports
feed the MetricsCollector; the EvictionPolicy turns the collector's
straggler feed into actuated ``router.evict_stragglers`` calls (a replica
flagged ``evict_after`` consecutive windows is evicted and replaced — the
loop doesn't just *compute* the straggler feed, it closes it); and — when
``autoscale`` — the PredictiveAllocator's decision is actuated via
router.scale_to.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.core.allocation.allocator import AllocatorConfig, PredictiveAllocator
from repro.core.dnn.features import deploy_vector
from repro.core.monitoring.anomaly import AnomalyDetector
from repro.core.monitoring.collector import MetricsCollector
from repro.core.scaling.scaler import EvictionPolicy, ScalingConstraints
from repro.serving.router import ReplicaRouter
from repro.serving.workload import synthetic_requests
from repro.sim.serving import WorkloadSpec


@dataclasses.dataclass(frozen=True)
class LoopConfig:
    slots: int = 4
    max_replicas: int = 4
    max_seq: int = 48
    prefill_chunk: int = 8
    steps_per_tick: int = 10     # decode rounds per control tick
    tick_s: float = 0.1          # virtual seconds per decode round
    slo_ms: float = 2000.0
    calm_rps: float = 1.2
    spike_rps: float = 7.0
    topology: str = "inproc"     # inproc | sharded | proc | tcp | pod
    addrs: tuple = ()            # tcp/pod: pre-started pods to attach to
    pod_size: int = 2            # pod: worker ranks per replica
    batch_submits: bool = True   # proc/tcp/pod: submits ride the step RPC
    evict_after: int = 3         # consecutive straggler windows → evict
    #                              (0 disables loop-actuated eviction)
    observe_addrs: tuple = ()    # read-only MetricsObserver attaches polled
    #                              each tick (never the router's session)
    pool: str = "dense"          # replica KV layout: dense | paged
    block_size: int | None = None   # paged: tokens per physical block
    num_blocks: int | None = None   # paged: physical blocks per replica
    spec_k: int = 0              # speculative decode: draft tokens per tick
    #                              (0 disables; streams are bit-identical)
    spec_ngram: int = 3          # prompt-lookup n-gram order for drafting
    alloc_mode: str = "planner"  # allocator: planner | rl | hybrid — hybrid
    #                              runs the (pretrained) DQN as the scaler
    #                              inside the planner's safety envelope
    learn: bool = True           # feed each tick's realized outcome back
    #                              into alloc.learn (reward credited to the
    #                              previous tick's action) when autoscaling
    batch_frac: float = 0.0      # fraction of arrivals on the batch tier
    #                              (0 keeps the workload single-tier and the
    #                              run bit-identical to the pre-tier loop)
    slo_batch_ms: float = 8000.0    # batch lane's (lenient) latency SLO
    batch_gate_frac: float = 0.9    # gate batch at this frac of the
    #                              interactive SLO (scaler hysteresis)
    reserved_replicas: int = 0   # >0 → heterogeneous fleet: this many
    #                              on-demand replica ids, every id past
    #                              them preemptible (FleetPlan)
    cost_on_demand: float = 1.0  # cost/tick of a reserved replica
    cost_preemptible: float = 0.35  # cost/tick of a spot replica
    rps_window: int = 8          # ticks of rps history published to the
    #                              scaler's burstiness analysis
    regions: tuple = ()          # region per replica id, cycled (FleetPlan
    #                              geography); () keeps the fleet
    #                              region-less and the run bit-identical
    #                              to the pre-region loop
    home_region: str = ""        # traffic origin: every arrival is tagged
    #                              with it (and the RTT matrix is measured
    #                              from it); "" = regions[0] when regioned
    region_aware: bool = True    # False routes region-BLIND while keeping
    #                              the injected RTT — the geo ablation's
    #                              control arm
    spot_market: bool = False    # price spot capacity by a seeded
    #                              SpotMarket process (mean-reverting walk
    #                              around cost_preemptible with spikes)
    #                              instead of a constant


@dataclasses.dataclass
class TickLog:
    tick: int
    rps_target: float
    arrivals: int
    served: int
    latency_p50_ms: float
    latency_p95_ms: float
    queue_depth: float
    replica_util: list          # [(replica_id, slot_util)] this window
    replicas: int               # realized count after actuation
    reason: str
    anomaly: bool
    evicted: list = dataclasses.field(default_factory=list)  # replica ids
    #                             the eviction policy actuated this tick
    observed: list = dataclasses.field(default_factory=list)  # one status()
    #                             per observe_addrs attach (out-of-band
    #                             lifetime counters, pod rank/mode)
    learn_loss: float | None = None   # DQN train-step loss, when the live
    #                             learning loop took one this tick
    batch_gated: bool = False    # batch lane gated during this tick's
    #                             scaling window (SLO protection)
    cost_per_tick: float = 0.0   # realized fleet spend for the window
    preemptions: int = 0         # lifetime spot reclaims absorbed so far
    region_spills: int = 0       # lifetime interactive cross-region routes


def default_profile(tick: int, ticks: int, lc: LoopConfig) -> float:
    """calm → spike → calm (requests per virtual second)."""
    lo, hi = ticks * 2 // 7, ticks * 9 // 14
    return lc.spike_rps if lo <= tick < hi else lc.calm_rps


def run_closed_loop(cfg, *, autoscale: bool = True, ticks: int = 14,
                    seed: int = 0, lc: LoopConfig = LoopConfig(),
                    spec: WorkloadSpec = WorkloadSpec(prompt_len=16,
                                                      gen_len=8),
                    profile=default_profile, sink: list | None = None,
                    recorder=None, chaos_hook=None, prime_allocator=None):
    """→ (router, [TickLog]).  ``autoscale=False`` pins one replica (the
    static baseline).  ``lc.topology`` picks the replica backend — the loop
    is transport-agnostic, so inproc / sharded / proc / tcp / pod runs on
    the same seed produce the same token streams and the same scaling
    trajectory.  ``sink``, when given, accumulates every completed Request
    (the cross-topology equivalence tests compare these).  Callers running
    the proc/tcp/pod topologies should ``router.close()`` when done (worker
    teardown).

    ``recorder`` (a ``core/dnn/traces.TraceRecorder``) captures one training
    record per tick: the collector aggregate plus the actuated decision,
    realized cost, anomaly/eviction counters, and the fleet's paged-pool
    prefix counters — replayable offline into StreamBuilder/ReplayBuffer
    datasets.  ``chaos_hook(tick, router, collector)`` runs after reports
    land and before eviction/scaling — fault scripts (straggler injection,
    worker kills) see exactly what the control plane sees.
    ``prime_allocator(alloc)`` runs once before the first tick — the hook
    offline-trained policies use to warm-start the live allocator."""
    plan = None
    if lc.reserved_replicas > 0:
        from repro.serving.profiles import FleetPlan, SpotMarket
        market = (SpotMarket(seed=seed, base=lc.cost_preemptible)
                  if lc.spot_market else None)
        plan = FleetPlan(reserved=lc.reserved_replicas,
                         cost_on_demand=lc.cost_on_demand,
                         cost_preemptible=lc.cost_preemptible,
                         regions=tuple(lc.regions),
                         home_region=lc.home_region, market=market)
    router = ReplicaRouter.from_topology(
        cfg, lc.topology, slots=lc.slots, max_seq=lc.max_seq, seed=seed,
        prefill_chunk=lc.prefill_chunk, n_replicas=1,
        max_replicas=lc.max_replicas, addrs=list(lc.addrs),
        pod_size=lc.pod_size, batch_submits=lc.batch_submits,
        pool=lc.pool, block_size=lc.block_size, num_blocks=lc.num_blocks,
        spec_k=lc.spec_k, spec_ngram=lc.spec_ngram, profile_fn=plan,
        region_aware=lc.region_aware)
    # the region arrivals originate from: tagged onto every request below
    # so the router can prefer in-region capacity
    origin = plan.origin if plan is not None else lc.home_region
    rng = np.random.default_rng(seed)
    evictor = (EvictionPolicy(k_windows=lc.evict_after)
               if lc.evict_after > 0 else None)
    observers = []

    # virtual-clock service time: streamed prompt tail + generation.  The
    # tail clamps at 0 — a prefill chunk >= the prompt swallows the whole
    # prompt in one shot; without the clamp the capacity model's service
    # time went NEGATIVE, inverting the planner (capacity < 0, util pinned
    # at 1.0, predicted latency negative → never scale up under a spike)
    service_s = (max(spec.prompt_len - lc.prefill_chunk, 0)
                 + spec.gen_len + 1) * lc.tick_s

    def perf_model(replicas, rps):
        """(latency_ms, util) — capacity model over the engine's own slot
        arithmetic; the planner scales so predicted latency meets the SLO."""
        cap = max(replicas, 1) * lc.slots / service_s
        util = min(rps / max(cap, 1e-9), 1.0)
        lat = service_s * (1.0 + 3.0 * max(util - 0.8, 0.0) / 0.2)
        return lat * 1e3, util

    collector = MetricsCollector()
    anomaly = AnomalyDetector(z_threshold=3.0, min_history=4)
    alloc = PredictiveAllocator(
        perf_model,
        ScalingConstraints(min_replicas=1, max_replicas=lc.max_replicas,
                           slo_ms=lc.slo_ms, slo_batch_ms=lc.slo_batch_ms,
                           batch_gate_frac=lc.batch_gate_frac),
        deploy_vector(model_params_b=cfg.n_params() / 1e9, family=cfg.family,
                      mesh_model=1, mesh_data=1, region_idx=0,
                      slo_ms=lc.slo_ms, cost_weight=0.5),
        cfg=AllocatorConfig(mode=lc.alloc_mode), seed=seed)
    if plan is not None:
        # the profile-AWARE planner: scale-up past the reserved pool is
        # priced at the spot rate, so batch headroom is bought cheap —
        # exactly the aware-vs-blind delta BENCH_tiers measures
        alloc.scaler.optimizer.cost_fn = plan.cost_of
    if prime_allocator is not None:
        prime_allocator(alloc)

    now, next_rid = 0.0, 0
    logs: list[TickLog] = []
    # rolling multi-tick rps history: publishing a single-sample window
    # made analyze_current_load's std/peak degenerate (std == 0, peak ==
    # mean), so burstiness never reached the planner
    rps_hist: deque[float] = deque(maxlen=max(int(lc.rps_window), 1))
    tick_span = lc.steps_per_tick * lc.tick_s
    try:
        if lc.observe_addrs:
            # read-only attaches: the loop's out-of-band view of the same
            # workers its router is mutating — lifetime counters come back
            # on a SEPARATE connection, so an external monitor's picture
            # and the control plane's can be compared tick by tick
            from repro.serving.observe import MetricsObserver
            for a in lc.observe_addrs:
                observers.append(MetricsObserver(a))
        for tick in range(ticks):
            rps = profile(tick, ticks, lc)
            n = int(rng.poisson(rps * tick_span))
            reqs = synthetic_requests(spec, n, cfg.vocab, rng=rng,
                                      base_rid=next_rid)
            next_rid += n
            if origin:
                for r in reqs:
                    r.region = origin
            if lc.batch_frac > 0.0:
                # tier draw only when the workload is actually mixed: a
                # single-tier run must consume the same rng stream as a
                # pre-tier one (bit-identical logs on a fixed seed)
                is_batch = rng.random(n) < lc.batch_frac
                for r, b in zip(reqs, is_batch):
                    if b:
                        r.tier = "batch"
            # deque: the old list.pop(0) drain was O(n²) per tick at high
            # rps (every pop shifted the whole remaining tail)
            arrivals = deque((now + (i / max(n, 1)) * tick_span, r)
                             for i, r in enumerate(reqs))
            served = 0
            for _ in range(lc.steps_per_tick):
                now += lc.tick_s
                while arrivals and arrivals[0][0] <= now:
                    t_arr, r = arrivals.popleft()
                    router.submit(r, now=t_arr)
                done = router.step(now)
                served += len(done)
                if sink is not None:
                    sink.extend(done)

            reports = router.reports(tick)
            for rep in reports:
                collector.submit(rep)
            if chaos_hook is not None:
                # fault scripts run on the control plane's view of the tick:
                # injected straggler evidence lands before the eviction
                # policy's window, scripted kills before the scaling decision
                chaos_hook(tick, router, collector)
            # close the straggler loop: flagged K consecutive windows → the
            # replica is evicted and replaced (its work requeues through the
            # survivors), BEFORE this tick's scaling decision sees the fleet
            evicted: list[int] = []
            if evictor is not None:
                evicted = router.evict_stragglers(
                    evictor.update(collector.stragglers(),
                                   router.replica_count), now=now)
            replicas_before = router.replica_count
            # fleet-level lifetime counters land BEFORE the aggregate so
            # this tick's record carries this tick's events (spot reclaims
            # from the chaos hook / reap above, placement spills from the
            # submits) as per-tick channels the DNN streams can consume
            collector.observe_fleet({
                "preemptions": router.preemptions,
                "tier_spills": router.tier_spills,
                "region_spills": router.region_spills})
            rec = collector.aggregate(tick, n_replicas=router.replica_count,
                                      max_replicas=lc.max_replicas)
            rec["evictions"] = float(len(evicted))   # visible to the DNN/selector
            # arrivals per VIRTUAL SECOND — perf_model and the forecaster
            # consume a rate, and the raw per-tick count only coincides with
            # it when steps_per_tick * tick_s == 1.0 (the default shape)
            rec["rps"] = float(n) / tick_span
            rps_hist.append(rec["rps"])
            rec["rps_window"] = list(rps_hist)
            anomalies = anomaly.update(tick, {"rps": rec["rps"]})
            reason = "static"
            learn_loss = None
            # realized spend for the window that produced these metrics: the
            # fleet that served it — profile rates when heterogeneous, the
            # flat constraints price otherwise.  Under a spot MARKET the
            # spot legs are billed at this tick's price, and the optimizer's
            # cost model is re-pointed at the same tick so the planner buys
            # (or stops buying) spot at what it actually costs right now
            if plan is not None and plan.market is not None:
                cost_per_tick = sum(plan.price_of(r.replica_id, tick)
                                    for r in router.serving_replicas)
                alloc.scaler.optimizer.cost_fn = (
                    lambda m, _t=tick: plan.cost_of(m, _t))
            elif plan is not None:
                cost_per_tick = router.cost_per_tick
            else:
                cost_per_tick = (replicas_before
                                 * alloc.constraints.cost_per_replica)
            gated = router.batch_gated
            if lc.batch_frac > 0.0:
                # interactive SLO protection runs even without autoscaling:
                # the gate is admission policy, not capacity actuation
                gated = alloc.scaler.batch_gate_decision(
                    rec, alloc.constraints)
                router.gate_batch(gated)
            if autoscale:
                alloc.observe(rec)
                alloc.replicas = router.replica_count
                decision = alloc.decide(rec)
                router.scale_to(decision.target_replicas, now=now)
                alloc.apply(decision)
                reason = decision.reason
                if lc.learn:
                    # the live learning loop: this tick's realized outcome
                    # becomes the reward credited to the PREVIOUS action
                    learn_loss = alloc.learn(rec, cost_per_tick)
            if recorder is not None:
                fleet = router.metrics()
                recorder.record({
                    **rec,
                    "rps_target": float(rps), "arrivals": int(n),
                    "served": int(served),
                    "replicas_before": int(replicas_before),
                    "replicas_after": int(router.replica_count),
                    "action_delta": int(decision.delta) if autoscale else 0,
                    "reason": reason,
                    "cost_per_tick": float(cost_per_tick),
                    "anomaly": float(bool(anomalies)),
                    # heterogeneous-fleet economics this tick (flat-fleet
                    # runs read cost at the constraints price, zero churn).
                    # The per-tick EVENT channels (preemptions/tier_spills/
                    # region_spills) are already in ``rec`` via the
                    # collector's fleet fold; the *_total keys keep the
                    # lifetime counters visible for run-level accounting
                    "fleet_cost_per_tick": float(fleet["fleet_cost_per_tick"]),
                    "spot_price": float(plan.spot_price(tick)
                                        if plan is not None else 0.0),
                    "preemptions_total": float(fleet["preemptions"]),
                    "tier_spills_total": float(fleet["tier_spills"]),
                    "region_spills_total": float(fleet["region_spills"]),
                    "batch_gated": float(gated),
                    # paged-pool cache efficiency, fleet-wide (0 for dense)
                    "prefix_hits": float(fleet["prefix_hits"]),
                    "tokens_shared": float(fleet["tokens_shared"]),
                    "prefill_tokens": float(fleet["prefill_tokens"]),
                    "prompt_tokens": float(fleet["prompt_tokens"]),
                })
            observed = []
            for obs in list(observers):
                try:
                    observed.append({"addr": obs.addr, **obs.status()})
                except (ConnectionError, OSError, RuntimeError):
                    # the observed worker retired (evicted / scaled away)
                    # or bounced the poll with an error reply — out-of-band
                    # monitoring must never take the loop down
                    obs.close()
                    observers.remove(obs)
            logs.append(TickLog(
                tick=tick, rps_target=rps, arrivals=n, served=served,
                latency_p50_ms=rec["latency_p50"],
                latency_p95_ms=rec["latency_p95"],
                queue_depth=rec["queue_depth"],
                replica_util=[(rep.replica_id, rep.flop_util) for rep in reports],
                replicas=router.replica_count, reason=reason, anomaly=bool(
                    anomalies), evicted=evicted, observed=observed,
                learn_loss=learn_loss, batch_gated=gated,
                cost_per_tick=float(cost_per_tick),
                preemptions=router.preemptions,
                region_spills=router.region_spills))
    except BaseException:
        # the caller never receives the router handle it is documented to
        # close — reap the fleet (spawned workers/pods included) here
        router.close()
        raise
    finally:
        # out-of-band attaches must not leak when a tick raises (worker
        # crash mid-run, an observer dial failing after the fleet is up)
        for obs in observers:
            obs.close()
    return router, logs
