"""ReplicaRouter: N replicas behind least-loaded routing, scalable mid-run.

The router is written purely against the Replica protocol
(serving/replica.py) — it never touches an engine, a scheduler, or a slot
array.  Whether a replica is an in-process object, one engine sharded over a
device mesh, a worker subprocess on the far side of a socketpair, or a TCP
pod on another host is a factory decision (``from_topology``); the routing,
scaling, drain/park, and straggler-eviction logic below is
transport-agnostic.

The router is the surface the control plane drives: ``scale_to(n)`` is the
actuator for DynamicScaler / PredictiveAllocator decisions, and
``reports()`` emits the per-replica ReplicaReport stream that
core/monitoring's MetricsCollector consumes (p50/p95 latency, throughput,
slot utilization, queue depth, transport latency).

Scaling semantics:
* up   — unpark a previously retired replica (warm: its process / compile /
         weights are live), else build a new one via the factory.
* down — victims are EVACUATED: queued requests AND in-flight ones
         (preempted, rewound) are requeued through the survivors'
         schedulers, and the victim parks immediately.  No request is ever
         stranded on a parked replica, lost, or duplicated; a preempted
         request restarts generation on a survivor (its RNG reseeds per
         (seed, rid), so the replayed stream is identical to a fresh run).

Failure semantics: a replica whose transport dies mid-step is reaped on the
next ``step`` — its lost requests are rewound and requeued, a replacement
is built to restore the actuated replica count, and its final ``n_errors``
report has already marked it a straggler in the collector.

Heterogeneous fleets (``profile_fn``): when the operator supplies a
``profile_fn(replica_id) -> ReplicaProfile`` (serving/profiles.py), replicas
stop being interchangeable —

* routing normalizes load by each replica's speed (prior from the profile,
  replaced by the MEASURED lifetime tokens/tick once a replica has served
  enough rounds) and tie-breaks toward cheaper capacity;
* interactive-tier requests are never placed on ``preemptible`` replicas
  while any stable one serves (``tier_spills`` counts forced fallbacks);
* on a region-tagged fleet (profiles carry ``region``), interactive
  requests prefer capacity in their OWN region — stability still trumps
  locality, so the in-region preference filters the stable set — and
  ``region_spills`` counts interactive placements forced cross-region.
  When the plan carries an RTT matrix (``transport_ms_for``), each new
  replica is built behind a ``chaos.DelayedReplica`` shim injecting that
  RTT on the virtual clock, so cross-region placement costs real measured
  latency on every topology without a wall-clock sleep;
* a failed preemptible replica is NOT replaced on reap (``preempt()`` is
  the chaos/provider-reclaim injection point) — batch absorbs the churn and
  the scaler re-provisions when the forecast still wants the capacity;
* ``metrics()`` reports the fleet's realized cost per tick, and downscale
  victims are highest-id first, which under a FleetPlan sheds spot
  capacity before reserved.

Without a profile_fn every profile is the default (equal speed/cost, not
preemptible) and routing is bit-identical to the legacy least-loaded key;
a profiled fleet whose profiles carry no regions routes bit-identically to
the pre-region profiled key (no delay shims, no spill counting).
"""
from __future__ import annotations

import numpy as np

from repro.core.monitoring.collector import ReplicaReport
from repro.serving.chaos import DelayedReplica
from repro.serving.engine import EngineCore
from repro.serving.profiles import ReplicaProfile
from repro.serving.replica import (
    InProcessReplica, Replica, ServingEngine, empty_report,
)
from repro.serving.scheduler import Request
from repro.serving.transport import TransportError

# measured speed needs this many served rounds before it replaces the
# profile's prior — a two-tick sample must not reroute the fleet
MIN_SPEED_TICKS = 16

TOPOLOGIES = ("inproc", "sharded", "proc", "tcp", "pod")


def _attach_factory(klass, cfg, addr_list, topology, **fixed):
    """Factory for attach-style replicas (tcp workers, pod heads): ids
    inside ``addr_list`` dial the operator's pre-scheduled endpoints; ids
    past it spawn LOCAL stand-ins so scale-up keeps working in a demo
    without a pod scheduler — but past an EXPLICIT list that substitution
    is capacity drift, so it is both warned (stderr readers) and counted
    (``factory.counters["off_list_spawns"]`` → router.metrics(), where the
    closed loop can see the topology drifting)."""
    import warnings

    counters = {"off_list_spawns": 0}

    def factory(replica_id: int):
        addr = addr_list[replica_id] if replica_id < len(addr_list) else None
        if addr is None and addr_list:
            counters["off_list_spawns"] += 1
            warnings.warn(
                f"{topology} replica {replica_id} exceeds the "
                f"{len(addr_list)}-pod attach list; spawning a LOCAL "
                f"worker on the router host", RuntimeWarning, stacklevel=2)
        return klass(cfg, addr=addr, replica_id=replica_id, **fixed)

    factory.counters = counters
    return factory


def _coerce(obj) -> Replica:
    """Legacy factories return bare ServingEngines — wrap them."""
    return InProcessReplica(obj) if isinstance(obj, ServingEngine) else obj


class ReplicaRouter:
    def __init__(self, replica_factory, *, n_replicas: int = 1,
                 max_replicas: int = 8, profile_fn=None,
                 region_aware: bool = True, delay_fn=None):
        """replica_factory(replica_id) -> Replica (or a bare ServingEngine,
        which is wrapped in-process for backward compatibility).

        ``profile_fn(replica_id) -> ReplicaProfile`` declares the fleet
        heterogeneous (see module docstring); None keeps every replica
        interchangeable and routing bit-identical to the legacy key.

        ``delay_fn(replica_id) -> rtt_ms`` injects deterministic transport
        latency (a DelayedReplica shim) in front of each new replica;
        defaults to the profile_fn's ``transport_ms_for`` when it has one
        (a FleetPlan with regions), so geography and its latency arrive
        together.  ``region_aware=False`` keeps the injected latency but
        routes region-BLIND — the control arm of the geo ablation."""
        self._factory = replica_factory
        self.max_replicas = max_replicas
        self._profile_fn = profile_fn
        self._profiled = profile_fn is not None
        self._region_aware = bool(region_aware)
        if delay_fn is None and hasattr(profile_fn, "transport_ms_for"):
            delay_fn = profile_fn.transport_ms_for
        self._delay_fn = delay_fn
        self._profiles: dict[int, ReplicaProfile] = {}
        # router-side speed measurement: completions and served rounds per
        # replica id (transport-free — no lifetime RPC on the hot path)
        self._tok_served: dict[int, int] = {}
        self._ticks_served: dict[int, int] = {}
        self.preemptions = 0          # preemptible replicas lost/reclaimed
        self.tier_spills = 0          # interactive forced onto volatile cap
        self.region_spills = 0        # interactive forced out of its region
        self._batch_gated = False
        self.replicas: list[Replica] = []
        self._parked: list[Replica] = []
        self._retired: list[Replica] = []     # failed, kept for accounting
        # retirement reports still owed, (phase, replica): phase 0 → the
        # crash report goes out next reports() round, phase 1 → the clean
        # tombstone does.  One structure, drained in one place.
        self._dying: list[tuple[int, Replica]] = []
        self._undelivered: list[Request] = []  # survived a mid-step raise
        self._next_replica_id = 0
        self._target = max(n_replicas, 1)
        self._t0: float | None = None
        self._last_now = 0.0
        for _ in range(self._target):
            self._add_replica()

    @classmethod
    def shared_core(cls, cfg, *, slots: int, max_seq: int, seed: int = 0,
                    prefill_chunk: int | None = None, n_replicas: int = 1,
                    max_replicas: int = 8) -> "ReplicaRouter":
        """In-process router whose replicas share one EngineCore (params +
        compiles)."""
        return cls.from_topology(cfg, "inproc", slots=slots, max_seq=max_seq,
                                 seed=seed, prefill_chunk=prefill_chunk,
                                 n_replicas=n_replicas,
                                 max_replicas=max_replicas)

    @classmethod
    def from_topology(cls, cfg, topology: str, *, slots: int, max_seq: int,
                      seed: int = 0, prefill_chunk: int | None = None,
                      n_replicas: int = 1, max_replicas: int = 8,
                      mesh=None, addrs=None, pod_size: int = 2,
                      batch_submits: bool = True, pool: str = "dense",
                      block_size: int | None = None,
                      num_blocks: int | None = None, spec_k: int = 0,
                      spec_ngram: int = 3,
                      profile_fn=None, region_aware: bool = True,
                      delay_fn=None) -> "ReplicaRouter":
        """Build the fleet for one of the five replica topologies.

        inproc  — replicas share one EngineCore (no re-init / re-jit).
        sharded — each replica spans the local device mesh (slot axis
                  sharded); replicas share the core AND one sharded decode
                  compile.
        proc    — each replica is a worker subprocess; workers re-derive
                  identical params from the shared seed, so token streams
                  match the in-process topology bit-for-bit.
        tcp     — each replica dials a listening TCP worker: ``addrs``
                  lists pre-started pods to attach to (cross-host);
                  replica ids past the list spawn local workers on
                  kernel-picked ports, so scale-up keeps working in a demo
                  without a pod scheduler.
        pod     — each replica is a MULTI-PROCESS pod of ``pod_size``
                  worker ranks behind one head (DistributedPodReplica):
                  ``addrs`` lists pre-scheduled pod HEAD addresses;
                  replica ids past the list launch local pods.

        ``batch_submits`` (proc/tcp/pod) folds per-tick submits into the
        step RPC — one message per round per replica instead of one per
        request.  For the attach topologies, off-list local spawns are
        counted in ``metrics()["off_list_spawns"]``.

        ``pool`` ∈ {"dense", "paged"} selects each replica's KV layout
        (serving/slots.py); ``block_size``/``num_blocks`` tune the paged
        pool's geometry.  The layout is observationally invisible — token
        streams match the dense pool bit-for-bit on every topology.

        ``spec_k``/``spec_ngram`` turn on speculative decoding inside each
        replica's engine (serving/engine.py) — also observationally
        invisible: accepted drafts are exact matches, so token streams are
        bit-identical with speculation on or off.  The sharded topology
        accepts the knobs but serves the plain path (its decode step is
        compiled for single-position ticks).

        ``profile_fn(replica_id) -> ReplicaProfile`` (e.g. a
        serving/profiles.py FleetPlan) declares the fleet heterogeneous —
        cost/speed-aware routing, tier placement, preemptible semantics;
        see the module docstring.  ``region_aware``/``delay_fn`` control
        the geographic axis (in-region preference and injected RTT; see
        ``__init__``).
        """
        if topology not in TOPOLOGIES:
            raise ValueError(f"unknown topology {topology!r} "
                             f"(expected one of {TOPOLOGIES})")
        pool_kw = dict(pool=pool, block_size=block_size,
                       num_blocks=num_blocks, spec_k=spec_k,
                       spec_ngram=spec_ngram)
        if topology == "proc":
            from repro.serving.replica import ProcessReplica

            def factory(replica_id: int):
                return ProcessReplica(cfg, slots=slots, max_seq=max_seq,
                                      seed=seed, prefill_chunk=prefill_chunk,
                                      replica_id=replica_id,
                                      batch_submits=batch_submits, **pool_kw)
        elif topology == "tcp":
            from repro.serving.replica import TcpReplica
            factory = _attach_factory(
                TcpReplica, cfg, list(addrs or []), topology, slots=slots,
                max_seq=max_seq, seed=seed, prefill_chunk=prefill_chunk,
                batch_submits=batch_submits, **pool_kw)
        elif topology == "pod":
            from repro.serving.replica import DistributedPodReplica
            factory = _attach_factory(
                DistributedPodReplica, cfg, list(addrs or []), topology,
                slots=slots, max_seq=max_seq, seed=seed,
                prefill_chunk=prefill_chunk, pod_size=pod_size,
                batch_submits=batch_submits, **pool_kw)
        elif topology == "sharded":
            from repro.serving.replica import (
                ShardedReplica, make_sharded_decode,
            )
            if mesh is None:
                import jax

                from repro.launch.mesh import make_mesh
                mesh = make_mesh((len(jax.devices()),), ("data",))
            core = EngineCore(cfg, max_seq, seed=seed)
            decode_fn = make_sharded_decode(cfg, mesh, slots, max_seq,
                                            pool=pool, block_size=block_size,
                                            num_blocks=num_blocks)

            def factory(replica_id: int):
                return ShardedReplica(cfg, slots=slots, max_seq=max_seq,
                                      mesh=mesh, seed=seed,
                                      prefill_chunk=prefill_chunk, core=core,
                                      replica_id=replica_id,
                                      decode_fn=decode_fn, **pool_kw)
        else:
            core = EngineCore(cfg, max_seq, seed=seed)

            def factory(replica_id: int):
                return InProcessReplica.build(
                    cfg, slots=slots, max_seq=max_seq,
                    prefill_chunk=prefill_chunk, core=core,
                    replica_id=replica_id, **pool_kw)

        return cls(factory, n_replicas=n_replicas, max_replicas=max_replicas,
                   profile_fn=profile_fn, region_aware=region_aware,
                   delay_fn=delay_fn)

    # ------------------------------------------------------------- topology

    def _add_replica(self):
        if self._parked:
            rep = self._parked.pop()
            rep.resume()
        else:
            rep = _coerce(self._factory(self._next_replica_id))
            self._next_replica_id += 1
            # geography: a replica whose region costs an RTT from the
            # router's vantage point comes up behind the delay shim —
            # parked replicas re-enter already wrapped
            delay = (float(self._delay_fn(rep.replica_id))
                     if self._delay_fn is not None else 0.0)
            if delay > 0.0:
                rep = DelayedReplica(rep, rtt_ms=delay)
        rid = rep.replica_id
        if rid not in self._profiles:
            self._profiles[rid] = (self._profile_fn(rid) if self._profiled
                                   else ReplicaProfile())
        # a replica joining a gated fleet must not open a batch side door
        if self._batch_gated:
            rep.gate_batch(True)
        self.replicas.append(rep)

    def profile(self, replica_id: int) -> ReplicaProfile:
        return self._profiles.get(replica_id) or ReplicaProfile()

    def effective_speed(self, replica_id: int) -> float:
        """The speed the routing key divides load by: the profile's prior
        until the replica has served MIN_SPEED_TICKS rounds, then its
        measured tokens/tick relative to the fleet's measured mean — live
        hardware truth replaces the operator's catalog number."""
        prior = self.profile(replica_id).speed
        ticks = self._ticks_served.get(replica_id, 0)
        if ticks < MIN_SPEED_TICKS:
            return prior
        rates = [self._tok_served.get(rid, 0) / t
                 for rid, t in self._ticks_served.items()
                 if t >= MIN_SPEED_TICKS]
        base = sum(rates) / len(rates) if rates else 0.0
        if base <= 0.0:
            return prior               # an idle fleet has measured nothing
        return max(self._tok_served.get(replica_id, 0) / ticks / base, 1e-3)

    @property
    def serving_replicas(self) -> list[Replica]:
        return [r for r in self.replicas if not r.draining and not r.failed]

    @property
    def replica_count(self) -> int:
        return len(self.serving_replicas)

    def scale_to(self, n: int, now: float = 0.0) -> int:
        """Actuate a control-plane decision; returns the realized count."""
        n = max(1, min(int(n), self.max_replicas))
        self._target = n
        while self.replica_count < n:
            self._add_replica()
        extra = self.replica_count - n
        if extra > 0:
            # highest id first: under a FleetPlan the ids past the reserved
            # pool are the preemptible ones, so downscale sheds spot
            # capacity before touching stable replicas
            victims = sorted(self.serving_replicas,
                             key=lambda r: -r.replica_id)[:extra]
            displaced: list[Request] = []
            for rep in victims:
                # queued AND in-flight leave with the replica, which parks
                # immediately — nothing is stranded behind a parked replica
                displaced.extend(rep.evacuate())
                self.replicas.remove(rep)
                self._parked.append(rep)
            for req in displaced:          # requeue through the survivors
                self.submit(req, now=now)
        return self.replica_count

    def evict(self, replica_id: int, now: float = 0.0, *,
              replace: bool = True) -> bool:
        """Remove one replica (straggler eviction / failure reaping): its
        requests are requeued through the survivors and — when ``replace``
        — a fresh replica restores the actuated count.

        The victim RETIRES, it does not park: parking would hand the same
        slow worker straight back to the next scale-up or eviction
        replacement (``_add_replica`` pops parked replicas LIFO), churning
        evict→revive forever.  Parking is for scale_to downscale (healthy
        warm-revive candidates); an evicted replica was condemned for
        cause."""
        rep = next((r for r in self.replicas if r.replica_id == replica_id),
                   None)
        if rep is None:
            return False
        displaced = rep.evacuate()
        displaced.extend(rep.lost_requests())
        self.replicas.remove(rep)
        # replacement first, THEN retire the victim (the order matters for
        # replica_count and keeps this path symmetric with scale_to's)
        if replace and self.replica_count < self._target:
            self._add_replica()
        rep.close()
        self._retired.append(rep)
        if rep.failed:
            if self.profile(replica_id).preemptible:
                self.preemptions += 1      # provider reclaimed spot capacity
            self._dying.append((0, rep))   # crash report, then tombstone
        else:
            # healthy straggler: one clean tombstone prunes its collector
            # latency EWMA, so the retired id drops off the straggler feed
            # instead of being re-flagged (and re-proposed) forever
            self._dying.append((1, rep))
        for req in displaced:
            self.submit(req, now=now)
        return True

    def evict_stragglers(self, straggler_ids, now: float = 0.0) -> list[int]:
        """Control-plane hook: evict every flagged replica (the collector's
        ``stragglers()`` feed), replacing each to hold the actuated count."""
        evicted = []
        for rid in list(straggler_ids):
            if self.evict(rid, now=now):
                evicted.append(rid)
        return evicted

    # ------------------------------------------------------------- requests

    def submit(self, request: Request, now: float = 0.0):
        """Least-loaded routing.  A replica whose transport died between
        steps only reveals itself when an RPC touches it — the submit that
        discovers the corpse reroutes to the next survivor instead of
        crashing the driver (the dead replica is excluded the moment its
        stub flips ``failed``, and the next step() reaps it properly)."""
        if request.t_submit is None:
            request.t_submit = now
        if self._t0 is None or request.t_submit < self._t0:
            self._t0 = request.t_submit
        while True:
            candidates = self.serving_replicas
            if not candidates:
                # every live replica is a corpse (single-replica fleet whose
                # worker died between steps): reap them NOW — eviction
                # builds the replacements step() would have built
                failed = [r for r in self.replicas if r.failed]
                if not failed:
                    raise RuntimeError("no live replicas to route to")
                for rep in failed:
                    self.evict(rep.replica_id, now=now)
                continue
            if self._profiled:
                # interactive work never rides volatile capacity while any
                # stable replica serves; when the whole fleet is spot, the
                # forced fallback is counted rather than refused
                if getattr(request, "tier", "interactive") == "interactive":
                    stable = [r for r in candidates
                              if not self.profile(r.replica_id).preemptible]
                    if stable:
                        candidates = stable
                    else:
                        self.tier_spills += 1
                    # geography: prefer in-region capacity — AFTER the
                    # stable filter, because SLO protection trumps
                    # locality (an in-region spot replica must not steal
                    # interactive work from a remote stable one) — and
                    # only while the in-region replicas have headroom
                    # (load < 1): pinning into a saturated region would
                    # trade one RTT for unbounded queueing.  Only a
                    # region-TAGGED candidate set engages the preference:
                    # region-less fleets and untagged requests skip it,
                    # keeping their placement bit-identical to the
                    # pre-region key
                    req_region = getattr(request, "region", "")
                    if req_region and self._region_aware:
                        local = [r for r in candidates
                                 if self.profile(r.replica_id).region
                                 == req_region and r.load < 1.0]
                        if local:
                            candidates = local
                        elif any(self.profile(r.replica_id).region
                                 for r in candidates):
                            self.region_spills += 1
                # least NORMALIZED load: a 2× replica at load 0.8 is as
                # admittable as a baseline one at 0.4; ties go to cheaper
                # capacity, so batch headroom lands on spot replicas
                rep = min(candidates, key=lambda r: (
                    r.load / self.effective_speed(r.replica_id),
                    self.profile(r.replica_id).cost_per_tick,
                    r.replica_id))
            else:
                rep = min(candidates, key=lambda r: (r.load, r.replica_id))
            try:
                rep.submit(request, now=now)
                return
            except TransportError:
                continue               # rep is now failed → excluded above

    def step(self, now: float = 0.0) -> list[Request]:
        """One tick across every live replica, split-phase: the round BEGINS
        on every replica before any result is collected, so remote workers
        decode concurrently (the round costs the slowest worker, not the
        sum).  Replicas whose transport died are reaped afterwards: lost
        requests rewound and requeued, replacements built to restore the
        actuated count."""
        live = list(self.replicas)
        for rep in live:
            rep.begin_step(now)
        # completions already collected must survive a later replica's
        # finish_step raising (their stubs have handed them over — they are
        # not recoverable anywhere else): stash and redeliver next step
        completed, self._undelivered = self._undelivered, []
        try:
            for rep in live:
                completed.extend(rep.finish_step())
        except Exception:
            self._undelivered = completed
            raise
        for rep in [r for r in self.replicas if r.failed]:
            # a lost PREEMPTIBLE replica is not replaced: the spot capacity
            # is gone, batch absorbs the churn, and the scaler re-provisions
            # if the forecast still wants it — auto-rebuilding here would
            # bill on-demand work as if spot never vanished
            self.evict(rep.replica_id, now=now,
                       replace=not self.profile(rep.replica_id).preemptible)
        for rep in self.serving_replicas:
            self._ticks_served[rep.replica_id] = \
                self._ticks_served.get(rep.replica_id, 0) + 1
        for req in completed:
            if req.replica_id is not None:
                self._tok_served[req.replica_id] = \
                    self._tok_served.get(req.replica_id, 0) \
                    + len(req.tokens_out)
        self._last_now = max(self._last_now, now)
        return completed

    @property
    def pending(self) -> int:
        """Requests somewhere in the system (queued or in a slot)."""
        return sum(r.pending for r in self.replicas)

    # ------------------------------------------------------ tiers & capacity

    def gate_batch(self, on: bool) -> bool:
        """Fleet-wide batch-lane gate (the scaler's SLO-protection
        actuator): while on, no replica admits batch-tier work — queued
        batch requests wait, interactive drains at full capacity.
        Replicas added while gated come up gated.  Returns the new state."""
        self._batch_gated = bool(on)
        for rep in self.replicas:
            rep.gate_batch(self._batch_gated)
        return self._batch_gated

    @property
    def batch_gated(self) -> bool:
        return self._batch_gated

    def preempt(self, replica_id: int, now: float = 0.0) -> bool:
        """Provider-reclaim injection: the replica vanishes WITHOUT notice
        (no graceful drain — in-flight work is rewound and requeued through
        the survivors, exactly once).  Not replaced: the capacity is gone
        until the scaler buys more.  Refuses to take the last serving
        replica — a fleet of zero cannot absorb anything."""
        rep = next((r for r in self.replicas if r.replica_id == replica_id),
                   None)
        if rep is None or len(self.serving_replicas) <= 1:
            return False
        rep.failed = True              # the reclaim is not a clean drain
        return self.evict(replica_id, now=now, replace=False)

    @property
    def cost_per_tick(self) -> float:
        """Realized fleet cost this tick: sum of serving replicas' profile
        rates (parked/dead capacity is not billed)."""
        return sum(self.profile(r.replica_id).cost_per_tick
                   for r in self.serving_replicas)

    # ------------------------------------------------------------- metrics

    def reports(self, tick: int) -> list[ReplicaReport]:
        """Per-replica reports for MetricsCollector.submit (drains each
        replica's metric window).  Parked replicas keep reporting (empty
        windows): the collector re-counts each replica's LAST report every
        aggregate, so going silent would replay a parked replica's final
        spike window forever — an explicit empty report zeroes it out.

        A retired (failed, closed) replica sends exactly TWO more reports:
        first its crash report (n_errors > 0 — this is what puts the crash
        on the collector's straggler list and in the fleet error rate; the
        reap happened inside step(), so without this the control plane
        would never see the failure at all), then one clean tombstone — a
        final n_errors report left in place would replay forever, keeping a
        long-dead replica flagged.

        A PARKED replica whose worker died (discovered by this very report
        poll) joins the same retirement flow here — nothing else ever
        touches parked replicas, so this is the only place the corpse can
        be noticed."""
        out = [rep.report(tick) for rep in self.replicas]
        dying_now, self._dying = self._dying, []
        for rep in list(self._parked):
            out.append(rep.report(tick))    # the poll that detects death
            if rep.failed:                  # that report WAS its crash one:
                self._parked.remove(rep)    # tombstone next round, never
                rep.close()                 # the same one
                self._retired.append(rep)
                self._dying.append((1, rep))
        for phase, rep in dying_now:        # one owed report per round
            if phase == 0:                  # crash report (parent-side stub)
                rpt = rep.report(tick)
                # phase 0 IS the crash report by definition: an in-process
                # replica preempted by fiat dies with a clean window, but
                # the collector must still see the loss as an error
                rpt.n_errors = max(rpt.n_errors, 1)
                out.append(rpt)
                self._dying.append((1, rep))
            else:                           # clean-up for the crash report
                out.append(empty_report(rep.replica_id, tick))
        return out

    def metrics(self) -> dict:
        """Fleet-level aggregates over replica lifetimes (parked and failed
        replicas keep their history — work they served must not vanish)."""
        ever = [r.lifetime() for r in
                self.replicas + self._parked + self._retired]
        lats = [l for lt in ever for l in lt["latencies_ms"]]
        lat = np.asarray(lats) if lats else np.zeros(1)
        tokens = sum(lt["total_tokens"] for lt in ever)
        completed = sum(lt["total_completed"] for lt in ever)
        wall = max(self._last_now - (self._t0 or 0.0), 1e-9)
        # tick-weighted mean: every lifetime is an AVERAGE over that
        # replica's served rounds, so a two-tick replacement must weigh
        # two ticks, not as much as a run-long survivor.  Lifetimes without
        # a tick count (older remote mirrors) fall back to weight 1.
        tick_w = [max(int(lt.get("total_ticks", 0)), 0) or 1 for lt in ever]
        util_num = sum(lt["slot_utilization"] * w
                       for lt, w in zip(ever, tick_w))
        return {
            "latency_p50_ms": float(np.percentile(lat, 50)),
            "latency_p95_ms": float(np.percentile(lat, 95)),
            "throughput_tok_s": tokens / wall,
            "completed": completed,
            "completed_tokens": tokens,
            "completed_interactive": sum(
                lt.get("completed_interactive", 0) for lt in ever),
            "completed_batch": sum(
                lt.get("completed_batch", 0) for lt in ever),
            "slot_utilization": (util_num / sum(tick_w)) if ever else 0.0,
            "queue_depth": sum(r.queue_depth for r in self.replicas),
            "transport_ms": float(np.mean(
                [r.transport_ms for r in self.replicas])) if self.replicas
            else 0.0,
            # frames this fleet put on the wire over its lifetime (0 for
            # in-process fleets) — the submit-batching benchmark metric
            "rpc_count": sum(getattr(r, "rpc_count", 0) for r in
                             self.replicas + self._parked + self._retired),
            # attach topologies: replacements/scale-ups that fell off the
            # operator's explicit attach list onto router-host workers —
            # topology drift the closed loop should see, not just stderr
            "off_list_spawns": getattr(self._factory, "counters",
                                       {}).get("off_list_spawns", 0),
            "replicas": self.replica_count,
            # heterogeneous-fleet economics: realized cost of the serving
            # set, spot losses absorbed, and interactive requests forced
            # onto volatile capacity (0 / default-priced when unprofiled)
            "fleet_cost_per_tick": self.cost_per_tick,
            "preemptions": self.preemptions,
            "tier_spills": self.tier_spills,
            # interactive placements forced out of their origin region (0
            # on region-less fleets and under region-blind routing)
            "region_spills": self.region_spills,
            "batch_gated": self._batch_gated,
            # paged-pool cache efficiency, fleet-wide — engines only report
            # these when running a paged KV pool, so dense fleets read 0
            "prefix_hits": sum(lt.get("prefix_hits", 0) for lt in ever),
            "tokens_shared": sum(lt.get("tokens_shared", 0) for lt in ever),
            "prefill_tokens": sum(lt.get("prefill_tokens", 0) for lt in ever),
            "prompt_tokens": sum(lt.get("prompt_tokens", 0) for lt in ever),
            # speculative decoding, fleet-wide: draft tokens proposed and
            # accepted over every engine's lifetime (0 with speculation off)
            "spec_proposed": sum(lt.get("spec_proposed", 0) for lt in ever),
            "spec_accepted": sum(lt.get("spec_accepted", 0) for lt in ever),
        }

    def close(self):
        """Release every replica (terminates proc-topology workers)."""
        for rep in self.replicas + self._parked:
            rep.close()
        self.replicas.clear()
        self._parked.clear()
