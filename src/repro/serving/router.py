"""ReplicaRouter: N replicas behind least-loaded routing, scalable mid-run.

The router is written purely against the Replica protocol
(serving/replica.py) — it never touches an engine, a scheduler, or a slot
array.  Whether a replica is an in-process object, one engine sharded over a
device mesh, a worker subprocess on the far side of a socketpair, or a TCP
pod on another host is a factory decision (``from_topology``); the routing,
scaling, drain/park, and straggler-eviction logic below is
transport-agnostic.

The router is the surface the control plane drives: ``scale_to(n)`` is the
actuator for DynamicScaler / PredictiveAllocator decisions, and
``reports()`` emits the per-replica ReplicaReport stream that
core/monitoring's MetricsCollector consumes (p50/p95 latency, throughput,
slot utilization, queue depth, transport latency).

Scaling semantics:
* up   — unpark a previously retired replica (warm: its process / compile /
         weights are live), else build a new one via the factory.
* down — victims are EVACUATED: queued requests AND in-flight ones
         (preempted, rewound) are requeued through the survivors'
         schedulers, and the victim parks immediately.  No request is ever
         stranded on a parked replica, lost, or duplicated; a preempted
         request restarts generation on a survivor (its RNG reseeds per
         (seed, rid), so the replayed stream is identical to a fresh run).

Failure semantics: a replica whose transport dies mid-step is reaped on the
next ``step`` — its lost requests are rewound and requeued, a replacement
is built to restore the actuated replica count, and its final ``n_errors``
report has already marked it a straggler in the collector.
"""
from __future__ import annotations

import numpy as np

from repro.core.monitoring.collector import ReplicaReport
from repro.serving.engine import EngineCore
from repro.serving.replica import (
    InProcessReplica, Replica, ServingEngine, empty_report,
)
from repro.serving.scheduler import Request
from repro.serving.transport import TransportError

TOPOLOGIES = ("inproc", "sharded", "proc", "tcp", "pod")


def _attach_factory(klass, cfg, addr_list, topology, **fixed):
    """Factory for attach-style replicas (tcp workers, pod heads): ids
    inside ``addr_list`` dial the operator's pre-scheduled endpoints; ids
    past it spawn LOCAL stand-ins so scale-up keeps working in a demo
    without a pod scheduler — but past an EXPLICIT list that substitution
    is capacity drift, so it is both warned (stderr readers) and counted
    (``factory.counters["off_list_spawns"]`` → router.metrics(), where the
    closed loop can see the topology drifting)."""
    import warnings

    counters = {"off_list_spawns": 0}

    def factory(replica_id: int):
        addr = addr_list[replica_id] if replica_id < len(addr_list) else None
        if addr is None and addr_list:
            counters["off_list_spawns"] += 1
            warnings.warn(
                f"{topology} replica {replica_id} exceeds the "
                f"{len(addr_list)}-pod attach list; spawning a LOCAL "
                f"worker on the router host", RuntimeWarning, stacklevel=2)
        return klass(cfg, addr=addr, replica_id=replica_id, **fixed)

    factory.counters = counters
    return factory


def _coerce(obj) -> Replica:
    """Legacy factories return bare ServingEngines — wrap them."""
    return InProcessReplica(obj) if isinstance(obj, ServingEngine) else obj


class ReplicaRouter:
    def __init__(self, replica_factory, *, n_replicas: int = 1,
                 max_replicas: int = 8):
        """replica_factory(replica_id) -> Replica (or a bare ServingEngine,
        which is wrapped in-process for backward compatibility)."""
        self._factory = replica_factory
        self.max_replicas = max_replicas
        self.replicas: list[Replica] = []
        self._parked: list[Replica] = []
        self._retired: list[Replica] = []     # failed, kept for accounting
        # retirement reports still owed, (phase, replica): phase 0 → the
        # crash report goes out next reports() round, phase 1 → the clean
        # tombstone does.  One structure, drained in one place.
        self._dying: list[tuple[int, Replica]] = []
        self._undelivered: list[Request] = []  # survived a mid-step raise
        self._next_replica_id = 0
        self._target = max(n_replicas, 1)
        self._t0: float | None = None
        self._last_now = 0.0
        for _ in range(self._target):
            self._add_replica()

    @classmethod
    def shared_core(cls, cfg, *, slots: int, max_seq: int, seed: int = 0,
                    prefill_chunk: int | None = None, n_replicas: int = 1,
                    max_replicas: int = 8) -> "ReplicaRouter":
        """In-process router whose replicas share one EngineCore (params +
        compiles)."""
        return cls.from_topology(cfg, "inproc", slots=slots, max_seq=max_seq,
                                 seed=seed, prefill_chunk=prefill_chunk,
                                 n_replicas=n_replicas,
                                 max_replicas=max_replicas)

    @classmethod
    def from_topology(cls, cfg, topology: str, *, slots: int, max_seq: int,
                      seed: int = 0, prefill_chunk: int | None = None,
                      n_replicas: int = 1, max_replicas: int = 8,
                      mesh=None, addrs=None, pod_size: int = 2,
                      batch_submits: bool = True, pool: str = "dense",
                      block_size: int | None = None,
                      num_blocks: int | None = None, spec_k: int = 0,
                      spec_ngram: int = 3) -> "ReplicaRouter":
        """Build the fleet for one of the five replica topologies.

        inproc  — replicas share one EngineCore (no re-init / re-jit).
        sharded — each replica spans the local device mesh (slot axis
                  sharded); replicas share the core AND one sharded decode
                  compile.
        proc    — each replica is a worker subprocess; workers re-derive
                  identical params from the shared seed, so token streams
                  match the in-process topology bit-for-bit.
        tcp     — each replica dials a listening TCP worker: ``addrs``
                  lists pre-started pods to attach to (cross-host);
                  replica ids past the list spawn local workers on
                  kernel-picked ports, so scale-up keeps working in a demo
                  without a pod scheduler.
        pod     — each replica is a MULTI-PROCESS pod of ``pod_size``
                  worker ranks behind one head (DistributedPodReplica):
                  ``addrs`` lists pre-scheduled pod HEAD addresses;
                  replica ids past the list launch local pods.

        ``batch_submits`` (proc/tcp/pod) folds per-tick submits into the
        step RPC — one message per round per replica instead of one per
        request.  For the attach topologies, off-list local spawns are
        counted in ``metrics()["off_list_spawns"]``.

        ``pool`` ∈ {"dense", "paged"} selects each replica's KV layout
        (serving/slots.py); ``block_size``/``num_blocks`` tune the paged
        pool's geometry.  The layout is observationally invisible — token
        streams match the dense pool bit-for-bit on every topology.

        ``spec_k``/``spec_ngram`` turn on speculative decoding inside each
        replica's engine (serving/engine.py) — also observationally
        invisible: accepted drafts are exact matches, so token streams are
        bit-identical with speculation on or off.  The sharded topology
        accepts the knobs but serves the plain path (its decode step is
        compiled for single-position ticks).
        """
        if topology not in TOPOLOGIES:
            raise ValueError(f"unknown topology {topology!r} "
                             f"(expected one of {TOPOLOGIES})")
        pool_kw = dict(pool=pool, block_size=block_size,
                       num_blocks=num_blocks, spec_k=spec_k,
                       spec_ngram=spec_ngram)
        if topology == "proc":
            from repro.serving.replica import ProcessReplica

            def factory(replica_id: int):
                return ProcessReplica(cfg, slots=slots, max_seq=max_seq,
                                      seed=seed, prefill_chunk=prefill_chunk,
                                      replica_id=replica_id,
                                      batch_submits=batch_submits, **pool_kw)
        elif topology == "tcp":
            from repro.serving.replica import TcpReplica
            factory = _attach_factory(
                TcpReplica, cfg, list(addrs or []), topology, slots=slots,
                max_seq=max_seq, seed=seed, prefill_chunk=prefill_chunk,
                batch_submits=batch_submits, **pool_kw)
        elif topology == "pod":
            from repro.serving.replica import DistributedPodReplica
            factory = _attach_factory(
                DistributedPodReplica, cfg, list(addrs or []), topology,
                slots=slots, max_seq=max_seq, seed=seed,
                prefill_chunk=prefill_chunk, pod_size=pod_size,
                batch_submits=batch_submits, **pool_kw)
        elif topology == "sharded":
            from repro.serving.replica import (
                ShardedReplica, make_sharded_decode,
            )
            if mesh is None:
                import jax

                from repro.launch.mesh import make_mesh
                mesh = make_mesh((len(jax.devices()),), ("data",))
            core = EngineCore(cfg, max_seq, seed=seed)
            decode_fn = make_sharded_decode(cfg, mesh, slots, max_seq,
                                            pool=pool, block_size=block_size,
                                            num_blocks=num_blocks)

            def factory(replica_id: int):
                return ShardedReplica(cfg, slots=slots, max_seq=max_seq,
                                      mesh=mesh, seed=seed,
                                      prefill_chunk=prefill_chunk, core=core,
                                      replica_id=replica_id,
                                      decode_fn=decode_fn, **pool_kw)
        else:
            core = EngineCore(cfg, max_seq, seed=seed)

            def factory(replica_id: int):
                return InProcessReplica.build(
                    cfg, slots=slots, max_seq=max_seq,
                    prefill_chunk=prefill_chunk, core=core,
                    replica_id=replica_id, **pool_kw)

        return cls(factory, n_replicas=n_replicas, max_replicas=max_replicas)

    # ------------------------------------------------------------- topology

    def _add_replica(self):
        if self._parked:
            rep = self._parked.pop()
            rep.resume()
        else:
            rep = _coerce(self._factory(self._next_replica_id))
            self._next_replica_id += 1
        self.replicas.append(rep)

    @property
    def serving_replicas(self) -> list[Replica]:
        return [r for r in self.replicas if not r.draining and not r.failed]

    @property
    def replica_count(self) -> int:
        return len(self.serving_replicas)

    def scale_to(self, n: int, now: float = 0.0) -> int:
        """Actuate a control-plane decision; returns the realized count."""
        n = max(1, min(int(n), self.max_replicas))
        self._target = n
        while self.replica_count < n:
            self._add_replica()
        extra = self.replica_count - n
        if extra > 0:
            victims = sorted(self.serving_replicas,
                             key=lambda r: -r.replica_id)[:extra]
            displaced: list[Request] = []
            for rep in victims:
                # queued AND in-flight leave with the replica, which parks
                # immediately — nothing is stranded behind a parked replica
                displaced.extend(rep.evacuate())
                self.replicas.remove(rep)
                self._parked.append(rep)
            for req in displaced:          # requeue through the survivors
                self.submit(req, now=now)
        return self.replica_count

    def evict(self, replica_id: int, now: float = 0.0, *,
              replace: bool = True) -> bool:
        """Remove one replica (straggler eviction / failure reaping): its
        requests are requeued through the survivors and — when ``replace``
        — a fresh replica restores the actuated count.

        The victim RETIRES, it does not park: parking would hand the same
        slow worker straight back to the next scale-up or eviction
        replacement (``_add_replica`` pops parked replicas LIFO), churning
        evict→revive forever.  Parking is for scale_to downscale (healthy
        warm-revive candidates); an evicted replica was condemned for
        cause."""
        rep = next((r for r in self.replicas if r.replica_id == replica_id),
                   None)
        if rep is None:
            return False
        displaced = rep.evacuate()
        displaced.extend(rep.lost_requests())
        self.replicas.remove(rep)
        # replacement first, THEN retire the victim (the order matters for
        # replica_count and keeps this path symmetric with scale_to's)
        if replace and self.replica_count < self._target:
            self._add_replica()
        rep.close()
        self._retired.append(rep)
        if rep.failed:
            self._dying.append((0, rep))   # crash report, then tombstone
        else:
            # healthy straggler: one clean tombstone prunes its collector
            # latency EWMA, so the retired id drops off the straggler feed
            # instead of being re-flagged (and re-proposed) forever
            self._dying.append((1, rep))
        for req in displaced:
            self.submit(req, now=now)
        return True

    def evict_stragglers(self, straggler_ids, now: float = 0.0) -> list[int]:
        """Control-plane hook: evict every flagged replica (the collector's
        ``stragglers()`` feed), replacing each to hold the actuated count."""
        evicted = []
        for rid in list(straggler_ids):
            if self.evict(rid, now=now):
                evicted.append(rid)
        return evicted

    # ------------------------------------------------------------- requests

    def submit(self, request: Request, now: float = 0.0):
        """Least-loaded routing.  A replica whose transport died between
        steps only reveals itself when an RPC touches it — the submit that
        discovers the corpse reroutes to the next survivor instead of
        crashing the driver (the dead replica is excluded the moment its
        stub flips ``failed``, and the next step() reaps it properly)."""
        if request.t_submit is None:
            request.t_submit = now
        if self._t0 is None or request.t_submit < self._t0:
            self._t0 = request.t_submit
        while True:
            candidates = self.serving_replicas
            if not candidates:
                # every live replica is a corpse (single-replica fleet whose
                # worker died between steps): reap them NOW — eviction
                # builds the replacements step() would have built
                failed = [r for r in self.replicas if r.failed]
                if not failed:
                    raise RuntimeError("no live replicas to route to")
                for rep in failed:
                    self.evict(rep.replica_id, now=now)
                continue
            rep = min(candidates, key=lambda r: (r.load, r.replica_id))
            try:
                rep.submit(request, now=now)
                return
            except TransportError:
                continue               # rep is now failed → excluded above

    def step(self, now: float = 0.0) -> list[Request]:
        """One tick across every live replica, split-phase: the round BEGINS
        on every replica before any result is collected, so remote workers
        decode concurrently (the round costs the slowest worker, not the
        sum).  Replicas whose transport died are reaped afterwards: lost
        requests rewound and requeued, replacements built to restore the
        actuated count."""
        live = list(self.replicas)
        for rep in live:
            rep.begin_step(now)
        # completions already collected must survive a later replica's
        # finish_step raising (their stubs have handed them over — they are
        # not recoverable anywhere else): stash and redeliver next step
        completed, self._undelivered = self._undelivered, []
        try:
            for rep in live:
                completed.extend(rep.finish_step())
        except Exception:
            self._undelivered = completed
            raise
        for rep in [r for r in self.replicas if r.failed]:
            self.evict(rep.replica_id, now=now)
        self._last_now = max(self._last_now, now)
        return completed

    @property
    def pending(self) -> int:
        """Requests somewhere in the system (queued or in a slot)."""
        return sum(r.pending for r in self.replicas)

    # ------------------------------------------------------------- metrics

    def reports(self, tick: int) -> list[ReplicaReport]:
        """Per-replica reports for MetricsCollector.submit (drains each
        replica's metric window).  Parked replicas keep reporting (empty
        windows): the collector re-counts each replica's LAST report every
        aggregate, so going silent would replay a parked replica's final
        spike window forever — an explicit empty report zeroes it out.

        A retired (failed, closed) replica sends exactly TWO more reports:
        first its crash report (n_errors > 0 — this is what puts the crash
        on the collector's straggler list and in the fleet error rate; the
        reap happened inside step(), so without this the control plane
        would never see the failure at all), then one clean tombstone — a
        final n_errors report left in place would replay forever, keeping a
        long-dead replica flagged.

        A PARKED replica whose worker died (discovered by this very report
        poll) joins the same retirement flow here — nothing else ever
        touches parked replicas, so this is the only place the corpse can
        be noticed."""
        out = [rep.report(tick) for rep in self.replicas]
        dying_now, self._dying = self._dying, []
        for rep in list(self._parked):
            out.append(rep.report(tick))    # the poll that detects death
            if rep.failed:                  # that report WAS its crash one:
                self._parked.remove(rep)    # tombstone next round, never
                rep.close()                 # the same one
                self._retired.append(rep)
                self._dying.append((1, rep))
        for phase, rep in dying_now:        # one owed report per round
            if phase == 0:                  # crash report (parent-side stub)
                out.append(rep.report(tick))
                self._dying.append((1, rep))
            else:                           # clean-up for the crash report
                out.append(empty_report(rep.replica_id, tick))
        return out

    def metrics(self) -> dict:
        """Fleet-level aggregates over replica lifetimes (parked and failed
        replicas keep their history — work they served must not vanish)."""
        ever = [r.lifetime() for r in
                self.replicas + self._parked + self._retired]
        lats = [l for lt in ever for l in lt["latencies_ms"]]
        lat = np.asarray(lats) if lats else np.zeros(1)
        tokens = sum(lt["total_tokens"] for lt in ever)
        completed = sum(lt["total_completed"] for lt in ever)
        wall = max(self._last_now - (self._t0 or 0.0), 1e-9)
        return {
            "latency_p50_ms": float(np.percentile(lat, 50)),
            "latency_p95_ms": float(np.percentile(lat, 95)),
            "throughput_tok_s": tokens / wall,
            "completed": completed,
            "completed_tokens": tokens,
            "slot_utilization": float(np.mean(
                [lt["slot_utilization"] for lt in ever])),
            "queue_depth": sum(r.queue_depth for r in self.replicas),
            "transport_ms": float(np.mean(
                [r.transport_ms for r in self.replicas])) if self.replicas
            else 0.0,
            # frames this fleet put on the wire over its lifetime (0 for
            # in-process fleets) — the submit-batching benchmark metric
            "rpc_count": sum(getattr(r, "rpc_count", 0) for r in
                             self.replicas + self._parked + self._retired),
            # attach topologies: replacements/scale-ups that fell off the
            # operator's explicit attach list onto router-host workers —
            # topology drift the closed loop should see, not just stderr
            "off_list_spawns": getattr(self._factory, "counters",
                                       {}).get("off_list_spawns", 0),
            "replicas": self.replica_count,
            # paged-pool cache efficiency, fleet-wide — engines only report
            # these when running a paged KV pool, so dense fleets read 0
            "prefix_hits": sum(lt.get("prefix_hits", 0) for lt in ever),
            "tokens_shared": sum(lt.get("tokens_shared", 0) for lt in ever),
            "prefill_tokens": sum(lt.get("prefill_tokens", 0) for lt in ever),
            "prompt_tokens": sum(lt.get("prompt_tokens", 0) for lt in ever),
            # speculative decoding, fleet-wide: draft tokens proposed and
            # accepted over every engine's lifetime (0 with speculation off)
            "spec_proposed": sum(lt.get("spec_proposed", 0) for lt in ever),
            "spec_accepted": sum(lt.get("spec_accepted", 0) for lt in ever),
        }

    def close(self):
        """Release every replica (terminates proc-topology workers)."""
        for rep in self.replicas + self._parked:
            rep.close()
        self.replicas.clear()
        self._parked.clear()
