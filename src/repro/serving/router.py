"""ReplicaRouter: N engines behind least-loaded routing, scalable mid-run.

The router is the surface the control plane drives: `scale_to(n)` is the
actuator for DynamicScaler / PredictiveAllocator decisions, and `reports()`
emits the per-replica ReplicaReport stream that core/monitoring's
MetricsCollector consumes (p50/p95 latency, throughput, slot utilization,
queue depth).

Scaling semantics:
* up   — revive a draining replica if one exists (warm), else unpark a
         previously retired engine, else build a new one via the factory
         (engines share one EngineCore, so this is cheap: no re-init/re-jit).
* down — mark the newest replicas "draining": they admit nothing new, their
         queued (not yet admitted) requests are immediately re-routed to the
         survivors, and the replica is retired to the warm pool once its
         in-flight slots finish.  No request is ever lost or duplicated.
"""
from __future__ import annotations

import numpy as np

from repro.core.monitoring.collector import ReplicaReport
from repro.serving.engine import EngineCore, ServingEngine
from repro.serving.scheduler import Request


class ReplicaRouter:
    def __init__(self, engine_factory, *, n_replicas: int = 1,
                 max_replicas: int = 8):
        """engine_factory(replica_id) -> ServingEngine."""
        self._factory = engine_factory
        self.max_replicas = max_replicas
        self.engines: list[ServingEngine] = []
        self._parked: list[ServingEngine] = []
        self._next_replica_id = 0
        self._t0: float | None = None
        self._last_now = 0.0
        for _ in range(max(n_replicas, 1)):
            self._add_replica()

    @classmethod
    def shared_core(cls, cfg, *, slots: int, max_seq: int, seed: int = 0,
                    prefill_chunk: int | None = None, n_replicas: int = 1,
                    max_replicas: int = 8) -> "ReplicaRouter":
        """Router whose replicas share one EngineCore (params + compiles)."""
        core = EngineCore(cfg, max_seq, seed=seed)

        def factory(replica_id: int) -> ServingEngine:
            return ServingEngine(cfg, slots=slots, max_seq=max_seq,
                                 prefill_chunk=prefill_chunk, core=core,
                                 replica_id=replica_id)

        return cls(factory, n_replicas=n_replicas, max_replicas=max_replicas)

    # ------------------------------------------------------------- topology

    def _add_replica(self):
        if self._parked:
            eng = self._parked.pop()
            eng.draining = False
        else:
            eng = self._factory(self._next_replica_id)
            self._next_replica_id += 1
        self.engines.append(eng)

    @property
    def serving_engines(self) -> list[ServingEngine]:
        return [e for e in self.engines if not e.draining]

    @property
    def replica_count(self) -> int:
        return len(self.serving_engines)

    def scale_to(self, n: int, now: float = 0.0) -> int:
        """Actuate a control-plane decision; returns the realized count."""
        n = max(1, min(int(n), self.max_replicas))
        for eng in self.engines:                 # revive drains first (warm)
            if self.replica_count >= n:
                break
            if eng.draining:
                eng.draining = False
        while self.replica_count < n:
            self._add_replica()
        extra = self.replica_count - n
        if extra > 0:
            victims = sorted(self.serving_engines,
                             key=lambda e: -e.replica_id)[:extra]
            for eng in victims:
                eng.draining = True
            for eng in victims:                  # hand backlog to survivors
                for req in eng.scheduler.drain():
                    self.submit(req, now=now)
        return self.replica_count

    # ------------------------------------------------------------- requests

    def submit(self, request: Request, now: float = 0.0):
        if request.t_submit is None:
            request.t_submit = now
        if self._t0 is None or request.t_submit < self._t0:
            self._t0 = request.t_submit
        eng = min(self.serving_engines,
                  key=lambda e: (e.load, e.replica_id))
        eng.submit(request, now=now)

    def step(self, now: float = 0.0) -> list[Request]:
        """One tick across every replica (including draining ones, which
        still finish their in-flight slots)."""
        completed: list[Request] = []
        for eng in list(self.engines):
            completed.extend(eng.step(now))
        for eng in [e for e in self.engines if e.draining and e.idle]:
            if len(self.engines) > 1:
                self.engines.remove(eng)
                self._parked.append(eng)
        self._last_now = max(self._last_now, now)
        return completed

    @property
    def pending(self) -> int:
        """Requests somewhere in the system (queued or in a slot)."""
        return sum(e.scheduler.depth + int(e.active.sum())
                   for e in self.engines)

    # ------------------------------------------------------------- metrics

    def reports(self, tick: int) -> list[ReplicaReport]:
        """Per-replica reports for MetricsCollector.submit (drains each
        engine's metric window).  Parked replicas keep reporting (empty
        windows): the collector re-counts each replica's LAST report every
        aggregate, so going silent would replay a parked replica's final
        spike window forever — an explicit empty report zeroes it out."""
        out = []
        for eng in self.engines + self._parked:
            w = eng.stats.drain_window()
            out.append(ReplicaReport(
                replica_id=eng.replica_id, tick=tick,
                latency_ms_samples=w["latency_ms_samples"],
                n_requests=w["n_requests"], n_errors=0,
                flop_util=w["slot_util"],
                hbm_util=w["slot_util"],          # CPU engine: slot occupancy
                ici_util=0.0,                     # stands in for chip signals
                mem_frac=w["slot_util"],
                queue_depth=w["queue_depth"]))
        return out

    def metrics(self) -> dict:
        """Fleet-level aggregates over engine lifetimes (parked replicas
        keep their history — work they served must not vanish on drain)."""
        ever = self.engines + self._parked
        lats = [l for e in ever for l in e.stats.latencies_ms]
        lat = np.asarray(lats) if lats else np.zeros(1)
        tokens = sum(e.stats.total_tokens for e in ever)
        completed = sum(e.stats.total_completed for e in ever)
        wall = max(self._last_now - (self._t0 or 0.0), 1e-9)
        return {
            "latency_p50_ms": float(np.percentile(lat, 50)),
            "latency_p95_ms": float(np.percentile(lat, 95)),
            "throughput_tok_s": tokens / wall,
            "completed": completed,
            "completed_tokens": tokens,
            "slot_utilization": float(np.mean(
                [e.stats.slot_utilization for e in ever])),
            "queue_depth": sum(e.scheduler.depth for e in self.engines),
            "replicas": self.replica_count,
        }
