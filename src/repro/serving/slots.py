"""KV slot pool: one shared cache pytree, S decode slots, per-slot positions.

``write_slot`` merges a single-request cache (batch 1) into the pool at a slot
index by detecting the batch axis structurally — the axis where the pool is
slot-sized and the single-request leaf is 1.  That one rule covers every
family's cache layout without family-specific code:

  dense/moe/vlm  k/v        (L, B, W, KV, hd)        → axis 1
  swa            k/v        (L, B, window, KV, hd)   → axis 1
  ssm            h / conv   (L, B, ...)              → axis 1
  hybrid         mamba      (G, A, B, ...)           → axis 2
                 attn k/v   (G, B, W, KV, hd)        → axis 1
  audio          self/cross (L, B, ...)              → axis 1

The pool's "index" leaf is a (slots,) int32 vector of per-slot absolute
positions (the seed engine kept a single scalar — every slot decoded with the
max position's RoPE angles and validity mask, which is wrong the moment
admissions stagger).  LM.decode accepts the vector directly.

``PagedSlotPool`` replaces the dense per-slot ring with a block-table pool:
every *pageable* cache leaf (logical "cache_seq" axis sized max_seq — i.e.
full-attention K/V) is re-laid as (A, NB, block, KV, hd) physical blocks
shared by all slots, a (slots, nk) "block_tbl" cache entry names each slot's
blocks, and blocks are refcounted with prefix sharing: admission of a prompt
whose block-aligned prefix is already resident maps the shared blocks
read-only and skips that part of prefill entirely.  Non-pageable leaves
(SSM state, sliding-window rings, cross K/V) keep the dense per-slot layout.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LM


def write_slot(pool, one, slot: int):
    """Merge one batch-1 cache leaf into the pool leaf at ``slot``.

    Identical shapes (a 1-slot pool) are a whole-pool overwrite — the seed's
    axis scan found no differing axis and silently dropped the write.

    A single-request leaf SHORTER than the pool on a non-batch axis is
    zero-padded up to the pool size before the write: enc-dec prefill emits
    encoder-length cross K/V, (L, 1, S_enc, KV, hd), while the pool spec is
    max_seq-sized — the pad rows sit past ``cross_len`` and are masked at
    decode, so padding with zeros is exact."""
    if pool.ndim == 0:          # defensive: scalar leaf — keep the max
        return jnp.maximum(pool, one)
    one = _pad_to_pool(pool, one)
    if pool.shape == one.shape:
        return one.astype(pool.dtype)
    for ax in range(pool.ndim):
        if one.shape[ax] == 1 and pool.shape[ax] != one.shape[ax]:
            idx = [slice(None)] * pool.ndim
            idx[ax] = slice(slot, slot + 1)
            return pool.at[tuple(idx)].set(one.astype(pool.dtype))
    return pool


def _pad_to_pool(pool, one):
    """Zero-pad ``one`` up to the pool's size on every non-batch axis (the
    batch axis is the one where one==1 and the pool differs)."""
    if pool.ndim != one.ndim:
        return one
    batch_ax = next((ax for ax in range(pool.ndim)
                     if one.shape[ax] == 1 and pool.shape[ax] != 1), None)
    pad = []
    for ax in range(pool.ndim):
        short = pool.shape[ax] - one.shape[ax]
        if ax == batch_ax or short <= 0:
            pad.append((0, 0))
        else:
            pad.append((0, short))
    if any(p != (0, 0) for p in pad):
        one = jnp.pad(one, pad)
    return one


class SlotPool:
    """The engine's shared decode cache with slot-granular writes."""

    def __init__(self, cfg, slots: int, max_seq: int):
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.cache = LM.init_cache(cfg, slots, max_seq)
        # per-slot absolute positions replace the scalar index leaf
        self.cache["index"] = jnp.zeros((slots,), jnp.int32)

    @property
    def index(self) -> jnp.ndarray:
        return self.cache["index"]

    def write(self, one, slot: int, *, index=None):
        """Write a batch-1 cache pytree (from prefill) into ``slot``; the
        slot's position is set to ``index`` (default: the one-cache's own)."""
        rest_pool = {k: v for k, v in self.cache.items() if k != "index"}
        rest_one = {k: v for k, v in one.items() if k != "index"}
        rest = jax.tree.map(lambda p, o: write_slot(p, o, slot),
                            rest_pool, rest_one)
        pos = one["index"] if index is None else index
        idx = self.cache["index"].at[slot].set(jnp.asarray(pos, jnp.int32))
        self.cache = {**rest, "index": idx}

    def set_index(self, values):
        self.cache = {**self.cache, "index": jnp.asarray(values, jnp.int32)}

    def set_slot_index(self, slot: int, pos):
        idx = self.cache["index"].at[slot].set(jnp.asarray(pos, jnp.int32))
        self.cache = {**self.cache, "index": idx}


# ---------------------------------------------------------------------------
# paged pool: block-granular allocation + refcounted prefix sharing
# ---------------------------------------------------------------------------


def _is_spec_leaf(x):
    return (isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple))


def _pageable(shape, axes, max_seq: int) -> bool:
    """A leaf pages iff it has a logical "cache_seq" axis sized max_seq —
    full-attention K/V.  A sliding-window ring (cache_seq == window <
    max_seq) is already bounded and wraps, so block-granular allocation
    buys nothing and the ring arithmetic stays dense."""
    return "cache_seq" in axes and shape[axes.index("cache_seq")] == max_seq


def paged_cache_spec(cfg, slots: int, max_seq: int, *, block_size: int,
                     num_blocks: int):
    """LM.cache_spec with pageable leaves re-laid as block pools.

    Pageable (A, slots, max_seq, KV, hd) leaves become
    (A, num_blocks, block_size, KV, hd) with logical axes
    ("layers", "cache_blocks", None, "kv_heads", None) — the block axis
    takes over the role the slot axis played for sharding (pod_decode_rules
    maps "cache_blocks" onto the same mesh axes as "batch", so a shard owns
    a contiguous range of physical blocks exactly as it owns a contiguous
    range of slots).  Adds the (slots, nk) int32 "block_tbl" leaf."""
    assert max_seq % block_size == 0, (max_seq, block_size)
    nk = max_seq // block_size

    def one(leaf):
        shape, dtype, axes = leaf
        if not _pageable(shape, axes, max_seq):
            return leaf
        b_ax = axes.index("batch")
        s_ax = axes.index("cache_seq")
        assert s_ax == b_ax + 1, (axes,)   # (…, batch, cache_seq, …)
        new_shape = (shape[:b_ax] + (num_blocks, block_size)
                     + shape[s_ax + 1:])
        new_axes = (axes[:b_ax] + ("cache_blocks", None) + axes[s_ax + 1:])
        return (new_shape, dtype, new_axes)

    spec = jax.tree.map(one, LM.cache_spec(cfg, slots, max_seq),
                        is_leaf=_is_spec_leaf)
    spec["index"] = ((slots,), jnp.int32, ("batch",))
    if any(_pageable(s, ax, max_seq) for s, _, ax in
           jax.tree.leaves(LM.cache_spec(cfg, slots, max_seq),
                           is_leaf=_is_spec_leaf)):
        spec["block_tbl"] = ((slots, nk), jnp.int32, ("batch", None))
    return spec


def pool_geometry(slots: int, max_seq: int, *, block_size: int | None = None,
                  num_blocks: int | None = None,
                  partitions: int = 1) -> tuple[int, int]:
    """Resolve (block_size, num_blocks) defaults — shared by PagedSlotPool
    and make_sharded_decode so the spec derivation and the engine's actual
    pool always agree on the cache geometry."""
    if block_size is None:
        # largest divisor of max_seq <= 8: the default must always yield a
        # valid geometry (max_seq=12 → bk=6), not crash on non-multiples
        bk = next(d for d in range(min(8, max_seq), 0, -1)
                  if max_seq % d == 0)
    else:
        bk = block_size
        if max_seq % bk != 0:
            raise ValueError(
                f"block_size={bk} must divide max_seq={max_seq} "
                f"(pass a block_size that divides max_seq, or omit it)")
    assert slots % partitions == 0, (slots, partitions)
    nk = max_seq // bk
    per_part = slots // partitions
    if num_blocks is None:
        # enough for every slot at max_seq, plus the trash block
        num_blocks = partitions * (per_part * nk + 1)
    assert num_blocks % partitions == 0, (num_blocks, partitions)
    return bk, num_blocks


def _prefix_key(prompt: np.ndarray, n: int, extra: bytes = b"") -> bytes:
    """Content hash of the first ``n`` prompt tokens — the prefix registry
    key.  Hashing (rather than the raw token tuple) keeps key size O(1) for
    long system prompts.  ``extra`` is mixed in for families whose prefix KV
    depends on more than the token ids (VLM vision patches): two prompts
    with identical ids but different extra content can never alias."""
    return hashlib.sha1(
        extra + np.ascontiguousarray(prompt[:n], dtype=np.int64).tobytes()
    ).digest()


class PagedSlotPool(SlotPool):
    """Block-table pool: pageable K/V leaves live in a shared physical block
    pool; each slot's (nk,) table row names its blocks; blocks are
    refcounted and prompt prefixes are shared copy-on-write.

    Layout / allocator invariants:
      - the pool is split into ``partitions`` contiguous ranges (one per
        shard of a sharded decode); slot s draws only from partition
        ``s * partitions // slots`` — its blocks stay on the shard that owns
        its table row, so the shard_map decode body's global→local id fold
        (``rem(id, NB_local)``) is exact
      - the FIRST block of each partition is that partition's *trash* block:
        inactive slots' table rows point at it, so their garbage decode
        writes land somewhere harmless that no live table row reads
      - a block's refcount = #slot tables naming it + 1 if the prefix
        registry holds it; it returns to the free list at zero
      - admission maps registered prefix blocks read-only (refcount++) and
        allocates private blocks for the rest; the engine only ever writes
        positions >= the shared prefix, so shared blocks are never written
        (``ensure_private`` forks a copy-on-write duplicate for any client
        that does need to write into a shared block)
    """

    def __init__(self, cfg, slots: int, max_seq: int, *,
                 block_size: int | None = None,
                 num_blocks: int | None = None, partitions: int = 1):
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        bk, num_blocks = pool_geometry(slots, max_seq, block_size=block_size,
                                       num_blocks=num_blocks,
                                       partitions=partitions)
        self.block_size = bk
        self.nk = max_seq // bk
        self.partitions = partitions
        self.num_blocks = num_blocks
        self.nb_local = num_blocks // partitions
        assert self.nb_local >= self.nk + 1, \
            "need at least one slot's worth of blocks + trash per partition"

        spec = paged_cache_spec(cfg, slots, max_seq, block_size=bk,
                                num_blocks=num_blocks)
        self._paged_leaf = jax.tree.map(
            lambda s: s[2] is not None and "cache_blocks" in s[2],
            {k: v for k, v in spec.items() if k not in ("index", "block_tbl")},
            is_leaf=_is_spec_leaf)
        self.cache = jax.tree.map(lambda s: jnp.zeros(s[0], s[1]), spec,
                                  is_leaf=_is_spec_leaf)

        # host-side allocator state
        self.trash = [p * self.nb_local for p in range(partitions)]
        self.free: list[list[int]] = [
            [p * self.nb_local + i for i in range(1, self.nb_local)]
            for p in range(partitions)]
        self.refcount = np.zeros(num_blocks, np.int64)
        self.tables = np.zeros((slots, self.nk), np.int32)
        for s in range(slots):
            self.tables[s, :] = self.trash[self._partition(s)]
        self.slot_blocks: list[list[int]] = [[] for _ in range(slots)]
        # per-partition prefix registry: key → block id, LRU-ordered.
        # Sharing needs the ENTIRE per-slot decode state to live in pageable
        # leaves (+ index) — recurrent SSM/mamba state or cross K/V encodes
        # the full prefix outside the blocks, so skipping prefill for those
        # families would skip state the blocks don't carry.
        self.can_share = (cfg.ssm is None and cfg.hybrid is None
                          and not cfg.enc_dec
                          and bool(jax.tree.leaves(self._paged_leaf))
                          and all(jax.tree.leaves(self._paged_leaf)))
        self.registry: list[OrderedDict] = [OrderedDict()
                                            for _ in range(partitions)]
        self._block_key: dict[int, bytes] = {}
        # prefix-cache observability (scraped into EngineStats.lifetime)
        self.n_admits = 0
        self.n_prefix_hits = 0
        self.tokens_shared = 0
        self._sync_tables()

    # ------------------------------------------------------------- layout

    @property
    def is_paged(self) -> bool:
        return "block_tbl" in self.cache

    def _partition(self, slot: int) -> int:
        return slot * self.partitions // self.slots

    def _block_partition(self, block: int) -> int:
        return block // self.nb_local

    def _sync_tables(self, slot: int | None = None):
        if "block_tbl" not in self.cache:
            return
        if slot is None:
            tbl = jnp.asarray(self.tables)
        else:
            tbl = self.cache["block_tbl"].at[slot].set(
                jnp.asarray(self.tables[slot]))
        self.cache = {**self.cache, "block_tbl": tbl}

    # ------------------------------------------------------------- alloc

    def blocks_needed(self, total_len: int) -> int:
        return -(-min(total_len, self.max_seq) // self.block_size)

    def lookup_prefix(self, slot: int, prompt: np.ndarray, *,
                      extra: bytes = b""):
        """→ (n_hit_blocks, [block ids]) for the longest registered
        block-aligned prefix of ``prompt`` on this slot's partition.  Capped
        at (P-1)//bk blocks so at least one prompt token always streams
        through the engine (the logits for the first sampled token must come
        from somewhere)."""
        if not self.can_share:
            return 0, []
        reg = self.registry[self._partition(slot)]
        P = len(prompt)
        hit: list[int] = []
        for j in range((P - 1) // self.block_size):
            key = _prefix_key(prompt, (j + 1) * self.block_size, extra)
            blk = reg.get(key)
            if blk is None:
                break
            reg.move_to_end(key)       # LRU touch
            hit.append(blk)
        return len(hit), hit

    def _reclaim(self, part: int, need: int):
        """LRU-evict registry-only blocks (refcount == 1) until the
        partition's free list can cover ``need`` private blocks."""
        reg = self.registry[part]
        while len(self.free[part]) < need:
            victim = next((k for k, b in reg.items()
                           if self.refcount[b] == 1), None)
            if victim is None:
                break
            blk = reg.pop(victim)
            self._block_key.pop(blk, None)
            self.refcount[blk] -= 1
            self.free[part].append(blk)

    def can_admit(self, slot: int, prompt: np.ndarray, gen_len: int, *,
                  extra: bytes = b"") -> bool:
        part = self._partition(slot)
        h, hit = self.lookup_prefix(slot, prompt, extra=extra)
        need = self.blocks_needed(len(prompt) + gen_len) - h
        reg = self.registry[part]
        # the hit blocks are NOT evictable for this admission: admit_slot
        # pins them before reclaiming, so the capacity promise here must
        # match what _reclaim may actually evict
        hit_set = set(hit)
        evictable = sum(1 for b in reg.values()
                        if self.refcount[b] == 1 and b not in hit_set)
        return len(self.free[part]) + evictable >= need

    def admit_slot(self, slot: int, prompt: np.ndarray, gen_len: int, *,
                   extra: bytes = b"") -> int:
        """Build the slot's table row: shared prefix blocks mapped read-only
        (refcount++), private blocks allocated for the rest, remaining table
        entries parked on the trash block.  Returns the number of prompt
        TOKENS already resident (0 → caller runs a full prefill)."""
        part = self._partition(slot)
        assert not self.slot_blocks[slot], f"slot {slot} not released"
        h, shared = self.lookup_prefix(slot, prompt, extra=extra)
        need_total = self.blocks_needed(len(prompt) + gen_len)
        n_priv = need_total - h
        # pin the hit blocks BEFORE reclaiming: a registry-only hit block
        # has refcount == 1 and would otherwise be evictable, so _reclaim
        # could push a block this admission is about to share onto the free
        # list — and the private pops below would hand the same physical
        # block out again as a writable block in the same table row
        for blk in shared:
            self.refcount[blk] += 1
        self._reclaim(part, n_priv)
        if len(self.free[part]) < n_priv:
            for blk in shared:         # roll the pins back; admission failed
                self.refcount[blk] -= 1
            raise AssertionError(
                f"partition {part} exhausted ({n_priv} blocks needed)")
        row = np.full(self.nk, self.trash[part], np.int32)
        for j, blk in enumerate(shared):
            row[j] = blk
        priv = [self.free[part].pop() for _ in range(n_priv)]
        for j, blk in enumerate(priv):
            self.refcount[blk] += 1
            row[h + j] = blk
        self.tables[slot] = row
        self.slot_blocks[slot] = shared + priv
        self._sync_tables(slot)
        self.n_admits += 1
        if h:
            self.n_prefix_hits += 1
            self.tokens_shared += h * self.block_size
        return h * self.block_size

    def register_block(self, slot: int, j: int, prompt: np.ndarray, *,
                       extra: bytes = b""):
        """Publish the slot's j-th block (fully written with
        prompt[:(j+1)·bk]) into the prefix registry — future admissions with
        the same prefix map it read-only.  The registry holds its own
        reference, so the block survives the slot's release."""
        if not self.can_share:
            return
        part = self._partition(slot)
        blk = int(self.tables[slot, j])
        if blk == self.trash[part]:
            return
        key = _prefix_key(prompt, (j + 1) * self.block_size, extra)
        reg = self.registry[part]
        if key in reg:
            return
        reg[key] = blk
        self._block_key[blk] = key
        self.refcount[blk] += 1

    def ensure_private(self, slot: int, j: int):
        """Copy-on-write fork: if the slot's j-th block is shared
        (refcount > 1), allocate a private copy, copy the block's contents
        in every pageable leaf, and repoint the table row.  The serving
        engine never needs this (it only writes past the shared prefix);
        it is the safety valve for clients that edit resident context."""
        part = self._partition(slot)
        blk = int(self.tables[slot, j])
        if blk == self.trash[part] or self.refcount[blk] <= 1:
            return blk
        self._reclaim(part, 1)
        assert self.free[part], f"partition {part} exhausted (COW fork)"
        new = self.free[part].pop()
        b_ax = 1   # pageable leaves are (A, NB, bk, KV, hd)

        def copy(leaf, paged):
            if not paged:
                return leaf
            idx = [slice(None)] * leaf.ndim
            idx[b_ax] = new
            src = [slice(None)] * leaf.ndim
            src[b_ax] = blk
            return leaf.at[tuple(idx)].set(leaf[tuple(src)])

        rest = {k: v for k, v in self.cache.items()
                if k not in ("index", "block_tbl")}
        rest = jax.tree.map(copy, rest, self._paged_leaf)
        self.cache = {**self.cache, **rest}
        self.refcount[new] += 1
        self.refcount[blk] -= 1
        pos = self.slot_blocks[slot].index(blk)
        self.slot_blocks[slot][pos] = new
        self.tables[slot, j] = new
        self._sync_tables(slot)
        return new

    def release(self, slot: int):
        """Drop the slot's references; blocks whose refcount reaches zero
        return to their partition's free list.  Registered prefix blocks
        survive (the registry's own reference keeps them resident)."""
        part = self._partition(slot)
        for blk in self.slot_blocks[slot]:
            self.refcount[blk] -= 1
            if self.refcount[blk] == 0:
                self.free[self._block_partition(blk)].append(blk)
        self.slot_blocks[slot] = []
        self.tables[slot, :] = self.trash[part]
        self._sync_tables(slot)

    def release_registry(self):
        """Drop every prefix-registry reference (engine evacuate): with all
        slots released, every refcount returns to zero and the pool is
        back to its freshly-initialized occupancy."""
        for part, reg in enumerate(self.registry):
            for key, blk in list(reg.items()):
                self.refcount[blk] -= 1
                if self.refcount[blk] == 0:
                    self.free[self._block_partition(blk)].append(blk)
            reg.clear()
        self._block_key.clear()

    # ------------------------------------------------------------- write

    def write(self, one, slot: int, *, index=None):
        """Write a batch-1 DENSE cache pytree (from prefill) into ``slot``:
        dense leaves merge exactly as in SlotPool; pageable leaves are cut
        into bk-token chunks and scattered into the slot's allocated
        physical blocks (shared prefix blocks are never among them — on a
        prefix hit the engine skips prefill, so write() only ever sees
        fully-private admissions)."""
        ids = np.asarray(self.tables[slot], np.int32)
        n_alloc = len(self.slot_blocks[slot])
        bk = self.block_size

        def write_leaf(pool, o, paged):
            if not paged:
                return write_slot(pool, o, slot)
            o = _pad_to_pool_seq(pool, o, self.max_seq)
            # (A, 1, Smax, KV, hd) → (A, nk, bk, KV, hd) chunks
            A = o.shape[0]
            chunks = o[:, 0].reshape((A, self.nk, bk) + o.shape[3:])
            tgt = jnp.asarray(ids[:n_alloc])
            return pool.at[:, tgt].set(
                chunks[:, :n_alloc].astype(pool.dtype))

        rest_pool = {k: v for k, v in self.cache.items()
                     if k not in ("index", "block_tbl")}
        rest_one = {k: v for k, v in one.items() if k != "index"}
        rest = jax.tree.map(write_leaf, rest_pool, rest_one, self._paged_leaf)
        pos = one["index"] if index is None else index
        idx = self.cache["index"].at[slot].set(jnp.asarray(pos, jnp.int32))
        self.cache = {**self.cache, **rest, "index": idx}


def _pad_to_pool_seq(pool, one, max_seq: int):
    """Zero-pad a batch-1 prefill leaf's seq axis (axis 2 of
    (A, 1, S, KV, hd)) up to max_seq so it cuts into nk whole blocks."""
    short = max_seq - one.shape[2]
    if short > 0:
        pad = [(0, 0)] * one.ndim
        pad[2] = (0, short)
        one = jnp.pad(one, pad)
    return one


def make_pool(cfg, slots: int, max_seq: int, *, pool: str = "dense",
              block_size: int | None = None, num_blocks: int | None = None,
              partitions: int = 1):
    """Pool factory: ``pool`` ∈ {"dense", "paged"}."""
    if pool == "paged":
        return PagedSlotPool(cfg, slots, max_seq, block_size=block_size,
                             num_blocks=num_blocks, partitions=partitions)
    assert pool == "dense", pool
    return SlotPool(cfg, slots, max_seq)
