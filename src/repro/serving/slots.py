"""KV slot pool: one shared cache pytree, S decode slots, per-slot positions.

``write_slot`` merges a single-request cache (batch 1) into the pool at a slot
index by detecting the batch axis structurally — the axis where the pool is
slot-sized and the single-request leaf is 1.  That one rule covers every
family's cache layout without family-specific code:

  dense/moe/vlm  k/v        (L, B, W, KV, hd)        → axis 1
  swa            k/v        (L, B, window, KV, hd)   → axis 1
  ssm            h / conv   (L, B, ...)              → axis 1
  hybrid         mamba      (G, A, B, ...)           → axis 2
                 attn k/v   (G, B, W, KV, hd)        → axis 1
  audio          self/cross (L, B, ...)              → axis 1

The pool's "index" leaf is a (slots,) int32 vector of per-slot absolute
positions (the seed engine kept a single scalar — every slot decoded with the
max position's RoPE angles and validity mask, which is wrong the moment
admissions stagger).  LM.decode accepts the vector directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import LM


def write_slot(pool, one, slot: int):
    """Merge one batch-1 cache leaf into the pool leaf at ``slot``.

    Identical shapes (a 1-slot pool) are a whole-pool overwrite — the seed's
    axis scan found no differing axis and silently dropped the write.

    A single-request leaf SHORTER than the pool on a non-batch axis is
    zero-padded up to the pool size before the write: enc-dec prefill emits
    encoder-length cross K/V, (L, 1, S_enc, KV, hd), while the pool spec is
    max_seq-sized — the pad rows sit past ``cross_len`` and are masked at
    decode, so padding with zeros is exact."""
    if pool.ndim == 0:          # defensive: scalar leaf — keep the max
        return jnp.maximum(pool, one)
    one = _pad_to_pool(pool, one)
    if pool.shape == one.shape:
        return one.astype(pool.dtype)
    for ax in range(pool.ndim):
        if one.shape[ax] == 1 and pool.shape[ax] != one.shape[ax]:
            idx = [slice(None)] * pool.ndim
            idx[ax] = slice(slot, slot + 1)
            return pool.at[tuple(idx)].set(one.astype(pool.dtype))
    return pool


def _pad_to_pool(pool, one):
    """Zero-pad ``one`` up to the pool's size on every non-batch axis (the
    batch axis is the one where one==1 and the pool differs)."""
    if pool.ndim != one.ndim:
        return one
    batch_ax = next((ax for ax in range(pool.ndim)
                     if one.shape[ax] == 1 and pool.shape[ax] != 1), None)
    pad = []
    for ax in range(pool.ndim):
        short = pool.shape[ax] - one.shape[ax]
        if ax == batch_ax or short <= 0:
            pad.append((0, 0))
        else:
            pad.append((0, short))
    if any(p != (0, 0) for p in pad):
        one = jnp.pad(one, pad)
    return one


class SlotPool:
    """The engine's shared decode cache with slot-granular writes."""

    def __init__(self, cfg, slots: int, max_seq: int):
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.cache = LM.init_cache(cfg, slots, max_seq)
        # per-slot absolute positions replace the scalar index leaf
        self.cache["index"] = jnp.zeros((slots,), jnp.int32)

    @property
    def index(self) -> jnp.ndarray:
        return self.cache["index"]

    def write(self, one, slot: int, *, index=None):
        """Write a batch-1 cache pytree (from prefill) into ``slot``; the
        slot's position is set to ``index`` (default: the one-cache's own)."""
        rest_pool = {k: v for k, v in self.cache.items() if k != "index"}
        rest_one = {k: v for k, v in one.items() if k != "index"}
        rest = jax.tree.map(lambda p, o: write_slot(p, o, slot),
                            rest_pool, rest_one)
        pos = one["index"] if index is None else index
        idx = self.cache["index"].at[slot].set(jnp.asarray(pos, jnp.int32))
        self.cache = {**rest, "index": idx}

    def set_index(self, values):
        self.cache = {**self.cache, "index": jnp.asarray(values, jnp.int32)}
