"""Wire format for the replica fabric: length-prefixed JSON frames.

The replica boundary is a *message protocol*, not an object reference —
every request, completion, and metric report that crosses it is encoded
here, so an engine driven over a socket (ProcessReplica) is observationally
identical to one held in-process.  Design points:

* **Framing.**  Each message is a 4-byte big-endian length followed by a
  UTF-8 JSON payload.  ``Connection.recv`` loops on the socket until the
  whole frame arrives (kernel buffers split frames arbitrarily — a partial
  read is the common case under load, not an error), and raises
  ``TransportError`` on EOF so a dead peer surfaces as a catchable failure,
  never a hang.  ``MAX_FRAME`` is enforced on BOTH ends: the receiver
  rejects an oversized declared length before allocating for it, and the
  sender refuses to emit a frame the peer is guaranteed to drop the
  connection over.

* **Transport-agnostic frames, TCP endpoints.**  ``Connection`` works over
  any stream socket (ProcessReplica rides a socketpair).  For cross-host
  replicas, ``Listener``/``dial`` provide the TCP endpoints: a worker binds
  and accepts (``worker.py --listen host:port``), the router dials with a
  connect deadline.  Both ends get TCP keepalive (a silently-vanished peer
  eventually surfaces as an error instead of a permanently-stuck fleet)
  and TCP_NODELAY (frames are small RPCs; Nagle would add 40 ms stalls to
  every decode round).

* **JSON, not pickle.**  The worker executes nothing it receives; a replica
  peer is a *service*, not a code-injection channel.  Python's JSON codec
  round-trips NaN/±Infinity (``allow_nan``), which metric payloads do
  contain (an empty latency window aggregates to NaN upstream).

* **Typed codecs.**  ``encode_request``/``decode_request`` and
  ``encode_report``/``decode_report`` pin the exact field set that crosses
  the wire; ``encode_config``/``decode_config`` rebuild a frozen
  ModelConfig (with its nested MoE/SSM/Hybrid sub-configs) so a worker can
  construct the identical engine from the handshake message alone.
"""
from __future__ import annotations

import dataclasses
import json
import socket
import struct

import numpy as np

from repro.core.monitoring.collector import ReplicaReport
from repro.models.config import HybridCfg, ModelConfig, MoECfg, SSMCfg
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Request

_LEN = struct.Struct(">I")
MAX_FRAME = 1 << 30           # 1 GiB sanity bound on a single frame


class TransportError(ConnectionError):
    """The peer is gone (EOF / reset / timeout) or sent a malformed frame."""


class WorkerBusyError(TransportError):
    """The worker already has a mutating session.  A listening worker
    serves ONE mutator (a router's SocketReplica) plus any number of
    read-only observers concurrently; a second ``attach(mode="mutate")``
    is rejected with this type — the wire carries it as
    ``etype: "WorkerBusyError"`` and the dialing stub re-raises it, so a
    router racing another router for a pod fails typed, not desynced."""


# --------------------------------------------------------------------- frames


def pack_frame(obj) -> bytes:
    payload = json.dumps(obj, allow_nan=True,
                         separators=(",", ":")).encode("utf-8")
    if len(payload) > MAX_FRAME:
        # the receiver would kill the connection over this frame anyway —
        # reject it at the sender, where the caller can still handle it
        raise TransportError(
            f"refusing to send oversized frame ({len(payload)} bytes "
            f"> MAX_FRAME {MAX_FRAME})")
    return _LEN.pack(len(payload)) + payload


def unpack_payload(payload: bytes):
    try:
        return json.loads(payload.decode("utf-8"))
    except (UnicodeDecodeError, ValueError) as e:
        # garbage on the wire is a peer we can no longer trust to frame
        # correctly — surface it as the same typed failure as EOF/reset
        raise TransportError(f"malformed frame payload: {e}") from e


def read_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly n bytes, looping over partial reads."""
    chunks = []
    got = 0
    while got < n:
        try:
            chunk = sock.recv(n - got)
        except (socket.timeout, TimeoutError) as e:
            raise TransportError(f"timed out waiting for peer: {e}") from e
        except OSError as e:
            raise TransportError(f"socket error: {e}") from e
        if not chunk:
            raise TransportError("peer closed the connection")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


class Connection:
    """One framed duplex channel over a connected socket."""

    def __init__(self, sock: socket.socket, *, timeout: float | None = None):
        self.sock = sock
        if timeout is not None:
            sock.settimeout(timeout)

    def send(self, obj):
        try:
            self.sock.sendall(pack_frame(obj))
        except OSError as e:
            raise TransportError(f"send failed: {e}") from e

    def recv(self):
        (n,) = _LEN.unpack(read_exact(self.sock, _LEN.size))
        if n > MAX_FRAME:
            raise TransportError(f"oversized frame ({n} bytes)")
        return unpack_payload(read_exact(self.sock, n))

    def close(self):
        try:
            self.sock.close()
        except OSError:
            pass


# ------------------------------------------------------------ TCP endpoints

DEFAULT_CONNECT_TIMEOUT_S = 10.0


def parse_addr(addr: str) -> tuple[str, int]:
    """"host:port" → (host, port).  Port 0 means "kernel picks"."""
    host, sep, port = addr.rpartition(":")
    if not sep or not port.isdigit():
        raise ValueError(f"expected host:port, got {addr!r}")
    return host or "127.0.0.1", int(port)


def _tune_tcp(sock: socket.socket):
    """Frames are small RPCs on a strict request/reply stream: Nagle's 40 ms
    coalescing stall would dominate a decode round, and a silently-vanished
    peer must eventually error out instead of wedging the fleet."""
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_KEEPALIVE, 1)


class Listener:
    """One bound TCP accept socket; ``accept()`` yields framed Connections.

    Binding to port 0 lets the kernel pick — ``self.port`` reports the
    realized port (workers print it so a parent/script can attach)."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 backlog: int = 16):
        self.sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self.sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.sock.bind((host, port))
        self.sock.listen(backlog)
        self.host, self.port = self.sock.getsockname()[:2]

    @property
    def addr(self) -> tuple[str, int]:
        return (self.host, self.port)

    def accept(self, timeout: float | None = None, *,
               conn_timeout: float | None = None) -> Connection:
        """Wait for one peer; raises TransportError on deadline/closure."""
        try:
            self.sock.settimeout(timeout)   # EBADF once close() ran — typed
            peer, _ = self.sock.accept()
        except (socket.timeout, TimeoutError) as e:
            raise TransportError(f"accept timed out: {e}") from e
        except OSError as e:
            raise TransportError(f"accept failed: {e}") from e
        _tune_tcp(peer)
        return Connection(peer, timeout=conn_timeout)

    def close(self):
        try:
            self.sock.close()
        except OSError:
            pass


def dial(host: str, port: int, *,
         connect_timeout: float = DEFAULT_CONNECT_TIMEOUT_S,
         timeout: float | None = None) -> Connection:
    """Connect to a listening worker; refused / unreachable / slow connects
    all surface as TransportError within the connect deadline."""
    try:
        sock = socket.create_connection((host, port), timeout=connect_timeout)
    except (socket.timeout, TimeoutError) as e:
        raise TransportError(
            f"connect to {host}:{port} timed out after "
            f"{connect_timeout}s") from e
    except OSError as e:
        raise TransportError(f"connect to {host}:{port} failed: {e}") from e
    _tune_tcp(sock)
    return Connection(sock, timeout=timeout)


# --------------------------------------------------------------------- codecs


def encode_request(req: Request) -> dict:
    return {
        "rid": req.rid,
        "prompt": np.asarray(req.prompt).astype(int).tolist(),
        "gen_len": int(req.gen_len),
        "tier": req.tier,
        "sampling": dataclasses.asdict(req.sampling),
        "t_submit": req.t_submit,
        "t_admit": req.t_admit,
        "t_first_token": req.t_first_token,
        "t_done": req.t_done,
        "replica_id": req.replica_id,
        "tokens_out": [int(t) for t in req.tokens_out],
        "frames": (None if req.frames is None
                   else np.asarray(req.frames, np.float32).tolist()),
    }


def decode_request(d: dict) -> Request:
    req = Request(rid=int(d["rid"]),
                  prompt=np.asarray(d["prompt"], np.int32),
                  gen_len=int(d["gen_len"]),
                  # .get: frames from pre-tier peers default interactive
                  tier=d.get("tier", "interactive"),
                  sampling=SamplingParams(**d["sampling"]),
                  frames=(None if d.get("frames") is None
                          else np.asarray(d["frames"], np.float32)))
    req.t_submit = d.get("t_submit")
    req.t_admit = d.get("t_admit")
    req.t_first_token = d.get("t_first_token")
    req.t_done = d.get("t_done")
    req.replica_id = d.get("replica_id")
    req.tokens_out = [int(t) for t in d.get("tokens_out", [])]
    return req


def encode_completion(req: Request) -> dict:
    """Slim completion record: everything the submitter's original object
    needs updated, and nothing it already has — echoing the prompt (and an
    enc-dec request's whole frames matrix) back over the wire per completion
    would be pure transport waste."""
    return {
        "rid": req.rid,
        "t_submit": req.t_submit,
        "t_admit": req.t_admit,
        "t_first_token": req.t_first_token,
        "t_done": req.t_done,
        "replica_id": req.replica_id,
        "tokens_out": [int(t) for t in req.tokens_out],
    }


def apply_request(dst: Request, d: dict) -> Request:
    """Merge a wire-side completion back into the submitter's original
    object — the caller's handle must reflect completion exactly as it does
    in-process (tokens, timestamps, owning replica)."""
    dst.t_submit = d.get("t_submit")
    dst.t_admit = d.get("t_admit")
    dst.t_first_token = d.get("t_first_token")
    dst.t_done = d.get("t_done")
    dst.replica_id = d.get("replica_id")
    dst.tokens_out = [int(t) for t in d.get("tokens_out", [])]
    return dst


def encode_report(rep: ReplicaReport) -> dict:
    return dataclasses.asdict(rep)


def decode_report(d: dict) -> ReplicaReport:
    fields = {f.name for f in dataclasses.fields(ReplicaReport)}
    return ReplicaReport(**{k: v for k, v in d.items() if k in fields})


_SUBCFGS = {"moe": MoECfg, "ssm": SSMCfg, "hybrid": HybridCfg}


def encode_config(cfg: ModelConfig) -> dict:
    return dataclasses.asdict(cfg)


def decode_config(d: dict) -> ModelConfig:
    d = dict(d)
    for name, klass in _SUBCFGS.items():
        if d.get(name) is not None:
            d[name] = klass(**d[name])
    if d.get("m_rope_sections") is not None:
        d["m_rope_sections"] = tuple(d["m_rope_sections"])
    return ModelConfig(**d)
