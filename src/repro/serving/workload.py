"""Synthetic serving workloads — shares the simulator's WorkloadSpec so the
control plane's queueing model (sim/serving.py) and the real data plane are
parameterized by the same request shape (prompt_len, gen_len).
"""
from __future__ import annotations

import numpy as np

from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Request
from repro.sim.serving import WorkloadSpec


def poisson_arrival_times(rps: float, n: int,
                          rng: np.random.Generator) -> np.ndarray:
    """n cumulative arrival times (seconds) at ``rps`` requests/second."""
    return np.cumsum(rng.exponential(1.0 / max(rps, 1e-9), n))


def synthetic_requests(spec: WorkloadSpec, n: int, vocab: int, *,
                       rng: np.random.Generator, base_rid: int = 0,
                       sampling: SamplingParams | None = None,
                       tier: str = "interactive") -> list[Request]:
    """n requests drawn from the spec's shape (uniform random token ids;
    ids < 3 reserved for specials, as in the seed driver).  When
    ``sampling`` is omitted, each request gets its OWN SamplingParams —
    never a shared default instance (the class-level-default trap this
    module's Request just shed)."""
    return [
        Request(rid=base_rid + i,
                prompt=rng.integers(3, vocab, size=spec.prompt_len
                                    ).astype(np.int32),
                gen_len=spec.gen_len, tier=tier,
                sampling=SamplingParams() if sampling is None else sampling)
        for i in range(n)
    ]


def tiered_requests(spec: WorkloadSpec, n: int, vocab: int, *,
                    batch_frac: float, rng: np.random.Generator,
                    base_rid: int = 0,
                    sampling: SamplingParams | None = None
                    ) -> list[Request]:
    """A mixed-tier stream: each request lands on the batch lane with
    probability ``batch_frac`` (drawn AFTER the prompts, so the prompt
    stream matches a same-seed synthetic_requests call token-for-token —
    only the tier labels differ)."""
    reqs = synthetic_requests(spec, n, vocab, rng=rng, base_rid=base_rid,
                              sampling=sampling)
    if batch_frac > 0.0:
        is_batch = rng.random(n) < batch_frac
        for r, b in zip(reqs, is_batch):
            if b:
                r.tier = "batch"
    return reqs


def repetitive_requests(spec: WorkloadSpec, n: int, vocab: int, *,
                        period: int, rng: np.random.Generator,
                        base_rid: int = 0,
                        sampling: SamplingParams | None = None
                        ) -> list[Request]:
    """n requests whose prompt cycles one random ``period``-token phrase
    (prompt-echo shape: extraction, templated boilerplate, code with
    repeated idioms).  The suffix n-gram of such a prompt recurs earlier in
    the history, so a prompt-lookup draft (serving/draft.py) keeps finding
    continuations — the workload speculative decoding is built for, and the
    one the tokens/s ablation measures acceptance on."""
    assert 1 <= period <= spec.prompt_len, (period, spec.prompt_len)
    out = []
    for i in range(n):
        phrase = rng.integers(3, vocab, size=period).astype(np.int32)
        reps = -(-spec.prompt_len // period)
        out.append(Request(
            rid=base_rid + i,
            prompt=np.tile(phrase, reps)[:spec.prompt_len],
            gen_len=spec.gen_len,
            sampling=SamplingParams() if sampling is None else sampling))
    return out


def shared_prefix_requests(spec: WorkloadSpec, n: int, vocab: int, *,
                           prefix_len: int, rng: np.random.Generator,
                           base_rid: int = 0,
                           sampling: SamplingParams | None = None
                           ) -> list[Request]:
    """n requests sharing one ``prefix_len``-token system prompt; the rest
    of each prompt is private.  The shape a paged pool's prefix cache is
    built for — the first admission prefills the prefix, later ones map its
    blocks read-only."""
    assert 0 <= prefix_len <= spec.prompt_len, (prefix_len, spec.prompt_len)
    prefix = rng.integers(3, vocab, size=prefix_len).astype(np.int32)
    return [
        Request(rid=base_rid + i,
                prompt=np.concatenate(
                    [prefix,
                     rng.integers(3, vocab, size=spec.prompt_len - prefix_len
                                  ).astype(np.int32)]),
                gen_len=spec.gen_len,
                sampling=SamplingParams() if sampling is None else sampling)
        for i in range(n)
    ]
