"""Request lifecycle + FCFS admission with priority lanes.

A Request moves QUEUED → PREFILL → DECODE → DONE.  The scheduler itself is
deliberately simple — first-come-first-served with slot-count admission
control — because the interesting scheduling (how many replicas exist at all)
belongs to the control plane driving the router.  Timestamps are caller-
supplied ("now" flows in from the driver), so tests run on a virtual clock
and production drivers pass wall time.

Traffic is non-uniform: every request carries a ``tier`` — "interactive"
(latency SLO) or "batch" (throughput, tolerant of queueing and preemption).
The scheduler keeps one FCFS deque PER LANE and admits strictly by lane
priority: the interactive lane drains first, and within a lane order is
exactly first-come-first-served — so a single-tier workload behaves
bit-identically to the old single-queue scheduler.  The control plane can
additionally GATE the batch lane (``batch_gated``) when the interactive
lane's SLO is at risk: gated batch requests stay queued (they still count
toward depth/load) but are invisible to pop/peek until the gate lifts.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.serving.sampling import SamplingParams, sample_token

# lane priority order: earlier tiers admit first
TIERS = ("interactive", "batch")


def validate_tier(tier: str) -> str:
    """Both the engine and a remote stub's parent side run this — a typo'd
    tier must bounce at submit, on the submitter's side of the wire."""
    if tier not in TIERS:
        raise ValueError(f"unknown tier {tier!r} (expected one of {TIERS})")
    return tier


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    gen_len: int
    # admission lane (TIERS): interactive requests admit ahead of batch
    # ones and are never routed onto preemptible capacity
    tier: str = "interactive"
    # origin region ("" = untagged): on a region-tagged fleet the router
    # prefers in-region capacity for interactive requests; untagged
    # requests (and region-less fleets) route on the legacy key
    region: str = ""
    # default_factory, NOT a shared class-level instance: safe today only
    # because SamplingParams is frozen, but a future mutable field would
    # silently couple every request in the fleet through one object
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    # enc-dec families: precomputed encoder frames, (S_enc, d_model) float.
    frames: Optional[np.ndarray] = None
    t_submit: Optional[float] = None
    t_admit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    replica_id: Optional[int] = None
    tokens_out: list = dataclasses.field(default_factory=list)
    _rng: Optional[np.random.Generator] = dataclasses.field(
        default=None, repr=False, compare=False)

    def sample(self, logits: np.ndarray) -> int:
        """Sample (and record) the next output token; RNG is seeded from
        (sampling.seed, rid) so replays are per-request deterministic."""
        if self._rng is None:
            self._rng = np.random.default_rng((self.sampling.seed, self.rid))
        tok = sample_token(logits, self.sampling, self._rng,
                           position=len(self.tokens_out))
        self.tokens_out.append(tok)
        return tok

    def reset_generation(self):
        """Rewind to the not-yet-admitted state (preemption / replica loss).
        t_submit survives — the requeue penalty is real user-visible latency
        and must stay in the accounting; everything generated on the lost
        replica is discarded so the replay is bit-identical to a fresh run
        (the sampling RNG reseeds from (seed, rid) on first use)."""
        self.tokens_out = []
        self._rng = None
        self.t_admit = None
        self.t_first_token = None
        self.t_done = None
        self.replica_id = None

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None or self.t_submit is None:
            return None
        return self.t_done - self.t_submit

    @property
    def ttft_s(self) -> Optional[float]:
        if self.t_first_token is None or self.t_submit is None:
            return None
        return self.t_first_token - self.t_submit


class FCFSScheduler:
    """Priority-laned FCFS admission queue for one engine: one deque per
    tier, drained in TIERS order (interactive before batch), first-come-
    first-served WITHIN a lane.  ``pop``/``peek`` always agree on the same
    head — the paged pool's head-of-line capacity gate peeks, then pops."""

    def __init__(self):
        self._lanes: dict[str, deque[Request]] = {t: deque() for t in TIERS}
        self.n_submitted = 0
        # control-plane gate: while set, the batch lane is invisible to
        # admission (pop/peek/__bool__) but its requests stay queued and
        # still count toward depth — interactive SLO protection, not drop
        self.batch_gated = False

    def submit(self, request: Request):
        self._lanes[validate_tier(request.tier)].append(request)
        self.n_submitted += 1

    def _head_lane(self) -> deque[Request] | None:
        for t in TIERS:
            if t == "batch" and self.batch_gated:
                continue
            if self._lanes[t]:
                return self._lanes[t]
        return None

    def pop(self) -> Request:
        lane = self._head_lane()
        if lane is None:
            raise IndexError("pop from an empty (or fully gated) scheduler")
        return lane.popleft()

    def peek(self) -> Request:
        """Head of the queue without removing it — admission gates that may
        refuse the head (paged pool out of blocks) must not reorder FCFS."""
        lane = self._head_lane()
        if lane is None:
            raise IndexError("peek at an empty (or fully gated) scheduler")
        return lane[0]

    def drain(self) -> list[Request]:
        """Remove and return every queued (not yet admitted) request — used
        when a draining replica hands its backlog to the survivors.  Gated
        batch requests leave too: an evacuation empties the replica."""
        out: list[Request] = []
        for t in TIERS:
            out.extend(self._lanes[t])
            self._lanes[t].clear()
        return out

    def lane_depth(self, tier: str) -> int:
        return len(self._lanes[tier])

    @property
    def depth(self) -> int:
        return sum(len(q) for q in self._lanes.values())

    def __bool__(self) -> bool:
        """Admissible work exists (a gated batch backlog reads False — the
        engine's admission loop must not spin on requests it cannot pop)."""
        return self._head_lane() is not None
