"""Request lifecycle + FCFS admission.

A Request moves QUEUED → PREFILL → DECODE → DONE.  The scheduler itself is
deliberately simple — first-come-first-served with slot-count admission
control — because the interesting scheduling (how many replicas exist at all)
belongs to the control plane driving the router.  Timestamps are caller-
supplied ("now" flows in from the driver), so tests run on a virtual clock
and production drivers pass wall time.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.serving.sampling import SamplingParams, sample_token


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    gen_len: int
    # default_factory, NOT a shared class-level instance: safe today only
    # because SamplingParams is frozen, but a future mutable field would
    # silently couple every request in the fleet through one object
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    # enc-dec families: precomputed encoder frames, (S_enc, d_model) float.
    frames: Optional[np.ndarray] = None
    t_submit: Optional[float] = None
    t_admit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    replica_id: Optional[int] = None
    tokens_out: list = dataclasses.field(default_factory=list)
    _rng: Optional[np.random.Generator] = dataclasses.field(
        default=None, repr=False, compare=False)

    def sample(self, logits: np.ndarray) -> int:
        """Sample (and record) the next output token; RNG is seeded from
        (sampling.seed, rid) so replays are per-request deterministic."""
        if self._rng is None:
            self._rng = np.random.default_rng((self.sampling.seed, self.rid))
        tok = sample_token(logits, self.sampling, self._rng,
                           position=len(self.tokens_out))
        self.tokens_out.append(tok)
        return tok

    def reset_generation(self):
        """Rewind to the not-yet-admitted state (preemption / replica loss).
        t_submit survives — the requeue penalty is real user-visible latency
        and must stay in the accounting; everything generated on the lost
        replica is discarded so the replay is bit-identical to a fresh run
        (the sampling RNG reseeds from (seed, rid) on first use)."""
        self.tokens_out = []
        self._rng = None
        self.t_admit = None
        self.t_first_token = None
        self.t_done = None
        self.replica_id = None

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None or self.t_submit is None:
            return None
        return self.t_done - self.t_submit

    @property
    def ttft_s(self) -> Optional[float]:
        if self.t_first_token is None or self.t_submit is None:
            return None
        return self.t_first_token - self.t_submit


class FCFSScheduler:
    """First-come-first-served admission queue for one engine."""

    def __init__(self):
        self._queue: deque[Request] = deque()
        self.n_submitted = 0

    def submit(self, request: Request):
        self._queue.append(request)
        self.n_submitted += 1

    def pop(self) -> Request:
        return self._queue.popleft()

    def peek(self) -> Request:
        """Head of the queue without removing it — admission gates that may
        refuse the head (paged pool out of blocks) must not reorder FCFS."""
        return self._queue[0]

    def drain(self) -> list[Request]:
        """Remove and return every queued (not yet admitted) request — used
        when a draining replica hands its backlog to the survivors."""
        out = list(self._queue)
        self._queue.clear()
        return out

    @property
    def depth(self) -> int:
        return len(self._queue)

    def __bool__(self) -> bool:
        return bool(self._queue)
