"""Replica worker: a ServingEngine driven over the socket transport.

``python -m repro.serving.worker <fd>`` serves one engine on an inherited
socketpair fd (ProcessReplica spawns it with ``pass_fds``).  The loop is a
strict request/reply RPC: every message is answered exactly once, in order,
so the parent can measure transport latency per call and a missing reply
always means the worker is gone (never "still thinking about an older
message").

Ops mirror the Replica protocol 1:1 (see serving/replica.py):

  init      — build the engine from an encoded ModelConfig (the handshake)
  submit    — enqueue one request (validation errors bounce back typed)
  step      — one scheduling round; replies completed requests + queue state
  report    — drain the metric window for one ReplicaReport
  lifetime  — lifetime accumulators for fleet-level metrics
  evacuate  — preempt + return every queued/in-flight request (downscale)
  resume    — clear the draining flag (warm revive)
  shutdown  — clean exit

Engine exceptions are caught per-message and replied as
``{"error": ..., "etype": ...}`` — a bad request must not kill the worker
that other requests are mid-generation on.
"""
from __future__ import annotations

import socket
import sys
import traceback

from repro.serving.transport import (
    Connection,
    TransportError,
    decode_config,
    decode_request,
    encode_completion,
)


def handle(engine, msg: dict):
    """One op → reply dict (engine may be None before init)."""
    op = msg["op"]
    if op == "ping":
        return {"ok": True}
    if op == "init":
        from repro.serving.engine import ServingEngine
        cfg = decode_config(msg["cfg"])
        engine = ServingEngine(cfg, slots=int(msg["slots"]),
                               max_seq=int(msg["max_seq"]),
                               seed=int(msg.get("seed", 0)),
                               prefill_chunk=msg.get("prefill_chunk"),
                               replica_id=int(msg.get("replica_id", 0)))
        return {"ok": True, "engine": engine}
    if engine is None:
        raise RuntimeError(f"op {op!r} before init")
    if op == "submit":
        engine.submit(decode_request(msg["request"]), now=msg.get("now", 0.0))
        return {"ok": True}
    if op == "step":
        completed = engine.step(now=msg.get("now"))
        return {"completed": [encode_completion(r) for r in completed],
                "queue_depth": engine.scheduler.depth,
                "active": int(engine.active.sum()),
                # one float so the parent's lifetime mirror (crash-proof
                # fleet accounting) tracks occupancy too, not just counts
                "slot_utilization": float(engine.stats.slot_utilization)}
    if op == "report":
        return {"window": engine.stats.drain_window()}
    if op == "lifetime":
        return {"lifetime": engine.lifetime()}
    if op == "evacuate":
        # rids only: the parent rewinds its own originals — the rewound
        # request state is derivable, so shipping it back would be waste
        engine.draining = True
        return {"rids": [r.rid for r in engine.evacuate()]}
    if op == "resume":
        engine.draining = False
        return {"ok": True}
    raise RuntimeError(f"unknown op {op!r}")


def serve(conn: Connection) -> int:
    engine = None
    while True:
        try:
            msg = conn.recv()
        except TransportError:
            return 0                      # parent went away: clean exit
        if msg.get("op") == "shutdown":
            conn.send({"ok": True})
            return 0
        try:
            reply = handle(engine, msg)
            engine = reply.pop("engine", engine)
        except Exception as e:            # typed bounce, worker stays up
            reply = {"error": f"{e}",
                     "etype": type(e).__name__,
                     "trace": traceback.format_exc(limit=8)}
        conn.send(reply)


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    fd = int(argv[0])
    sock = socket.socket(fileno=fd)
    return serve(Connection(sock))


if __name__ == "__main__":
    sys.exit(main())
