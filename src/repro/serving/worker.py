"""Replica worker: a ServingEngine driven over the socket transport.

Two ways to become a worker:

  ``python -m repro.serving.worker <fd>``
      serve one engine on an inherited socketpair fd (ProcessReplica
      spawns it with ``pass_fds`` — single-host).
  ``python -m repro.serving.worker --listen host:port``
      bind a TCP listener (port 0 → kernel-picked) and print
      ``WORKER_LISTENING host:port`` so a parent or script can attach.
      The worker is a pod: a router DIALS it (TcpReplica), and when that
      router goes away the worker returns to accept for the next one —
      unless started ``--once``, which ties its lifetime to the first
      connection (stub-owned local workers).

The loop is a strict request/reply RPC: every message is answered exactly
once, in order, and the reply echoes the request's ``seq`` — so the parent
can measure transport latency per call, a missing reply always means the
worker is gone (never "still thinking about an older message"), and a
duplicated or dropped frame surfaces parent-side as a seq desync.

Ops mirror the Replica protocol 1:1 (see serving/replica.py):

  init      — build the engine from an encoded ModelConfig (the handshake)
  submit    — enqueue one request (validation errors bounce back typed)
  step      — one scheduling round; batched submits (``"submits": [...]``)
              are enqueued first, so one message per round replaces one per
              request; replies completed requests + queue state
  report    — drain the metric window for one ReplicaReport
  lifetime  — lifetime accumulators for fleet-level metrics
  evacuate  — preempt + return every queued/in-flight request (downscale)
  resume    — clear the draining flag (warm revive)
  shutdown  — clean exit (also ends a --listen worker's accept loop)

Engine exceptions are caught per-message and replied as
``{"error": ..., "etype": ...}`` — a bad request must not kill the worker
that other requests are mid-generation on.  A rejected *batched* submit is
replied per-request (``"submit_errors"``) so one bad request cannot take
the round's good submits down with it.
"""
from __future__ import annotations

import argparse
import socket
import sys
import traceback

from repro.serving.transport import (
    Connection,
    Listener,
    TransportError,
    decode_config,
    decode_request,
    encode_completion,
    parse_addr,
)


def handle(engine, msg: dict):
    """One op → reply dict (engine may be None before init)."""
    op = msg["op"]
    if op == "ping":
        return {"ok": True}
    if op == "init":
        from repro.serving.engine import ServingEngine
        cfg = decode_config(msg["cfg"])
        engine = ServingEngine(cfg, slots=int(msg["slots"]),
                               max_seq=int(msg["max_seq"]),
                               seed=int(msg.get("seed", 0)),
                               prefill_chunk=msg.get("prefill_chunk"),
                               replica_id=int(msg.get("replica_id", 0)))
        return {"ok": True, "engine": engine}
    if engine is None:
        raise RuntimeError(f"op {op!r} before init")
    if op == "submit":
        engine.submit(decode_request(msg["request"]), now=msg.get("now", 0.0))
        return {"ok": True}
    if op == "step":
        submit_errors = []
        for d in msg.get("submits", ()):
            # enqueue BEFORE the round runs — identical ordering to the
            # unbatched flow, where each submit RPC preceded the step
            try:
                engine.submit(decode_request(d["request"]),
                              now=d.get("now", 0.0))
            except Exception as e:     # bounce per-request, run the round
                submit_errors.append({"rid": d["request"].get("rid"),
                                      "error": str(e),
                                      "etype": type(e).__name__})
        completed = engine.step(now=msg.get("now"))
        reply = {"completed": [encode_completion(r) for r in completed],
                 "queue_depth": engine.scheduler.depth,
                 "active": int(engine.active.sum()),
                 # one float so the parent's lifetime mirror (crash-proof
                 # fleet accounting) tracks occupancy too, not just counts
                 "slot_utilization": float(engine.stats.slot_utilization)}
        if submit_errors:
            reply["submit_errors"] = submit_errors
        return reply
    if op == "report":
        return {"window": engine.stats.drain_window()}
    if op == "lifetime":
        return {"lifetime": engine.lifetime()}
    if op == "evacuate":
        # rids only: the parent rewinds its own originals — the rewound
        # request state is derivable, so shipping it back would be waste
        engine.draining = True
        return {"rids": [r.rid for r in engine.evacuate()]}
    if op == "resume":
        engine.draining = False
        return {"ok": True}
    raise RuntimeError(f"unknown op {op!r}")


def serve(conn: Connection, engine=None) -> str:
    """Drive one connection to completion; → "eof" (peer went away — a
    --listen worker returns to accept) or "shutdown" (exit the process)."""
    while True:
        try:
            msg = conn.recv()
        except TransportError:
            return "eof"
        if msg.get("op") == "shutdown":
            try:
                conn.send({"ok": True, "seq": msg.get("seq")})
            except TransportError:
                pass
            return "shutdown"
        try:
            reply = handle(engine, msg)
            engine = reply.pop("engine", engine)
        except Exception as e:            # typed bounce, worker stays up
            reply = {"error": f"{e}",
                     "etype": type(e).__name__,
                     "trace": traceback.format_exc(limit=8)}
        reply["seq"] = msg.get("seq")     # the desync-detection echo
        try:
            conn.send(reply)
        except TransportError:
            # the peer detached mid-round (router torn down with a step in
            # flight): same as EOF on recv — a --listen pod must go back to
            # accept, not die with the reply in hand
            return "eof"


def serve_listener(listener: Listener, *, once: bool = False) -> int:
    """Accept loop for a pod-like worker: one connection at a time; EOF
    sends us back to accept (the next router re-inits its own engine),
    shutdown — or ``once`` — ends the process."""
    try:
        while True:
            conn = listener.accept()
            reason = serve(conn)
            conn.close()
            if reason == "shutdown" or once:
                return 0
    finally:
        listener.close()


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    ap = argparse.ArgumentParser(prog="repro.serving.worker")
    ap.add_argument("fd", nargs="?", type=int,
                    help="inherited socketpair fd (ProcessReplica mode)")
    ap.add_argument("--listen", metavar="HOST:PORT",
                    help="bind a TCP listener instead (port 0 = kernel-"
                         "picked); prints WORKER_LISTENING host:port")
    ap.add_argument("--once", action="store_true",
                    help="exit after the first connection ends")
    args = ap.parse_args(argv)
    if args.listen:
        host, port = parse_addr(args.listen)
        listener = Listener(host, port)
        print(f"WORKER_LISTENING {listener.host}:{listener.port}",
              flush=True)
        return serve_listener(listener, once=args.once)
    if args.fd is None:
        ap.error("need an inherited fd or --listen host:port")
    sock = socket.socket(fileno=args.fd)
    serve(Connection(sock))
    return 0


if __name__ == "__main__":
    sys.exit(main())
