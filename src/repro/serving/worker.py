"""Replica worker: a ServingEngine driven over the socket transport.

Three ways to become a worker:

  ``python -m repro.serving.worker <fd>``
      serve one engine on an inherited socketpair fd (ProcessReplica
      spawns it with ``pass_fds`` — single-host).
  ``python -m repro.serving.worker --listen host:port``
      bind a TCP listener (port 0 → kernel-picked) and print
      ``WORKER_LISTENING host:port`` so a parent or script can attach.
      The worker is a pod: a router DIALS it (TcpReplica), and when that
      router goes away the worker returns to accept for the next one —
      unless started ``--once``, which ties its lifetime to the first
      mutating session (stub-owned local workers).
  ``python -m repro.serving.worker --listen host:port --pod-rank R
      --pod-size N [--coordinator host:port] [--pod-peers a:p,b:q]``
      one rank of a MULTI-PROCESS POD: N listening workers jointly back
      one router-visible replica.  Rank 0 is the RPC head — the only rank
      a router dials; it holds a mutating session on every non-head rank
      and forwards each mutating op before running it locally, so all
      ranks step in lockstep.  Ranks join a jax.distributed cluster when
      ``--coordinator`` is given (process count and rank are plumbed from
      these flags, never discovered ambiently).  See "Pod execution"
      below for how the tick is laid out.

Concurrent sessions (``--listen`` mode): the accept loop multiplexes ONE
mutating session (a router's SocketReplica, or the pod head for a non-head
rank) with ANY number of read-only observer sessions over ``select``.  A
connection's first message decides its role: ``attach {mode}`` claims it
explicitly (a second ``mutate`` attach is rejected with a typed
``WorkerBusyError`` reply and closed — the racing router fails typed, not
desynced), and any other first op falls back to an implicit mutate claim
(pre-attach clients keep working).  Observers may send only the read-only
ops (ping / lifetime / status — none of which drain the mutator's metric
window); anything else is bounced per-message with a typed
``PermissionError`` reply.  An observer torn down mid-frame is simply
dropped — the mutating session never notices.

The RPC stream per session is strict request/reply: every message is
answered exactly once, in order, and the reply echoes the request's
``seq`` — so the parent can measure transport latency per call, a missing
reply always means the worker is gone (never "still thinking about an
older message"), and a duplicated or dropped frame surfaces parent-side
as a seq desync.

Ops mirror the Replica protocol 1:1 (see serving/replica.py):

  attach    — session handshake: {"mode": "mutate" | "observe"}
  init      — build the engine from an encoded ModelConfig (the handshake)
  submit    — enqueue one request (validation errors bounce back typed)
  step      — one scheduling round; batched submits (``"submits": [...]``)
              are enqueued first, so one message per round replaces one per
              request; replies completed requests + queue state
  report    — drain the metric window for one ReplicaReport
  lifetime  — lifetime accumulators for fleet-level metrics
  status    — NON-DRAINING snapshot (observer-safe): lifetime counters,
              queue depth, active slots, pod rank/mode when applicable
  evacuate  — preempt + return every queued/in-flight request (downscale)
  resume    — clear the draining flag (warm revive)
  shutdown  — clean exit (a pod head forwards it, so one shutdown retires
              every rank)

Pod execution: each rank builds the SAME engine (same config, same seed →
identical params and per-request sampling streams) and runs the decode
tick under ``shard_map`` on a mesh built for its role.  When the backend
can place one program across processes (``launch.mesh.spmd_across_
processes`` — every rank reaches the same verdict), the global
``make_pod_mesh`` whose "model" axis spans the ranks is available to the
tick; until the host loop learns to gather cross-process logits (ROADMAP),
every rank conservatively runs the full slot set on its LOCAL mesh in
lockstep — mirror mode.  Lockstep is verified, not assumed: non-head
ranks answer each step with a DIGEST of (completed rids+tokens, queue
state) instead of echoing completions, and the head compares digests
every round — a diverging rank (heterogeneous hardware, bitrot) surfaces
as a typed ``PodDesyncError`` reply and the pod retires, it does not
silently serve two histories.  A lost rank is fatal the same way: the
head drops its router connection so the parent reaps the pod cleanly.

Engine exceptions are caught per-message and replied as
``{"error": ..., "etype": ...}`` — a bad request must not kill the worker
that other requests are mid-generation on.  A rejected *batched* submit is
replied per-request (``"submit_errors"``) so one bad request cannot take
the round's good submits down with it.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import select
import socket
import sys
import traceback

from repro.serving.transport import (
    Connection,
    Listener,
    TransportError,
    decode_config,
    decode_request,
    dial,
    encode_completion,
    parse_addr,
)

# ops an observer session may issue — all read-only, none drain the
# mutator's metric window (report DOES drain: it stays mutator-only)
OBSERVER_OPS = frozenset({"ping", "lifetime", "status"})

# ops the pod head forwards to every non-head rank before running them
# locally (report rides along so follower windows drain instead of
# accumulating forever); shutdown is forwarded separately on exit
POD_LOCKSTEP_OPS = frozenset(
    {"init", "submit", "step", "evacuate", "resume", "report"})

# session RECEIVES never block (per-session buffers — a peer stalled
# mid-frame just parks its partial frame); this deadline bounds the SEND
# side: a peer that stops reading long enough to fill its receive window
# plus our send buffer is dropped instead of freezing the accept loop
SESSION_IO_TIMEOUT_S = 30.0

# the head's deadline per lockstep op on the rank fabric: generous enough
# for a rank's first step to jit-compile, finite so a wedged-but-alive
# rank (stuck device call; keepalive never fires) surfaces as a typed
# rank loss and the pod retires instead of hanging forever
POD_RANK_TIMEOUT_S = 600.0


class PodDesyncError(RuntimeError):
    """Two pod ranks produced different step results.  The ranks' engine
    states have already diverged, so the pod cannot serve another round —
    the head replies this typed error and retires."""


def _percentile(sorted_vals: list, q: float) -> float:
    """Nearest-rank percentile over an already-sorted list (stdlib-only —
    the worker avoids importing numpy for one summary)."""
    if not sorted_vals:
        return 0.0
    idx = min(int(q * len(sorted_vals)), len(sorted_vals) - 1)
    return float(sorted_vals[idx])


def step_digest(reply: dict) -> str:
    """Order-independent fingerprint of one step's observable outcome —
    what lockstep ranks must agree on (completions and queue state; NOT
    timestamps, which are host-local)."""
    basis = sorted((int(d["rid"]), tuple(int(t) for t in d["tokens_out"]))
                   for d in reply.get("completed", ()))
    blob = json.dumps([basis, int(reply["queue_depth"]),
                       int(reply["active"])]).encode()
    return hashlib.sha1(blob).hexdigest()[:16]


class PodRuntime:
    """One rank's pod context: identity (rank/size/coordinator) plus — on
    the head — the mutating sessions it holds on every non-head rank."""

    def __init__(self, rank: int, size: int, coordinator: str | None,
                 peers: tuple[str, ...] = ()):
        self.rank = int(rank)
        self.size = int(size)
        self.coordinator = coordinator
        self.peer_addrs = tuple(peers)
        self.followers: list[Connection] = []
        self._seqs: list[int] = []
        self.mode: str | None = None       # "mirror" once the engine is up
        self.spmd_capable: bool | None = None

    @property
    def is_head(self) -> bool:
        return self.rank == 0

    # ----------------------------------------------------------- head side

    def connect_followers(self, *, connect_timeout_s: float = 60.0):
        """Dial every non-head rank and claim its mutating session.  The
        connections are owned by the head PROCESS, not by any one router
        session — a router detaching and re-attaching re-inits the
        engines over the same rank fabric."""
        for addr in self.peer_addrs:
            conn = dial(*parse_addr(addr), connect_timeout=connect_timeout_s,
                        timeout=POD_RANK_TIMEOUT_S)
            self.followers.append(conn)
            self._seqs.append(0)
            [reply] = self._collect([self._send(len(self.followers) - 1,
                                                {"op": "attach",
                                                 "mode": "mutate"})],
                                    conns=[conn])
            if "error" in reply:
                raise TransportError(
                    f"pod rank {len(self.followers)} refused the head's "
                    f"mutate attach: {reply['error']}")

    def _send(self, i: int, msg: dict) -> int:
        msg = dict(msg)
        seq, self._seqs[i] = self._seqs[i], self._seqs[i] + 1
        msg["seq"] = seq
        self.followers[i].send(msg)
        return seq

    def _collect(self, seqs: list[int], conns=None) -> list[dict]:
        replies = []
        for conn, seq in zip(conns or self.followers, seqs):
            reply = conn.recv()
            if reply.get("seq") != seq:
                raise TransportError(
                    f"pod lockstep desync on the rank fabric: expected "
                    f"reply seq {seq}, got {reply.get('seq')!r}")
            replies.append(reply)
        return replies

    def forward(self, msg: dict) -> list[int]:
        """Put one lockstep op on every rank's wire (send-only — the head
        runs its local copy while the ranks compute)."""
        return [self._send(i, msg) for i in range(len(self.followers))]

    def collect(self, seqs: list[int]) -> list[dict]:
        return self._collect(seqs)

    def close(self):
        for conn in self.followers:
            conn.close()
        self.followers.clear()

    # ---------------------------------------------------------- both sides

    def build_engine(self, msg: dict):
        """The pod tick: one engine per rank, decode under shard_map on
        the mesh this rank's role dictates.  Every rank must pass through
        here exactly once per init — the distributed handshake and the
        spmd probe are collective-ish (all ranks reach them because the
        head forwards init before running its own)."""
        from repro.launch.mesh import (
            init_distributed, local_pod_mesh, spmd_across_processes,
        )
        from repro.serving.engine import ServingEngine
        from repro.serving.replica import make_sharded_decode

        if self.size > 1 and self.coordinator:
            init_distributed(self.coordinator, self.size, self.rank)
            self.spmd_capable = spmd_across_processes()
        else:
            self.spmd_capable = False
        # mirror mode: the full slot set on this rank's local devices, in
        # lockstep with every other rank.  Flipping to make_pod_mesh()
        # (the "model" axis spanning ranks) is gated on spmd_capable AND
        # the host loop gathering cross-process logits — see ROADMAP.
        self.mode = "mirror"
        mesh = local_pod_mesh()
        cfg = decode_config(msg["cfg"])
        slots, max_seq = int(msg["slots"]), int(msg["max_seq"])
        pool = msg.get("pool") or "dense"
        block_size, num_blocks = msg.get("block_size"), msg.get("num_blocks")
        engine = ServingEngine(cfg, slots=slots, max_seq=max_seq,
                               seed=int(msg.get("seed", 0)),
                               prefill_chunk=msg.get("prefill_chunk"),
                               replica_id=int(msg.get("replica_id", 0)),
                               pool=pool, block_size=block_size,
                               num_blocks=num_blocks,
                               partitions=int(mesh.devices.size),
                               spec_k=int(msg.get("spec_k", 0) or 0),
                               spec_ngram=int(msg.get("spec_ngram", 3) or 3))
        engine.decode = make_sharded_decode(cfg, mesh, slots, max_seq,
                                            pool=pool, block_size=block_size,
                                            num_blocks=num_blocks)
        return engine

    def info(self) -> dict:
        out = {"rank": self.rank, "size": self.size, "mode": self.mode,
               "spmd_capable": self.spmd_capable}
        if self.mode is not None:
            import jax
            out["process_count"] = int(jax.process_count())
            out["device_count"] = int(jax.device_count())
        return out


def handle(engine, msg: dict, pod: PodRuntime | None = None):
    """One op → reply dict (engine may be None before init)."""
    op = msg["op"]
    if op == "ping":
        return {"ok": True}
    if op == "attach":
        # fd-mode / pod-fabric reachable only: the --listen accept loop
        # arbitrates attaches itself.  A lone socketpair peer is the
        # mutator by construction, so the claim is always granted.
        return {"ok": True, "role": msg.get("mode", "mutate")}
    if op == "init":
        if pod is not None:
            return {"ok": True, "engine": pod.build_engine(msg)}
        from repro.serving.engine import ServingEngine
        cfg = decode_config(msg["cfg"])
        engine = ServingEngine(cfg, slots=int(msg["slots"]),
                               max_seq=int(msg["max_seq"]),
                               seed=int(msg.get("seed", 0)),
                               prefill_chunk=msg.get("prefill_chunk"),
                               replica_id=int(msg.get("replica_id", 0)),
                               pool=msg.get("pool") or "dense",
                               block_size=msg.get("block_size"),
                               num_blocks=msg.get("num_blocks"),
                               spec_k=int(msg.get("spec_k", 0) or 0),
                               spec_ngram=int(msg.get("spec_ngram", 3) or 3))
        return {"ok": True, "engine": engine}
    if op == "status":
        # observer-safe: reads accumulators, drains nothing.  The lifetime
        # latency SAMPLES are summarized to percentiles — a per-tick poll
        # must not ship the whole 4096-float history every round (the
        # authoritative samples stay available via the lifetime op)
        out = {"initialized": engine is not None}
        if engine is not None:
            lt = engine.lifetime()
            lats = sorted(lt.pop("latencies_ms"))
            lt["n_latencies"] = len(lats)
            lt["latency_p50_ms"] = _percentile(lats, 0.50)
            lt["latency_p95_ms"] = _percentile(lats, 0.95)
            out.update(queue_depth=engine.scheduler.depth,
                       active=int(engine.active.sum()),
                       draining=bool(engine.draining),
                       lifetime=lt)
        if pod is not None:
            out["pod"] = pod.info()
        return out
    if engine is None:
        raise RuntimeError(f"op {op!r} before init")
    if op == "submit":
        engine.submit(decode_request(msg["request"]), now=msg.get("now", 0.0))
        return {"ok": True}
    if op == "step":
        if "batch_gate" in msg:
            # gate changes ride the step message (like batched submits) and
            # apply BEFORE this round's submits/admission
            engine.scheduler.batch_gated = bool(msg["batch_gate"])
        submit_errors = []
        for d in msg.get("submits", ()):
            # enqueue BEFORE the round runs — identical ordering to the
            # unbatched flow, where each submit RPC preceded the step
            try:
                engine.submit(decode_request(d["request"]),
                              now=d.get("now", 0.0))
            except Exception as e:     # bounce per-request, run the round
                submit_errors.append({"rid": d["request"].get("rid"),
                                      "error": str(e),
                                      "etype": type(e).__name__})
        completed = engine.step(now=msg.get("now"))
        reply = {"completed": [encode_completion(r) for r in completed],
                 "queue_depth": engine.scheduler.depth,
                 "active": int(engine.active.sum()),
                 # one float so the parent's lifetime mirror (crash-proof
                 # fleet accounting) tracks occupancy too, not just counts
                 "slot_utilization": float(engine.stats.slot_utilization)}
        if submit_errors:
            reply["submit_errors"] = submit_errors
        if pod is not None and not pod.is_head:
            # lockstep verification beats N identical completion copies:
            # the head's stream is authoritative, the rank proves parity
            return {"digest": step_digest(reply),
                    "queue_depth": reply["queue_depth"],
                    "active": reply["active"]}
        return reply
    if op == "report":
        return {"window": engine.stats.drain_window()}
    if op == "lifetime":
        return {"lifetime": engine.lifetime()}
    if op == "evacuate":
        # rids only: the parent rewinds its own originals — the rewound
        # request state is derivable, so shipping it back would be waste
        engine.draining = True
        return {"rids": [r.rid for r in engine.evacuate()]}
    if op == "resume":
        engine.draining = False
        return {"ok": True}
    raise RuntimeError(f"unknown op {op!r}")


def dispatch(engine, msg: dict, pod: PodRuntime | None):
    """handle() plus pod lockstep: the head forwards a mutating op to every
    rank BEFORE running it locally (the ranks' compute overlaps the
    head's), then reconciles — step digests must match rank-for-rank, and
    a local exception is re-raised only after the rank replies are drained
    (the ranks failed the same deterministic way; leaving their replies
    unread would desync the fabric for the NEXT op)."""
    op = msg.get("op")
    if pod is None or not pod.is_head or op not in POD_LOCKSTEP_OPS \
            or not pod.followers:
        return handle(engine, msg, pod=pod)
    seqs = pod.forward(msg)
    err = None
    reply = None
    try:
        reply = handle(engine, msg, pod=pod)
    except Exception as e:
        err = e
    echoes = pod.collect(seqs)             # TransportError here is fatal
    if err is not None:
        raise err
    failed = [e for e in echoes if "error" in e]
    if failed:
        raise PodDesyncError(
            f"pod rank(s) errored where the head succeeded on {op!r}: "
            f"{[e['error'] for e in failed]}")
    if op == "step":
        mine = step_digest(reply)
        theirs = [e.get("digest") for e in echoes]
        if any(d != mine for d in theirs):
            raise PodDesyncError(
                f"pod lockstep divergence on step: head digest {mine}, "
                f"ranks {theirs} — the ranks' engine states have split")
    return reply


def serve(conn: Connection, engine=None) -> str:
    """Drive one connection to completion (fd mode — a lone socketpair
    peer, no listener); → "eof" (peer went away) or "shutdown"."""
    while True:
        try:
            msg = conn.recv()
        except TransportError:
            return "eof"
        if msg.get("op") == "shutdown":
            try:
                conn.send({"ok": True, "seq": msg.get("seq")})
            except TransportError:
                pass
            return "shutdown"
        try:
            reply = handle(engine, msg)
            engine = reply.pop("engine", engine)
        except Exception as e:            # typed bounce, worker stays up
            reply = {"error": f"{e}",
                     "etype": type(e).__name__,
                     "trace": traceback.format_exc(limit=8)}
        reply["seq"] = msg.get("seq")     # the desync-detection echo
        try:
            conn.send(reply)
        except TransportError:
            # the peer detached mid-round (router torn down with a step in
            # flight): same as EOF on recv — a --listen pod must go back to
            # accept, not die with the reply in hand
            return "eof"


class _Session:
    __slots__ = ("conn", "role", "buf")

    def __init__(self, conn: Connection):
        self.conn = conn
        self.role: str | None = None       # None until the first message
        self.buf = b""                     # partial-frame receive buffer


def _reject(conn: Connection, seq, error: str, etype: str):
    try:
        conn.send({"error": error, "etype": etype, "seq": seq})
    except TransportError:
        pass


def serve_listener(listener: Listener, *, once: bool = False,
                   pod: PodRuntime | None = None) -> int:
    """The concurrent accept loop: one mutating session + any number of
    read-only observers, multiplexed over select with NON-BLOCKING
    per-session receive buffers — a peer stalled mid-frame parks its
    partial frame in its own buffer and costs the other sessions nothing
    (the isolation the observer contract promises; only a peer that stops
    *reading* long enough to back up the send side is dropped, after
    SESSION_IO_TIMEOUT_S).  EOF on the mutator sends us back to accept
    (the next router re-inits its own engine); shutdown — or ``once``
    after the first mutating session ends — ends the process.  A pod head
    additionally holds the rank fabric: losing a rank (TransportError) or
    a lockstep divergence (PodDesyncError) is fatal for the whole pod —
    the head retires so the router reaps it."""
    from repro.serving.transport import _LEN, MAX_FRAME, unpack_payload

    engine = None
    mutator: _Session | None = None
    sessions: dict[socket.socket, _Session] = {}

    def drop(sess: _Session):
        nonlocal mutator, engine
        sessions.pop(sess.conn.sock, None)
        sess.conn.close()
        if sess is mutator:
            mutator = None
            engine = None             # the next mutator re-inits its own

    def close_all():
        for sess in list(sessions.values()):
            sess.conn.close()
        sessions.clear()
        if pod is not None:
            pod.close()
        listener.close()

    def pump(sess: _Session):
        """Drain the bytes available RIGHT NOW (select guarantees one recv
        returns promptly) and slice complete frames off the session
        buffer; → decoded messages, or None when the peer is gone or its
        framing broke (oversized length, garbage payload)."""
        try:
            chunk = sess.conn.sock.recv(1 << 16)
        except (BlockingIOError, InterruptedError):
            return []
        except OSError:
            return None
        if not chunk:
            return None
        sess.buf += chunk
        msgs = []
        while len(sess.buf) >= _LEN.size:
            (n,) = _LEN.unpack(sess.buf[:_LEN.size])
            if n > MAX_FRAME:
                return None
            if len(sess.buf) < _LEN.size + n:
                break
            payload = sess.buf[_LEN.size:_LEN.size + n]
            sess.buf = sess.buf[_LEN.size + n:]
            try:
                msgs.append(unpack_payload(payload))
            except TransportError:
                return None
        return msgs

    def process(sess: _Session, msg: dict):
        """One message through role assignment + dispatch; → None to keep
        serving, or the process's exit code."""
        nonlocal mutator, engine
        seq = msg.get("seq")
        op = msg.get("op")

        # -------------------------------------------- role assignment
        if sess.role is None:
            if op == "attach":
                mode = msg.get("mode", "mutate")
                if mode == "observe":
                    sess.role = "observe"
                elif mode == "mutate":
                    if mutator is not None:
                        _reject(sess.conn, seq,
                                "worker already has a mutating session; "
                                "attach as an observer or wait for the "
                                "detach", "WorkerBusyError")
                        drop(sess)
                        return None
                    sess.role = "mutate"
                    mutator = sess
                else:
                    _reject(sess.conn, seq,
                            f"unknown attach mode {mode!r}", "ValueError")
                    drop(sess)
                    return None
                try:
                    sess.conn.send({"ok": True, "role": sess.role,
                                    "seq": seq})
                except TransportError:
                    drop(sess)
                return None
            # legacy first op: an implicit mutate claim
            if mutator is not None:
                _reject(sess.conn, seq,
                        "worker already has a mutating session",
                        "WorkerBusyError")
                drop(sess)
                return None
            sess.role = "mutate"
            mutator = sess

        # ------------------------------------------------ dispatch
        if sess.role == "observe" and op not in OBSERVER_OPS:
            _reject(sess.conn, seq,
                    f"op {op!r} needs the mutating session (observers "
                    f"are read-only)", "PermissionError")
            return None
        if op == "shutdown":
            if pod is not None and pod.is_head:
                try:
                    pod.forward({"op": "shutdown"})
                except TransportError:
                    pass              # a rank already gone cannot object
            try:
                sess.conn.send({"ok": True, "seq": seq})
            except TransportError:
                pass
            return 0
        try:
            reply = dispatch(engine, msg, pod)
            engine = reply.pop("engine", engine)
        except TransportError as e:
            # a pod rank is gone: the lockstep contract is broken for
            # good — retire the whole pod; the parent's dead connection
            # is its typed signal to reap us
            print(f"pod head: rank fabric lost ({e}); retiring",
                  file=sys.stderr, flush=True)
            return 1
        except PodDesyncError as e:
            _reject(sess.conn, seq, str(e), "PodDesyncError")
            print(f"pod head: {e}; retiring", file=sys.stderr, flush=True)
            return 1
        except Exception as e:        # typed bounce, worker stays up
            reply = {"error": f"{e}",
                     "etype": type(e).__name__,
                     "trace": traceback.format_exc(limit=8)}
        reply["seq"] = seq            # the desync-detection echo
        try:
            sess.conn.send(reply)
        except TransportError:
            was_mutator = sess is mutator
            drop(sess)
            if was_mutator and once:
                return 0
        return None

    try:
        while True:
            rlist = [listener.sock] + list(sessions)
            readable, _, _ = select.select(rlist, [], [])
            for sock in readable:
                if sock is listener.sock:
                    try:
                        conn = listener.accept(
                            timeout=SESSION_IO_TIMEOUT_S,
                            conn_timeout=SESSION_IO_TIMEOUT_S)
                    except TransportError:
                        continue
                    sessions[conn.sock] = _Session(conn)
                    continue
                sess = sessions.get(sock)
                if sess is None:
                    continue
                msgs = pump(sess)
                if msgs is None:
                    was_mutator = sess is mutator
                    drop(sess)
                    if was_mutator and once:
                        return 0
                    continue
                for msg in msgs:
                    rc = process(sess, msg)
                    if rc is not None:
                        return rc
                    if sess.conn.sock not in sessions:
                        break         # process() dropped this session
    finally:
        close_all()


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    ap = argparse.ArgumentParser(prog="repro.serving.worker")
    ap.add_argument("fd", nargs="?", type=int,
                    help="inherited socketpair fd (ProcessReplica mode)")
    ap.add_argument("--listen", metavar="HOST:PORT",
                    help="bind a TCP listener instead (port 0 = kernel-"
                         "picked); prints WORKER_LISTENING host:port")
    ap.add_argument("--once", action="store_true",
                    help="exit after the first mutating session ends")
    ap.add_argument("--pod-rank", type=int, default=None,
                    help="this worker's rank in a multi-process pod "
                         "(0 = the RPC head)")
    ap.add_argument("--pod-size", type=int, default=None,
                    help="total ranks in the pod")
    ap.add_argument("--coordinator", metavar="HOST:PORT", default=None,
                    help="jax.distributed coordinator address (rank 0 "
                         "binds it; all ranks dial it)")
    ap.add_argument("--pod-peers", metavar="HOST:PORT,...", default=None,
                    help="head only: the non-head ranks' listen addresses, "
                         "rank-ordered")
    args = ap.parse_args(argv)
    pod = None
    if args.pod_rank is not None:
        if not args.listen:
            ap.error("--pod-rank needs --listen")
        if not args.pod_size or args.pod_size < 1:
            ap.error("--pod-rank needs --pod-size >= 1")
        if not (0 <= args.pod_rank < args.pod_size):
            ap.error("--pod-rank must be in [0, pod-size)")
        peers = tuple(p for p in (args.pod_peers or "").split(",") if p)
        if args.pod_rank == 0:
            if len(peers) != args.pod_size - 1:
                ap.error(f"head needs --pod-peers with {args.pod_size - 1} "
                         f"address(es)")
        elif peers:
            ap.error("--pod-peers is head-only (rank 0)")
        pod = PodRuntime(args.pod_rank, args.pod_size, args.coordinator,
                         peers)
    if args.listen:
        host, port = parse_addr(args.listen)
        listener = Listener(host, port)
        if pod is not None and pod.is_head and pod.peer_addrs:
            # claim every rank's mutating session BEFORE announcing the
            # pod — the banner means "dialable and whole"
            pod.connect_followers()
        print(f"WORKER_LISTENING {listener.host}:{listener.port}",
              flush=True)
        return serve_listener(listener, once=args.once, pod=pod)
    if args.fd is None:
        ap.error("need an inherited fd or --listen host:port")
    sock = socket.socket(fileno=args.fd)
    serve(Connection(sock))
    return 0


if __name__ == "__main__":
    sys.exit(main())
