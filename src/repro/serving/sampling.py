"""Sampling layer: temperature / top-k / greedy, seeded per request.

Sampling runs on the host over the one row of logits each slot produced this
tick — at serving time the (slots, 1, V) logits are already being pulled back
for lifecycle bookkeeping, so host-side numpy keeps the device tick a pure
fixed-shape decode (the TPU-friendly form) while every request still gets its
own reproducible RNG.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """temperature == 0 → greedy argmax (the deterministic default);
    top_k == 0 → sample over the full vocabulary."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


GREEDY = SamplingParams()


def sample_token(logits: np.ndarray, params: SamplingParams,
                 rng: np.random.Generator | None = None, *,
                 position: int = 0) -> int:
    """logits: (V,) float — one slot's next-token distribution.

    Callers holding a stateful per-request generator (Request.sample) pass
    ``rng`` and ignore ``position``.  Stateless callers must pass the
    token position instead: the fallback stream is derived from
    ``(seed, position)``, so successive positions draw fresh randomness —
    seeding from ``seed`` alone would rebuild the identical generator every
    call and emit the same token forever.
    """
    logits = np.asarray(logits, np.float64)
    if params.temperature <= 0.0:
        return int(np.argmax(logits))
    if rng is None:
        rng = np.random.default_rng((params.seed, position))
    scaled = logits / params.temperature
    if params.top_k > 0:
        k = min(params.top_k, scaled.size)
        kth = np.partition(scaled, -k)[-k]
        scaled = np.where(scaled >= kth, scaled, -np.inf)
    scaled -= np.max(scaled)
    probs = np.exp(scaled)
    probs /= probs.sum()
    return int(rng.choice(scaled.size, p=probs))
