"""Single-replica continuous-batching engine.

Every tick decodes one fixed-shape (slots, 1) token batch — the TPU-friendly
form (real multi-host serving shards the same cache via SERVE_RULES; this
engine exercises the logic end to end on CPU).  Three things distinguish it
from a naive batched decoder:

* **Chunked prefill.**  Admission prefills only the first ``prefill_chunk``
  prompt tokens one-shot; the rest of the prompt *streams through the shared
  decode tick* one token per step (the slot is in PREFILL phase and feeds
  prompt tokens instead of sampled ones).  A long prompt therefore never
  stalls the other slots' decode progress — admission cost per tick is
  bounded by the chunk.

* **Per-slot ring positions.**  The pool cache's "index" leaf is a (slots,)
  vector, so every slot gets its own RoPE angles, ring-buffer write slot and
  validity mask (see slots.py for why the seed's shared scalar was wrong).

* **Sampling layer.**  Greedy argmax is just the default SamplingParams;
  temperature/top-k sampling is seeded per request (scheduler.Request).
  Sampling is FUSED into the decode tail (steps.make_fused_decode_step):
  greedy rows take the device-sampled token, so a greedy tick pulls (B,)
  int32s instead of (B, 1, V) logits — only temperature rows pull their
  one logits row to keep their stateful per-request host RNG.

* **Speculative decoding** (``spec_k > 0``).  A model-free prompt-lookup
  draft (serving/draft.py) proposes up to k tokens per decode slot from
  the slot's own prompt+generated history; the target model verifies the
  whole window in ONE jitted multi-position decode (steps.make_verify_step)
  and the engine accepts the longest exact-match prefix — emitting a+1
  tokens per tick where the plain path emits 1.  Rejected tails rewind via
  the pool index vector (the same mechanism preemption uses), so rejected
  K/V is simply re-covered.  PREFILL rows ride the same window: up to W
  upcoming prompt tokens stream per tick.  Acceptance is exact-match on
  sampled tokens, so streams are bit-identical to the plain path for ANY
  sampling mode; families whose state can't rewind (SSM/hybrid recurrence,
  sliding-window rings that wrap) silently serve the plain path.

The low-level admit()/tick() surface is kept compatible with the seed's
launch/serve.py engine; submit()/step() add the queued-request lifecycle.
"""
from __future__ import annotations

import hashlib
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LM
from repro.models.attention import Attention
from repro.models.steps import (
    make_decode_step, make_fused_decode_step, make_prefill_step,
    make_verify_step,
)
from repro.serving.draft import ngram_propose
from repro.serving.scheduler import FCFSScheduler, Request
from repro.serving.slots import make_pool

PHASE_FREE, PHASE_PREFILL, PHASE_DECODE = 0, 1, 2


class EngineCore:
    """Model params + jitted step functions, shared by all replicas of one
    deployment — N engines reuse one compile and one weight copy."""

    def __init__(self, cfg, max_seq: int, *, seed: int = 0):
        self.cfg = cfg
        self.max_seq = max_seq
        params, _ = LM.init(jax.random.PRNGKey(seed), cfg)
        self.params = params
        self.prefill = jax.jit(make_prefill_step(cfg, max_seq))
        self.decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))
        # compiled lazily on first use: a fused-sampling decode tick and the
        # multi-position verify step (one retrace per distinct window width)
        self.fused_decode = jax.jit(make_fused_decode_step(cfg),
                                    donate_argnums=(2,))
        self.verify = jax.jit(make_verify_step(cfg), donate_argnums=(2,))


class EngineStats:
    """Per-replica accumulators: a drainable window (one monitoring tick) on
    top of lifetime totals."""

    def __init__(self):
        self.total_completed = 0
        self.total_tokens = 0
        self.total_ticks = 0
        self.total_busy = 0.0
        self.total_spec_proposed = 0
        self.total_spec_accepted = 0
        self.completed_by_tier: dict[str, int] = {}
        self.latencies_ms = deque(maxlen=4096)
        self.queue_depth = 0
        self._reset_window()

    def _reset_window(self):
        self._win_lat: list[float] = []
        self._win_lat_tiers: dict[str, list[float]] = {}
        self._win_completed = 0
        self._win_tokens = 0
        self._win_ticks = 0
        self._win_busy = 0.0
        self._win_spec_prop = 0
        self._win_spec_acc = 0

    def on_tick(self, busy_slots: int, slots: int, queue_depth: int):
        self.total_ticks += 1
        self.total_busy += busy_slots / max(slots, 1)
        self._win_ticks += 1
        self._win_busy += busy_slots / max(slots, 1)
        self.queue_depth = queue_depth

    def on_complete(self, request: Request):
        tier = getattr(request, "tier", "interactive")
        lat = request.latency_s
        if lat is not None:
            self.latencies_ms.append(lat * 1e3)
            self._win_lat.append(lat * 1e3)
            self._win_lat_tiers.setdefault(tier, []).append(lat * 1e3)
        self.total_completed += 1
        self.completed_by_tier[tier] = self.completed_by_tier.get(tier, 0) + 1
        self.total_tokens += len(request.tokens_out)
        self._win_completed += 1
        self._win_tokens += len(request.tokens_out)

    def on_speculate(self, proposed: int, accepted: int):
        self.total_spec_proposed += proposed
        self.total_spec_accepted += accepted
        self._win_spec_prop += proposed
        self._win_spec_acc += accepted

    @property
    def slot_utilization(self) -> float:
        return self.total_busy / max(self.total_ticks, 1)

    def drain_window(self) -> dict:
        """Window metrics since the last drain (one ReplicaReport's worth)."""
        out = {
            "latency_ms_samples": list(self._win_lat),
            # the same samples keyed by tier — the collector's per-tier SLO
            # channels (latency_p95_interactive / _batch) fold these
            "lat_tiers": {t: list(v)
                          for t, v in self._win_lat_tiers.items() if v},
            "n_requests": self._win_completed,
            "n_tokens": self._win_tokens,
            "slot_util": self._win_busy / max(self._win_ticks, 1),
            "queue_depth": self.queue_depth,
            "spec_proposed": self._win_spec_prop,
            "spec_accepted": self._win_spec_acc,
        }
        self._reset_window()
        return out


def validate_request(cfg, max_seq: int, prompt: np.ndarray, frames=None):
    """Shape/length validation for one request against (cfg, max_seq).

    Module-level because TWO parties run it: the engine at submit (a
    malformed request must bounce back typed, not abort a batch step
    mid-tick), and a remote replica's parent-side stub — batched submits
    ride the step RPC, so without a local check a bad request would only
    surface a round later, on the wrong side of the wire."""
    P = len(prompt)
    if P < 1:
        raise ValueError("empty prompt")
    if (not cfg.attn_free and cfg.sliding_window is None
            and P >= max_seq):
        raise ValueError(f"prompt ({P}) must fit below max_seq "
                         f"({max_seq}) with room to generate")
    if cfg.family == "vlm" and P <= cfg.n_vision_patches:
        raise ValueError("vlm prompt must extend past the patch prefix")
    if cfg.enc_dec:
        if frames is None:
            raise ValueError("enc-dec request needs encoder frames")
        frames = np.asarray(frames)
        if frames.ndim != 2 or frames.shape[1] != cfg.d_model:
            raise ValueError(f"frames must be (S_enc, d_model="
                             f"{cfg.d_model}), got {frames.shape}")
        if frames.shape[0] < 1 or frames.shape[0] > max_seq:
            raise ValueError(f"encoder length ({frames.shape[0]}) must "
                             f"fit the cross pool (1..{max_seq})")


class ServingEngine:
    """One replica: S decode slots over one shared cache pytree."""

    def __init__(self, cfg, *, slots: int, max_seq: int, seed: int = 0,
                 prefill_chunk: int | None = None,
                 core: EngineCore | None = None, replica_id: int = 0,
                 pool: str = "dense", block_size: int | None = None,
                 num_blocks: int | None = None, partitions: int = 1,
                 spec_k: int = 0, spec_ngram: int = 3):
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.replica_id = replica_id
        self.core = core if core is not None else EngineCore(
            cfg, max_seq, seed=seed)
        self.params = self.core.params
        self.prefill = self.core.prefill
        self.decode = self.core.decode
        self.pool = make_pool(cfg, slots, max_seq, pool=pool,
                              block_size=block_size, num_blocks=num_blocks,
                              partitions=partitions)
        # "paged" on a family with no pageable leaves (pure SSM, short
        # sliding windows) degenerates to the dense pool — same cache tree,
        # so the engine's dense code paths apply unchanged
        self._paged = getattr(self.pool, "is_paged", False)
        self.prefill_tokens = 0      # prompt tokens actually computed
        self.prompt_tokens = 0       # prompt tokens admitted (incl. shared)
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self._tokens_host = np.zeros(slots, np.int32)
        # host-side token truth may run ahead of the staged device copy:
        # verify ticks build their window from _tokens_host directly, so
        # they defer the (slots, 1) device put until a fused/legacy tick
        # (or admission) actually needs self.tokens
        self._tokens_dirty = False
        self.pos = np.zeros(slots, np.int64)        # per-slot position
        self.remaining = np.zeros(slots, np.int64)  # tokens left to generate
        self.active = np.zeros(slots, bool)
        self.phase = np.zeros(slots, np.int8)
        self.slot_owner: dict[int, Request] = {}    # cleared on release
        chunk = prefill_chunk if prefill_chunk is not None else max_seq
        if cfg.family == "vlm":
            # the patch prefix must land in the one-shot prefill portion
            chunk = max(chunk, cfg.n_vision_patches + 1)
        self.prefill_chunk = max(chunk, 1)
        self._prompt: list[np.ndarray | None] = [None] * slots
        self._fed = np.zeros(slots, np.int64)       # prompt tokens staged
        # vlm prefix KV depends on the vision patches, not just the token
        # ids, so the patch content is digested into every prefix-cache key:
        # two prompts with identical ids but different patches can never
        # alias in the registry.  The engine feeds the same zero patches to
        # every request today (so this is one constant per engine); if
        # patches become request-dependent, digest them per request here.
        self._patch_key = (hashlib.sha1(np.zeros(
            (cfg.n_vision_patches, cfg.d_model), np.float32).tobytes()
        ).digest() if cfg.family == "vlm" else b"")
        self.spec_k = max(int(spec_k), 0)
        self.spec_ngram = max(int(spec_ngram), 1)
        # speculation needs a rewindable cache: recurrent state (SSM towers,
        # hybrid interleaves) can't roll back, and a sliding-window ring
        # shorter than max_seq wraps — speculative writes would clobber live
        # context that rewinding the index cannot restore.  Ineligible
        # families silently serve the plain path; the knob is never an error.
        self._spec_ok = (
            self.spec_k > 0
            and cfg.ssm is None and getattr(cfg, "hybrid", None) is None
            and not cfg.enc_dec and not cfg.attn_free
            and Attention.cache_len(cfg, max_seq) == max_seq)
        self.logits_pulls = 0        # host (·, V) logits materializations
        self.scheduler = FCFSScheduler()
        self.draining = False
        self.stats = EngineStats()

    # ------------------------------------------------------------- queue API

    def submit(self, request: Request, now: float = 0.0):
        """Enqueue one request.  Validation happens HERE, not at admission:
        a malformed request must bounce back to the submitter, not abort a
        batch step mid-tick with other requests in flight."""
        self._validate(np.asarray(request.prompt).reshape(-1),
                       frames=request.frames)
        if request.t_submit is None:
            request.t_submit = now
        self.scheduler.submit(request)

    def _validate(self, prompt: np.ndarray, frames=None):
        validate_request(self.cfg, self.max_seq, prompt, frames=frames)

    @property
    def idle(self) -> bool:
        return not self.active.any() and not self.scheduler

    @property
    def load(self) -> float:
        """Admitted + queued work relative to slot capacity."""
        return (int(self.active.sum()) + self.scheduler.depth) / max(
            self.slots, 1)

    def step(self, now: float | None = None) -> list[Request]:
        """One scheduling round: FCFS admission into free slots, one decode
        tick, completion + slot release.  Returns finished requests."""
        if now is None:
            now = time.monotonic()
        completed: list[Request] = []
        if not self.draining:
            free = [s for s in range(self.slots) if not self.active[s]]
            while free and self.scheduler:
                if self._paged:
                    # head-of-line capacity gate: a paged pool can have free
                    # SLOTS but no free BLOCKS (slots oversubscribe the
                    # pool); admitting anyway would fault mid-decode, and
                    # skipping ahead would break FCFS order
                    head = self.scheduler.peek()
                    if not self.pool.can_admit(
                            free[0], np.asarray(head.prompt).reshape(-1),
                            head.gen_len, extra=self._patch_key):
                        break
                req = self.scheduler.pop()
                slot = free.pop(0)
                req.t_admit = now
                req.replica_id = self.replica_id
                self.admit(slot, req.prompt, req.gen_len, request=req)
                if self.phase[slot] == PHASE_DECODE:
                    req.t_first_token = now      # prompt fit in one chunk
        for slot in self.tick(now=now):
            req = self.slot_owner.get(slot)
            self.release_slot(slot)
            if isinstance(req, Request):
                req.t_done = now
                self.stats.on_complete(req)
                completed.append(req)
        self.stats.on_tick(int(self.active.sum()), self.slots,
                           self.scheduler.depth)
        return completed

    # ------------------------------------------------------------- slot API

    def admit(self, slot: int, prompt: np.ndarray, gen_len: int,
              request: Request | None = None, frames=None):
        """Prefill one slot: one-shot over the first chunk, the remainder of
        the prompt streams through tick() (PREFILL phase).  enc-dec families
        pass ``frames`` (or carry them on the request): the encoder runs
        whole in the one-shot portion — cross K/V cover every frame and the
        decoder prompt tail can still stream through the decode tick."""
        if self.active[slot]:
            raise ValueError(f"slot {slot} is still active")
        if frames is None and request is not None:
            frames = request.frames
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        P = len(prompt)
        # defense; submit() already rejected malformed requests
        self._validate(prompt, frames=frames)
        if not self.cfg.attn_free and self.cfg.sliding_window is None:
            # full-attention ring wrap would overwrite live context
            gen_len = min(gen_len, self.max_seq - P)
        self.prompt_tokens += P
        if self._paged:
            h_tok = self.pool.admit_slot(slot, prompt, gen_len,
                                         extra=self._patch_key)
            if h_tok > 0:
                # resident prefix: the shared blocks already hold positions
                # 0..h_tok-1, so NO prefill runs at all — the rest of the
                # prompt streams through the decode tick exactly like the
                # chunked-prefill tail, starting at position h_tok
                self.prefill_tokens += P - h_tok
                self.pool.set_slot_index(slot, h_tok)
                self.pos[slot] = h_tok
                self._prompt[slot] = prompt
                self.remaining[slot] = gen_len
                self.active[slot] = True
                if request is not None:
                    self.slot_owner[slot] = request
                self._tokens_host[slot] = int(prompt[h_tok])
                self._fed[slot] = h_tok + 1      # h_tok shared + 1 staged
                self.phase[slot] = PHASE_PREFILL
                self._stage_tokens()
                return
        c = P if self.prefill_chunk >= P else self.prefill_chunk
        self.prefill_tokens += P
        inputs = {"tokens": jnp.asarray(prompt[None, :c])}
        if self.cfg.family == "vlm":
            inputs["patches"] = jnp.zeros(
                (1, self.cfg.n_vision_patches, self.cfg.d_model),
                self.cfg.cdtype)
        if self.cfg.enc_dec:
            inputs["frames"] = jnp.asarray(np.asarray(frames)[None],
                                           self.cfg.cdtype)
        logits, cache1 = self.prefill(self.params, inputs)
        self.pool.write(cache1, slot, index=c)
        if self._paged:
            # blocks fully covered by the one-shot prefill are complete
            # prompt prefixes — publish them for future admissions to share
            for j in range(c // self.pool.block_size):
                self.pool.register_block(slot, j, prompt,
                                         extra=self._patch_key)
        self.pos[slot] = c
        self._prompt[slot] = prompt
        self.remaining[slot] = gen_len
        self.active[slot] = True
        if request is not None:
            self.slot_owner[slot] = request
        if c == P:
            row = np.asarray(logits[0, -1], np.float32)
            tok = (request.sample(row) if request is not None
                   else int(np.argmax(row)))
            self._tokens_host[slot] = tok
            self.phase[slot] = PHASE_DECODE
        else:
            self._tokens_host[slot] = int(prompt[c])
            self._fed[slot] = c + 1              # c cached + 1 staged
            self.phase[slot] = PHASE_PREFILL
        self._stage_tokens()

    def tick(self, now: float | None = None) -> list[int]:
        """One decode step for all slots (inactive slots decode garbage that
        is simply ignored).  Returns slots that finished this tick.

        Three paths, one contract (bit-identical token streams):

        * **legacy** — ``self.decode`` was replaced (sharded topologies
          install their own compiled step; tests monkeypatch): bulk-pull the
          (slots, 1, V) logits and sample on host, as the seed did.
        * **fused** — sampling runs in the decode tail on device; greedy
          rows never materialize logits on host (the engine pulls (slots,)
          int32 tokens), temperature rows pull only their one (V,) row.
        * **verify** — when speculation is on and a draft (or a streamable
          prompt tail) exists, ONE multi-position decode verifies a whole
          (slots, W) window and the engine emits the accepted prefix.
        """
        if not self.active.any():
            return []
        if self.decode is not self.core.decode:
            if self._tokens_dirty:
                self._stage_tokens()
            logits, cache = self.decode(self.params, self.tokens,
                                        self.pool.cache)
            self.pool.cache = cache
            rows = np.asarray(logits[:, 0], np.float32)     # (slots, V)
            self.logits_pulls += 1
            toks = np.argmax(rows, axis=1).astype(np.int32)
            return self._advance(toks, lambda s: rows[s], now)
        if self._spec_ok:
            drafts, window_w = self._plan_window()
            if window_w >= 2:
                return self._tick_verify(drafts, window_w, now)
        return self._tick_fused(now)

    # -------------------------------------------------- shared tick plumbing

    def _stage_tokens(self):
        """Materialize the device copy of every slot's next input token."""
        self.tokens = jnp.asarray(self._tokens_host[:, None])
        self._tokens_dirty = False

    def _emit(self, slot: int, req, tok_dev: int, fetch_row) -> int:
        """One sampled token for a slot, device-first: greedy rows take the
        device-sampled token (bit-equal to host argmax), temperature rows
        pull their one logits row and keep their stateful host RNG."""
        if isinstance(req, Request) and req.sampling.temperature > 0.0:
            return req.sample(fetch_row(slot))
        tok = int(tok_dev)
        if isinstance(req, Request):
            req.tokens_out.append(tok)
        return tok

    def _advance(self, toks_host, fetch_row, now) -> list[int]:
        """Single-position tick bookkeeping: per-slot host state advance
        given the device-sampled tokens and a lazy logits-row getter."""
        done: list[int] = []
        for slot in np.nonzero(self.active)[0]:
            slot = int(slot)
            self.pos[slot] += 1
            req = self.slot_owner.get(slot)
            if self.phase[slot] == PHASE_PREFILL:
                prompt = self._prompt[slot]
                pos = int(self.pos[slot])
                if (self._paged and pos % self.pool.block_size == 0
                        and pos <= len(prompt)):
                    # a streamed block just filled with pure prompt tokens —
                    # publish it (positions pos-bk..pos-1 are prompt[:pos])
                    self.pool.register_block(
                        slot, pos // self.pool.block_size - 1, prompt,
                        extra=self._patch_key)
                if self._fed[slot] < len(prompt):
                    self._tokens_host[slot] = int(prompt[self._fed[slot]])
                    self._fed[slot] += 1
                else:
                    # last prompt token just decoded → first generated token
                    self._tokens_host[slot] = self._emit(
                        slot, req, toks_host[slot], fetch_row)
                    self.phase[slot] = PHASE_DECODE
                    if (isinstance(req, Request) and req.t_first_token is None
                            and now is not None):
                        req.t_first_token = now
            else:
                self.remaining[slot] -= 1
                if self.remaining[slot] <= 0:
                    self.active[slot] = False
                    done.append(slot)
                else:
                    self._tokens_host[slot] = self._emit(
                        slot, req, toks_host[slot], fetch_row)
        self._stage_tokens()
        return done

    def _tick_fused(self, now) -> list[int]:
        """One decode step with sampling fused into the decode tail: the
        kernel draws from stateless (seed, rid, pos) counters per row, and a
        greedy tick pulls (slots,) int32 tokens — zero host logits traffic."""
        B = self.slots
        seed = np.zeros(B, np.int32)
        rid = np.zeros(B, np.int32)
        pos = np.zeros(B, np.int32)
        temp = np.zeros(B, np.float32)
        for slot, req in self.slot_owner.items():
            if isinstance(req, Request):
                seed[slot] = req.sampling.seed
                rid[slot] = req.rid
                pos[slot] = len(req.tokens_out)
                temp[slot] = req.sampling.temperature
        if self._tokens_dirty:
            self._stage_tokens()
        toks, logits, cache = self.core.fused_decode(
            self.params, self.tokens, self.pool.cache,
            jnp.asarray(seed), jnp.asarray(rid), jnp.asarray(pos),
            jnp.asarray(temp))
        self.pool.cache = cache
        toks_host = np.asarray(toks)                    # (slots,) int32

        def fetch_row(s):
            self.logits_pulls += 1
            return np.asarray(logits[s, 0], np.float32)

        return self._advance(toks_host, fetch_row, now)

    # ------------------------------------------------------- speculative path

    def _plan_window(self) -> tuple[dict[int, np.ndarray], int]:
        """Collect n-gram drafts and size this tick's verify window.

        Returns (drafts, W).  W is clamped so no ACTIVE row's window writes
        past ``max_seq - 1``: the multi-position decode advances EVERY row's
        index by W, writes wrap modulo the ring, and a wrapped garbage write
        would clobber valid context (or, paged, a shared prefix block) that
        rewinding the index cannot restore.  Inactive rows only ever write
        their own garbage slot, so they don't constrain W.  W < 2 means a
        window buys nothing this tick — caller falls back to the fused tick.
        """
        drafts: dict[int, np.ndarray] = {}
        w_cap = self.spec_k + 1
        streamable = False
        for slot in np.nonzero(self.active)[0]:
            slot = int(slot)
            w_cap = min(w_cap, self.max_seq - int(self.pos[slot]))
            if self.phase[slot] == PHASE_PREFILL:
                if self._fed[slot] < len(self._prompt[slot]):
                    streamable = True
                continue
            req = self.slot_owner.get(slot)
            lim = min(self.spec_k, int(self.remaining[slot]) - 1)
            if not isinstance(req, Request) or lim <= 0:
                continue
            # plain-int history: tokens_out already holds python ints, and
            # the list path through ngram_propose is tick-critical
            hist = np.asarray(req.prompt).ravel().tolist() + \
                list(req.tokens_out)
            d = ngram_propose(hist, k=lim, ngram=self.spec_ngram)
            if d.size:
                drafts[slot] = d
        if not drafts and not streamable:
            return {}, 0
        return drafts, max(w_cap, 0)

    def _tick_verify(self, drafts: dict[int, np.ndarray], W: int,
                     now) -> list[int]:
        """One multi-position decode over a (slots, W) window.

        Lane 0 is every slot's staged token (what the plain tick would have
        fed); decode lanes 1.. carry that slot's draft, prefill lanes carry
        upcoming prompt tokens.  After the device pass the engine accepts
        the longest exact-match draft prefix per slot and REWINDS the pool
        index vector to the authoritative host positions — unconsumed lanes
        simply get re-covered by later writes, the same mechanism preemption
        relies on.
        """
        B = self.slots
        window = np.zeros((B, W), np.int32)
        window[:, 0] = self._tokens_host
        n_extra = np.zeros(B, np.int64)      # prompt tokens fed in lanes 1..
        n_draft = np.zeros(B, np.int64)      # draft tokens staged in lanes 1..
        for slot in np.nonzero(self.active)[0]:
            slot = int(slot)
            if self.phase[slot] == PHASE_PREFILL:
                prompt = self._prompt[slot]
                m = min(W - 1, len(prompt) - int(self._fed[slot]))
                if m > 0:
                    lo = int(self._fed[slot])
                    window[slot, 1:1 + m] = prompt[lo:lo + m]
                    n_extra[slot] = m
            elif slot in drafts:
                d = drafts[slot][:W - 1]
                window[slot, 1:1 + len(d)] = d
                n_draft[slot] = len(d)
        toks, logits, cache = self.core.verify(
            self.params, jnp.asarray(window), self.pool.cache)
        self.pool.cache = cache
        toks_host = np.asarray(toks)                    # (slots, W) int32

        done: list[int] = []
        for slot in np.nonzero(self.active)[0]:
            slot = int(slot)
            req = self.slot_owner.get(slot)

            def fetch_row(lane, slot=slot):
                self.logits_pulls += 1
                return np.asarray(logits[slot, lane], np.float32)

            if self.phase[slot] == PHASE_PREFILL:
                done.extend(self._advance_prefill_window(
                    slot, req, int(n_extra[slot]), toks_host, fetch_row, now))
            else:
                done.extend(self._advance_decode_window(
                    slot, req, window, int(n_draft[slot]), toks_host,
                    fetch_row))
        # authoritative rewind: host positions are truth, rejected (and
        # padding) lanes' device writes fall past the new horizon.  The
        # next-token device copy is NOT re-staged here — the next verify
        # window reads _tokens_host directly, so the put is deferred until
        # a fused/legacy tick (or admission) needs it.
        self.pool.set_index(self.pos.astype(np.int32))
        self._tokens_dirty = True
        return done

    def _advance_prefill_window(self, slot, req, m, toks_host, fetch_row,
                                now) -> list[int]:
        """A PREFILL slot consumed lanes 0..m: the staged prompt token plus
        m more.  Publish every prompt block the window crossed, then either
        stage the next prompt token or transition to DECODE off the last
        consumed lane's logits."""
        prompt = self._prompt[slot]
        pos_old = int(self.pos[slot])
        self.pos[slot] += 1 + m
        self._fed[slot] += m
        pos_new = int(self.pos[slot])
        if self._paged:
            bs = self.pool.block_size
            q = (pos_old // bs + 1) * bs
            while q <= min(pos_new, len(prompt)):
                self.pool.register_block(slot, q // bs - 1, prompt,
                                         extra=self._patch_key)
                q += bs
        if self._fed[slot] < len(prompt):
            self._tokens_host[slot] = int(prompt[self._fed[slot]])
            self._fed[slot] += 1
        else:
            self._tokens_host[slot] = self._emit(
                slot, req, toks_host[slot, m], lambda s: fetch_row(m))
            self.phase[slot] = PHASE_DECODE
            if (isinstance(req, Request) and req.t_first_token is None
                    and now is not None):
                req.t_first_token = now
        return []

    def _advance_decode_window(self, slot, req, window, m, toks_host,
                               fetch_row) -> list[int]:
        """A DECODE slot with m draft lanes: accept the longest prefix where
        the model's sampled token equals the draft, emit a+1 tokens.  Exact-
        match acceptance keeps streams bit-identical for ANY sampling mode —
        temperature rows sample each lane with their stateful host RNG (one
        draw per emitted token, same as the plain path) and accept iff the
        sample agrees with the draft."""
        a = 0
        for j in range(m + 1):
            # one simulated plain tick per lane: decrement, maybe complete
            # (the plain path's completing tick samples NOTHING — neither
            # may this one, or temperature RNG streams would diverge)
            self.pos[slot] += 1
            self.remaining[slot] -= 1
            if self.remaining[slot] <= 0:
                self.stats.on_speculate(m, a)
                self.active[slot] = False
                return [slot]
            tok = self._emit(slot, req, toks_host[slot, j],
                             lambda s, j=j: fetch_row(j))
            self._tokens_host[slot] = tok
            if not (j < m and tok == int(window[slot, j + 1])):
                break
            a += 1
        self.stats.on_speculate(m, a)
        return []

    def release_slot(self, slot: int):
        """Free a finished slot: owner cleared here — a stale owner must
        never survive the slot's release (seed bug)."""
        self.active[slot] = False
        self.phase[slot] = PHASE_FREE
        self._prompt[slot] = None
        self._fed[slot] = 0
        self.slot_owner.pop(slot, None)
        if self._paged:
            # refcount decrement: blocks nobody references (no table row,
            # no registry entry) return to the free list immediately
            self.pool.release(slot)

    def preempt_slot(self, slot: int) -> Request | None:
        """Evict an in-flight request from its slot, rewound for requeue.
        The slot's cache rows are garbage after this, which is safe: an
        inactive slot's decode output is ignored and the next admission
        overwrites the rows."""
        req = self.slot_owner.get(slot)
        self.release_slot(slot)
        if isinstance(req, Request):
            req.reset_generation()
            return req
        return None

    def evacuate(self) -> list[Request]:
        """Empty the whole replica for an immediate park/retire: queued
        requests plus every in-flight one (preempted, rewound).  Nothing is
        left behind — the caller requeues the returned requests elsewhere."""
        out = self.scheduler.drain()
        for slot in np.nonzero(self.active)[0]:
            req = self.preempt_slot(int(slot))
            if req is not None:
                out.append(req)
        if self._paged:
            # with every slot released, dropping the prefix registry's own
            # references drives every block refcount back to zero
            self.pool.release_registry()
        return out

    def lifetime(self) -> dict:
        """Lifetime accumulators for fleet-level metrics — ONE definition,
        shared by the in-process replica wrapper and the subprocess worker,
        so the two transports cannot drift apart field-by-field."""
        out = {
            "latencies_ms": [float(v) for v in self.stats.latencies_ms],
            "total_tokens": int(self.stats.total_tokens),
            "total_completed": int(self.stats.total_completed),
            "completed_interactive": int(
                self.stats.completed_by_tier.get("interactive", 0)),
            "completed_batch": int(
                self.stats.completed_by_tier.get("batch", 0)),
            # served ticks: the weight the router's fleet-mean utilization
            # uses (a two-tick replacement must not weigh like a survivor)
            "total_ticks": int(self.stats.total_ticks),
            "slot_utilization": float(self.stats.slot_utilization),
            "queue_depth": int(self.scheduler.depth),
            "prefill_tokens": int(self.prefill_tokens),
            "prompt_tokens": int(self.prompt_tokens),
            "spec_proposed": int(self.stats.total_spec_proposed),
            "spec_accepted": int(self.stats.total_spec_accepted),
            "logits_pulls": int(self.logits_pulls),
        }
        if self._paged:
            out["prefix_hits"] = int(self.pool.n_prefix_hits)
            out["prefix_admits"] = int(self.pool.n_admits)
            out["tokens_shared"] = int(self.pool.tokens_shared)
        return out

    # ------------------------------------------------------------- compat

    @property
    def cache(self):
        return self.pool.cache

    @cache.setter
    def cache(self, value):
        self.pool.cache = value
