"""Multi-replica continuous-batching serving subsystem (the data plane).

Layout:
  sampling.py   — per-request sampling params + host-side token sampler
  scheduler.py  — Request lifecycle + FCFS admission queue
  slots.py      — generic KV slot pool over any family's cache pytree
  engine.py     — single-replica engine: chunked prefill streamed through the
                  batched decode tick, per-slot ring positions
  replica.py    — the Replica protocol (submit/step/report/scale hooks) and
                  its five backends: InProcessReplica, ShardedReplica (one
                  engine spanning a device mesh), ProcessReplica (engine in
                  a forked worker over a socketpair), TcpReplica (engine in
                  a listening worker pod the router dials),
                  DistributedPodReplica (a multi-process pod of worker
                  ranks behind one RPC head, stepping in lockstep)
  transport.py  — length-prefixed JSON framing, TCP Listener/dial endpoints
                  + Request/ReplicaReport/ModelConfig codecs (the wire
                  contract)
  worker.py     — the far side of the remote backends (inherited-fd,
                  --listen host:port, or --pod-rank R pod mode); one
                  mutating session + concurrent read-only observers
  observe.py    — MetricsObserver: read-only attach to a live worker/pod
                  (lifetime/status polls that never steal the router's
                  connection or drain its metric window)
  fleet.py      — launch_fleet / launch_pod: local listening workers and
                  multi-process pods for demos/CI
  chaos.py      — fault-injection harness (FaultyConnection, ChaosProxy)
                  pinning that faults surface typed, never as hangs, plus
                  DelayedReplica: deterministic virtual-clock transport
                  RTT in front of any replica (the inter-region latency
                  injection shim)
  profiles.py   — ReplicaProfile / FleetPlan / SpotMarket: heterogeneous
                  capacity (cost per tick, relative speed, preemptible,
                  region + RTT matrix) and the profile-aware planner's
                  marginal-cost model, spot-priced per tick by a seeded
                  mean-reverting market process
  router.py     — N replicas behind the protocol: least-loaded routing
                  (speed/cost-normalized when profiled, tier + in-region
                  placement), scale up/down mid-run (evacuate + requeue),
                  straggler eviction + preemption absorption,
                  ReplicaReport stream for core/monitoring
  workload.py   — synthetic request generation (shares sim.WorkloadSpec)
  closed_loop.py— the full control loop (router + collector + allocator),
                  shared by examples/serve_autoscale.py and the serving
                  latency benchmark's --engine mode, topology-agnostic

The `core/` control plane (scaler + allocator) drives ReplicaRouter.scale_to;
examples/serve_autoscale.py closes the loop end to end on CPU.
"""
from repro.serving.engine import EngineCore, ServingEngine
from repro.serving.fleet import (
    Fleet,
    PodHandle,
    launch_fleet,
    launch_pod,
    spawn_worker,
)
from repro.serving.observe import MetricsObserver
from repro.serving.replica import (
    DistributedPodReplica,
    InProcessReplica,
    ProcessReplica,
    Replica,
    ShardedReplica,
    SocketReplica,
    TcpReplica,
)
from repro.serving.chaos import DelayedReplica
from repro.serving.profiles import FleetPlan, ReplicaProfile, SpotMarket
from repro.serving.router import ReplicaRouter, TOPOLOGIES
from repro.serving.sampling import SamplingParams, sample_token
from repro.serving.scheduler import FCFSScheduler, Request, TIERS
from repro.serving.slots import (
    PagedSlotPool, SlotPool, make_pool, paged_cache_spec, write_slot,
)
from repro.serving.transport import (
    Connection,
    Listener,
    TransportError,
    WorkerBusyError,
    dial,
    parse_addr,
)
from repro.serving.workload import (
    poisson_arrival_times, shared_prefix_requests, synthetic_requests,
    tiered_requests,
)

__all__ = [
    "EngineCore", "ServingEngine", "ReplicaRouter", "TOPOLOGIES",
    "Replica", "InProcessReplica", "ShardedReplica", "ProcessReplica",
    "SocketReplica", "TcpReplica", "DistributedPodReplica",
    "Fleet", "PodHandle", "launch_fleet", "launch_pod", "spawn_worker",
    "MetricsObserver",
    "Connection", "Listener", "TransportError", "WorkerBusyError",
    "dial", "parse_addr",
    "SamplingParams", "sample_token",
    "FCFSScheduler", "Request", "TIERS",
    "FleetPlan", "ReplicaProfile", "SpotMarket", "DelayedReplica",
    "SlotPool", "PagedSlotPool", "make_pool", "paged_cache_spec",
    "write_slot",
    "poisson_arrival_times", "shared_prefix_requests", "synthetic_requests",
    "tiered_requests",
]
