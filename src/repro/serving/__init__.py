"""Multi-replica continuous-batching serving subsystem (the data plane).

Layout:
  sampling.py   — per-request sampling params + host-side token sampler
  scheduler.py  — Request lifecycle + FCFS admission queue
  slots.py      — generic KV slot pool over any family's cache pytree
  engine.py     — single-replica engine: chunked prefill streamed through the
                  batched decode tick, per-slot ring positions
  replica.py    — the Replica protocol (submit/step/report/scale hooks) and
                  its four backends: InProcessReplica, ShardedReplica (one
                  engine data-parallel over a device mesh), ProcessReplica
                  (engine in a forked worker over a socketpair), TcpReplica
                  (engine in a listening worker pod the router dials)
  transport.py  — length-prefixed JSON framing, TCP Listener/dial endpoints
                  + Request/ReplicaReport/ModelConfig codecs (the wire
                  contract)
  worker.py     — the far side of ProcessReplica/TcpReplica (inherited-fd
                  or --listen host:port)
  fleet.py      — launch_fleet: N local listening workers for demos/CI
  chaos.py      — fault-injection harness (FaultyConnection, ChaosProxy)
                  pinning that faults surface typed, never as hangs
  router.py     — N replicas behind the protocol: least-loaded routing,
                  scale up/down mid-run (evacuate + requeue), straggler
                  eviction, ReplicaReport stream for core/monitoring
  workload.py   — synthetic request generation (shares sim.WorkloadSpec)
  closed_loop.py— the full control loop (router + collector + allocator),
                  shared by examples/serve_autoscale.py and the serving
                  latency benchmark's --engine mode, topology-agnostic

The `core/` control plane (scaler + allocator) drives ReplicaRouter.scale_to;
examples/serve_autoscale.py closes the loop end to end on CPU.
"""
from repro.serving.engine import EngineCore, ServingEngine
from repro.serving.fleet import Fleet, launch_fleet, spawn_worker
from repro.serving.replica import (
    InProcessReplica,
    ProcessReplica,
    Replica,
    ShardedReplica,
    SocketReplica,
    TcpReplica,
)
from repro.serving.router import ReplicaRouter, TOPOLOGIES
from repro.serving.sampling import SamplingParams, sample_token
from repro.serving.scheduler import FCFSScheduler, Request
from repro.serving.slots import SlotPool, write_slot
from repro.serving.transport import (
    Connection,
    Listener,
    TransportError,
    dial,
    parse_addr,
)
from repro.serving.workload import poisson_arrival_times, synthetic_requests

__all__ = [
    "EngineCore", "ServingEngine", "ReplicaRouter", "TOPOLOGIES",
    "Replica", "InProcessReplica", "ShardedReplica", "ProcessReplica",
    "SocketReplica", "TcpReplica",
    "Fleet", "launch_fleet", "spawn_worker",
    "Connection", "Listener", "TransportError", "dial", "parse_addr",
    "SamplingParams", "sample_token",
    "FCFSScheduler", "Request",
    "SlotPool", "write_slot",
    "poisson_arrival_times", "synthetic_requests",
]
