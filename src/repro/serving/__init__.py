"""Multi-replica continuous-batching serving subsystem (the data plane).

Layout:
  sampling.py   — per-request sampling params + host-side token sampler
  scheduler.py  — Request lifecycle + FCFS admission queue
  slots.py      — generic KV slot pool over any family's cache pytree
  engine.py     — single-replica engine: chunked prefill streamed through the
                  batched decode tick, per-slot ring positions
  router.py     — N engines, least-loaded routing, scale up/down mid-run,
                  ReplicaReport stream for core/monitoring
  workload.py   — synthetic request generation (shares sim.WorkloadSpec)
  closed_loop.py— the full control loop (router + collector + allocator),
                  shared by examples/serve_autoscale.py and the serving
                  latency benchmark's --engine mode

The `core/` control plane (scaler + allocator) drives ReplicaRouter.scale_to;
examples/serve_autoscale.py closes the loop end to end on CPU.
"""
from repro.serving.engine import EngineCore, ServingEngine
from repro.serving.router import ReplicaRouter
from repro.serving.sampling import SamplingParams, sample_token
from repro.serving.scheduler import FCFSScheduler, Request
from repro.serving.slots import SlotPool, write_slot
from repro.serving.workload import poisson_arrival_times, synthetic_requests

__all__ = [
    "EngineCore", "ServingEngine", "ReplicaRouter",
    "SamplingParams", "sample_token",
    "FCFSScheduler", "Request",
    "SlotPool", "write_slot",
    "poisson_arrival_times", "synthetic_requests",
]
