"""Model-free draft proposals for speculative decoding: prompt lookup.

The draft model here is the request's own history.  LLM output — especially
on retrieval, summarization, and code workloads — re-quotes long spans of
its prompt and of its own earlier output, so the last ``n`` generated tokens
very often continue exactly the way they continued the *previous* time that
n-gram appeared.  ``ngram_propose`` finds the most recent earlier occurrence
of the current n-gram suffix in the slot's prompt+generated history and
proposes the tokens that followed it, up to ``k``.

This is the zero-parameter end of the draft-model spectrum (no second
network, no extra HBM, no draft/target skew to manage): proposals are free
on the host, and the target model's verify step is what decides — a wrong
draft costs one wasted lane in a batched decode, never a wrong token.  The
acceptance rate it achieves is therefore purely a *workload* property,
which is exactly why the engine reports it upstream as a metric stream.

Matching is longest-suffix-first: an order-``n`` match is more specific
than an order-1 match, so its continuation is more likely to verify.  The
scan runs right-to-left so the *most recent* occurrence wins — recency
tracks local context (the same n-gram earlier in a long document may have
continued differently).
"""
from __future__ import annotations

import numpy as np


def ngram_propose(history: np.ndarray, *, k: int, ngram: int = 3
                  ) -> np.ndarray:
    """Propose up to ``k`` draft tokens continuing ``history``.

    history: 1-D int token ids (array or list) — the slot's prompt followed
    by everything it has generated so far (the last entry is the newest
    token).  Returns a (m,) int32 array, 0 <= m <= k; empty when no earlier
    occurrence of any suffix n-gram exists (e.g. all-unique prompts) or
    k <= 0.

    The scan runs on plain python ints: it executes on the host once per
    decode slot per verify tick, over histories of at most max_seq tokens,
    where list-slice comparisons are an order of magnitude cheaper than
    per-candidate numpy dispatch — this is engine tick-path code, and draft
    cost eats directly into the speculation speedup.
    """
    h = history if isinstance(history, list) \
        else np.asarray(history).ravel().tolist()
    T = len(h)
    if k <= 0 or T < 2:
        return np.zeros(0, np.int32)
    for n in range(min(ngram, T - 1), 0, -1):
        tail = h[T - n:]
        # candidate match starts: windows h[i:i+n] with i+n < T (the window
        # must END strictly before the suffix itself so there is at least
        # one following token to propose); scan newest-first.  Prefer the
        # newest match with a FULL k-token follow: when generation settles
        # into a cycle shorter than k, the very newest match sits so close
        # to the end that its follow is truncated to a token or two, while
        # one cycle earlier the same continuation is available at full
        # length — a short draft there wastes verify lanes for no accuracy
        # gain.  The newest (possibly truncated) match is the fallback.
        fallback = -1
        for i in range(T - n - 1, -1, -1):
            if h[i:i + n] == tail:
                if i + n + k <= T:
                    return np.asarray(h[i + n: i + n + k], np.int32)
                if fallback < 0:
                    fallback = i
        if fallback >= 0:
            follow = h[fallback + n: fallback + n + k]
            if follow:
                return np.asarray(follow, np.int32)
    return np.zeros(0, np.int32)
