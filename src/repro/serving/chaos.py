"""Fault injection for the replica-fabric transport.

The fabric's failure contract is binary: a fault either (a) is absorbed by
the framing (splits, delays — partial reads are the common case, not an
error) leaving the topology observationally identical, or (b) surfaces as a
typed ``TransportError`` → the router reaps the replica and requeues its
work.  NEVER a hang, never a stranded request, never a silently-wrong
reply.  This module is the adversary that pins that contract:

* ``FaultPlan``      — a declarative per-direction fault script: split
                       writes into N-byte pieces, delay each piece, sever
                       the connection mid-way through a chosen frame,
                       duplicate a chosen frame, or corrupt one byte.
* ``ChaosProxy``     — a byte-level TCP proxy between a dialing stub and a
                       real worker; each direction applies its own plan.
                       Frame-indexed faults (sever-in / duplicate) parse
                       the length-prefix stream so tests can say "cut the
                       SECOND reply in half" deterministically.
* ``FaultyConnection`` — a Connection whose ``send`` applies a plan
                       directly (no proxy) for endpoint-level unit tests.
* ``DelayedReplica``  — deterministic transport latency on the VIRTUAL
                       clock: a Replica-protocol shim that holds each
                       submitted request for ``rtt_ms`` of virtual time
                       before delivering it.  This is how a FleetPlan's
                       inter-region RTT matrix reaches the fabric — the
                       same shim on every topology (no wall-clock sleeps,
                       so inproc fleets stay fast and runs stay
                       reproducible), surfacing through ``transport_ms``
                       like a real remote link.

Lives in src (not tests/) because the benchmark and any future soak driver
inject faults through the same shim the test suite does.
"""
from __future__ import annotations

import dataclasses
import socket
import threading
import time

from repro.serving.transport import (
    _LEN,
    Connection,
    Listener,
    TransportError,
    pack_frame,
)


@dataclasses.dataclass
class FaultPlan:
    """One direction's fault script.  Defaults are a clean passthrough."""

    chunk_bytes: int | None = None      # split writes into ≤ this many bytes
    delay_s: float = 0.0                # sleep before each forwarded piece
    sever_in_frame: int | None = None   # 1-based: send HALF this frame, cut
    duplicate_frame: int | None = None  # 1-based: forward this frame twice
    corrupt_in_frame: int | None = None  # 1-based: flip a payload byte

    @property
    def framed(self) -> bool:
        """Frame-indexed faults need the length-prefix parse."""
        return (self.sever_in_frame is not None
                or self.duplicate_frame is not None
                or self.corrupt_in_frame is not None)


class _Severed(Exception):
    """Internal: the plan cut the connection."""


def _chunked_write(sendall, data: bytes, plan: FaultPlan):
    step = plan.chunk_bytes or len(data) or 1
    for lo in range(0, len(data), step):
        if plan.delay_s:
            time.sleep(plan.delay_s)
        sendall(data[lo:lo + step])


def _emit_frame_with_faults(sendall, frame: bytes, frame_no: int,
                            plan: FaultPlan) -> bool:
    """Send one length-prefixed frame through the fault script; → True when
    the plan severed the stream (half the frame went out, the caller must
    close the channel).  The ONE implementation of sever/corrupt/duplicate
    semantics — the proxy pump and the endpoint shim must inject
    byte-identical faults or their tests silently diverge."""
    if plan.sever_in_frame == frame_no:
        _chunked_write(sendall, frame[:max(len(frame) // 2, 1)], plan)
        return True                        # peer sees EOF mid-frame
    if plan.corrupt_in_frame == frame_no and len(frame) > _LEN.size:
        body = bytearray(frame)
        body[_LEN.size] ^= 0xFF            # first payload byte → garbage
        frame = bytes(body)
    _chunked_write(sendall, frame, plan)
    if plan.duplicate_frame == frame_no:
        _chunked_write(sendall, frame, plan)   # the replayed frame
    return False


class _Pump:
    """One direction of the proxy: src socket → plan → dst socket."""

    def __init__(self, src: socket.socket, dst: socket.socket,
                 plan: FaultPlan, on_sever):
        self.src, self.dst, self.plan = src, dst, plan
        self.on_sever = on_sever
        self._buf = b""
        self._frame_no = 0
        self.thread = threading.Thread(target=self._run, daemon=True)

    def start(self):
        self.thread.start()

    def _emit_frame(self, frame: bytes):
        self._frame_no += 1
        if _emit_frame_with_faults(self.dst.sendall, frame, self._frame_no,
                                   self.plan):
            raise _Severed()

    def _run(self):
        try:
            while True:
                data = self.src.recv(65536)
                if not data:
                    raise _Severed()
                if not self.plan.framed:
                    _chunked_write(self.dst.sendall, data, self.plan)
                    continue
                self._buf += data
                while len(self._buf) >= _LEN.size:
                    (n,) = _LEN.unpack(self._buf[:_LEN.size])
                    if len(self._buf) < _LEN.size + n:
                        break
                    frame = self._buf[:_LEN.size + n]
                    self._buf = self._buf[_LEN.size + n:]
                    self._emit_frame(frame)
        except (_Severed, OSError):
            # a sever (scripted or natural EOF) kills BOTH directions: a
            # half-dead proxy would turn a clean fault into a hang
            self.on_sever()


class ChaosProxy:
    """A fault-injecting TCP proxy in front of one upstream worker.

    Dial ``proxy.addr`` instead of the worker's own address; bytes flow
    client ↔ proxy ↔ upstream with each direction's FaultPlan applied.
    One client connection at a time (the stub protocol is one connection
    per replica)."""

    def __init__(self, upstream: tuple[str, int], *,
                 c2s: FaultPlan | None = None,
                 s2c: FaultPlan | None = None,
                 host: str = "127.0.0.1"):
        self.upstream = upstream
        self.c2s = c2s or FaultPlan()
        self.s2c = s2c or FaultPlan()
        self._listener = Listener(host, 0)
        self.addr = self._listener.addr
        self._lock = threading.Lock()
        self._socks: list[socket.socket] = []
        self._closed = False
        self._accept_thread = threading.Thread(target=self._accept_loop,
                                               daemon=True)
        self._accept_thread.start()

    def _accept_loop(self):
        while not self._closed:
            try:
                client = self._listener.accept(timeout=0.25).sock
            except TransportError:
                continue
            try:
                server = socket.create_connection(self.upstream, timeout=10)
            except OSError:
                client.close()
                continue
            with self._lock:
                self._socks = [client, server]

            def sever():
                self._kill_pair(client, server)

            _Pump(client, server, self.c2s, sever).start()
            _Pump(server, client, self.s2c, sever).start()

    def _kill_pair(self, *socks):
        for s in socks:
            try:
                s.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                s.close()
            except OSError:
                pass

    def close(self):
        self._closed = True
        self._listener.close()
        with self._lock:
            self._kill_pair(*self._socks)
        self._accept_thread.join(timeout=5)

    def __enter__(self) -> "ChaosProxy":
        return self

    def __exit__(self, *exc):
        self.close()


class DelayedReplica:
    """A Replica wrapper that injects a fixed transport RTT on the virtual
    clock: ``submit`` parks the request in an ingress queue stamped
    ``now + rtt_ms``, and each ``begin_step(now)`` delivers every request
    whose stamp has passed before stepping the inner replica.  The full
    round trip is charged on the ingress leg (arrival + return collapsed
    into one delay), so a completion's ``t_done - t_submit`` latency —
    measured engine-side, where the per-tier SLO channels sample — includes
    the RTT without any change to the engine or the wire.

    The delay also rides the metrics surface: ``transport_ms`` (the
    property and every report) reads inner + rtt, exactly as if the link
    were physically that far away — the scaler's transport budgeting sees
    injected geography and real socket latency through one channel.

    Everything else delegates: the wrapper is load/evacuation/failure
    transparent (ingress requests count toward load and queue depth, leave
    with ``evacuate()``/``lost_requests()`` exactly once, and are never
    delivered to a failed inner replica)."""

    def __init__(self, inner, *, rtt_ms: float):
        self.inner = inner
        self.rtt_ms = float(rtt_ms)
        self._ingress: list[tuple[float, object]] = []  # (deliver_at, req)
        self._slots = (getattr(inner, "slots", None)
                       or getattr(getattr(inner, "engine", None),
                                  "slots", None) or 1)

    # ------------------------------------------------------------- protocol

    def submit(self, request, now: float = 0.0):
        if self.inner.failed:
            # mirror the remote stub: touching a corpse raises so the
            # router's failover reroutes instead of stranding the request
            raise TransportError(
                f"replica {self.inner.replica_id} is lost")
        self._ingress.append((float(now) + self.rtt_ms / 1e3, request))

    def _deliver_due(self, now: float):
        due = [(d, r) for d, r in self._ingress if d <= now]
        if not due:
            return
        self._ingress = [(d, r) for d, r in self._ingress if d > now]
        for i, (d, r) in enumerate(due):
            try:
                self.inner.submit(r, now=now)
            except TransportError:
                # inner died mid-delivery: everything undelivered goes back
                # to ingress so lost_requests() can rewind it exactly once
                self._ingress.extend(due[i:])
                return

    def begin_step(self, now: float | None = None):
        t = float(now or 0.0)
        if not self.inner.failed:
            self._deliver_due(t)
        self.inner.begin_step(now)

    def finish_step(self):
        return self.inner.finish_step()

    def step(self, now: float | None = None):
        self.begin_step(now)
        return self.finish_step()

    def report(self, tick: int):
        rpt = self.inner.report(tick)
        rpt.transport_ms = float(rpt.transport_ms) + self.rtt_ms
        rpt.queue_depth = int(rpt.queue_depth) + len(self._ingress)
        return rpt

    def lifetime(self) -> dict:
        return self.inner.lifetime()

    def evacuate(self):
        mine = [r for _, r in self._ingress]
        self._ingress = []
        return mine + list(self.inner.evacuate())

    def resume(self):
        self.inner.resume()

    def gate_batch(self, on: bool):
        self.inner.gate_batch(on)

    def lost_requests(self):
        mine = [r for _, r in self._ingress]
        self._ingress = []
        return mine + list(self.inner.lost_requests())

    def close(self):
        self.inner.close()

    # ---------------------------------------------------------- properties

    @property
    def load(self) -> float:
        # in-flight-to-deliver work is still this replica's work: routing
        # must see it or it would pile submissions onto the longest queue
        return self.inner.load + len(self._ingress) / max(self._slots, 1)

    @property
    def idle(self) -> bool:
        return self.inner.idle and not self._ingress

    @property
    def queue_depth(self) -> int:
        return self.inner.queue_depth + len(self._ingress)

    @property
    def pending(self) -> int:
        return self.inner.pending + len(self._ingress)

    @property
    def draining(self) -> bool:
        return self.inner.draining

    @draining.setter
    def draining(self, value: bool):
        self.inner.draining = bool(value)

    @property
    def failed(self) -> bool:
        return self.inner.failed

    @failed.setter
    def failed(self, value: bool):
        # router.preempt flips this by fiat — it must reach the inner
        # replica or the reap path would see a healthy engine
        self.inner.failed = bool(value)

    @property
    def transport_ms(self) -> float:
        return self.inner.transport_ms + self.rtt_ms

    def __getattr__(self, name):
        # replica_id, rpc_count, slots, engine, … — everything the wrapper
        # doesn't shape passes straight through
        return getattr(self.inner, name)


class FaultyConnection(Connection):
    """A Connection whose ``send`` runs the fault script locally — for
    endpoint unit tests that don't want a proxy in the middle.  Frame
    indices count this connection's sends."""

    def __init__(self, sock: socket.socket, plan: FaultPlan, *,
                 timeout: float | None = None):
        super().__init__(sock, timeout=timeout)
        self.plan = plan
        self._frame_no = 0

    def send(self, obj):
        frame = pack_frame(obj)
        self._frame_no += 1
        try:
            severed = _emit_frame_with_faults(self.sock.sendall, frame,
                                              self._frame_no, self.plan)
        except OSError as e:
            raise TransportError(f"send failed: {e}") from e
        if severed:
            self.sock.close()
            raise TransportError("fault injection: severed mid-frame")
