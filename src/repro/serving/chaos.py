"""Fault injection for the replica-fabric transport.

The fabric's failure contract is binary: a fault either (a) is absorbed by
the framing (splits, delays — partial reads are the common case, not an
error) leaving the topology observationally identical, or (b) surfaces as a
typed ``TransportError`` → the router reaps the replica and requeues its
work.  NEVER a hang, never a stranded request, never a silently-wrong
reply.  This module is the adversary that pins that contract:

* ``FaultPlan``      — a declarative per-direction fault script: split
                       writes into N-byte pieces, delay each piece, sever
                       the connection mid-way through a chosen frame,
                       duplicate a chosen frame, or corrupt one byte.
* ``ChaosProxy``     — a byte-level TCP proxy between a dialing stub and a
                       real worker; each direction applies its own plan.
                       Frame-indexed faults (sever-in / duplicate) parse
                       the length-prefix stream so tests can say "cut the
                       SECOND reply in half" deterministically.
* ``FaultyConnection`` — a Connection whose ``send`` applies a plan
                       directly (no proxy) for endpoint-level unit tests.

Lives in src (not tests/) because the benchmark and any future soak driver
inject faults through the same shim the test suite does.
"""
from __future__ import annotations

import dataclasses
import socket
import threading
import time

from repro.serving.transport import (
    _LEN,
    Connection,
    Listener,
    TransportError,
    pack_frame,
)


@dataclasses.dataclass
class FaultPlan:
    """One direction's fault script.  Defaults are a clean passthrough."""

    chunk_bytes: int | None = None      # split writes into ≤ this many bytes
    delay_s: float = 0.0                # sleep before each forwarded piece
    sever_in_frame: int | None = None   # 1-based: send HALF this frame, cut
    duplicate_frame: int | None = None  # 1-based: forward this frame twice
    corrupt_in_frame: int | None = None  # 1-based: flip a payload byte

    @property
    def framed(self) -> bool:
        """Frame-indexed faults need the length-prefix parse."""
        return (self.sever_in_frame is not None
                or self.duplicate_frame is not None
                or self.corrupt_in_frame is not None)


class _Severed(Exception):
    """Internal: the plan cut the connection."""


def _chunked_write(sendall, data: bytes, plan: FaultPlan):
    step = plan.chunk_bytes or len(data) or 1
    for lo in range(0, len(data), step):
        if plan.delay_s:
            time.sleep(plan.delay_s)
        sendall(data[lo:lo + step])


def _emit_frame_with_faults(sendall, frame: bytes, frame_no: int,
                            plan: FaultPlan) -> bool:
    """Send one length-prefixed frame through the fault script; → True when
    the plan severed the stream (half the frame went out, the caller must
    close the channel).  The ONE implementation of sever/corrupt/duplicate
    semantics — the proxy pump and the endpoint shim must inject
    byte-identical faults or their tests silently diverge."""
    if plan.sever_in_frame == frame_no:
        _chunked_write(sendall, frame[:max(len(frame) // 2, 1)], plan)
        return True                        # peer sees EOF mid-frame
    if plan.corrupt_in_frame == frame_no and len(frame) > _LEN.size:
        body = bytearray(frame)
        body[_LEN.size] ^= 0xFF            # first payload byte → garbage
        frame = bytes(body)
    _chunked_write(sendall, frame, plan)
    if plan.duplicate_frame == frame_no:
        _chunked_write(sendall, frame, plan)   # the replayed frame
    return False


class _Pump:
    """One direction of the proxy: src socket → plan → dst socket."""

    def __init__(self, src: socket.socket, dst: socket.socket,
                 plan: FaultPlan, on_sever):
        self.src, self.dst, self.plan = src, dst, plan
        self.on_sever = on_sever
        self._buf = b""
        self._frame_no = 0
        self.thread = threading.Thread(target=self._run, daemon=True)

    def start(self):
        self.thread.start()

    def _emit_frame(self, frame: bytes):
        self._frame_no += 1
        if _emit_frame_with_faults(self.dst.sendall, frame, self._frame_no,
                                   self.plan):
            raise _Severed()

    def _run(self):
        try:
            while True:
                data = self.src.recv(65536)
                if not data:
                    raise _Severed()
                if not self.plan.framed:
                    _chunked_write(self.dst.sendall, data, self.plan)
                    continue
                self._buf += data
                while len(self._buf) >= _LEN.size:
                    (n,) = _LEN.unpack(self._buf[:_LEN.size])
                    if len(self._buf) < _LEN.size + n:
                        break
                    frame = self._buf[:_LEN.size + n]
                    self._buf = self._buf[_LEN.size + n:]
                    self._emit_frame(frame)
        except (_Severed, OSError):
            # a sever (scripted or natural EOF) kills BOTH directions: a
            # half-dead proxy would turn a clean fault into a hang
            self.on_sever()


class ChaosProxy:
    """A fault-injecting TCP proxy in front of one upstream worker.

    Dial ``proxy.addr`` instead of the worker's own address; bytes flow
    client ↔ proxy ↔ upstream with each direction's FaultPlan applied.
    One client connection at a time (the stub protocol is one connection
    per replica)."""

    def __init__(self, upstream: tuple[str, int], *,
                 c2s: FaultPlan | None = None,
                 s2c: FaultPlan | None = None,
                 host: str = "127.0.0.1"):
        self.upstream = upstream
        self.c2s = c2s or FaultPlan()
        self.s2c = s2c or FaultPlan()
        self._listener = Listener(host, 0)
        self.addr = self._listener.addr
        self._lock = threading.Lock()
        self._socks: list[socket.socket] = []
        self._closed = False
        self._accept_thread = threading.Thread(target=self._accept_loop,
                                               daemon=True)
        self._accept_thread.start()

    def _accept_loop(self):
        while not self._closed:
            try:
                client = self._listener.accept(timeout=0.25).sock
            except TransportError:
                continue
            try:
                server = socket.create_connection(self.upstream, timeout=10)
            except OSError:
                client.close()
                continue
            with self._lock:
                self._socks = [client, server]

            def sever():
                self._kill_pair(client, server)

            _Pump(client, server, self.c2s, sever).start()
            _Pump(server, client, self.s2c, sever).start()

    def _kill_pair(self, *socks):
        for s in socks:
            try:
                s.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                s.close()
            except OSError:
                pass

    def close(self):
        self._closed = True
        self._listener.close()
        with self._lock:
            self._kill_pair(*self._socks)
        self._accept_thread.join(timeout=5)

    def __enter__(self) -> "ChaosProxy":
        return self

    def __exit__(self, *exc):
        self.close()


class FaultyConnection(Connection):
    """A Connection whose ``send`` runs the fault script locally — for
    endpoint unit tests that don't want a proxy in the middle.  Frame
    indices count this connection's sends."""

    def __init__(self, sock: socket.socket, plan: FaultPlan, *,
                 timeout: float | None = None):
        super().__init__(sock, timeout=timeout)
        self.plan = plan
        self._frame_no = 0

    def send(self, obj):
        frame = pack_frame(obj)
        self._frame_no += 1
        try:
            severed = _emit_frame_with_faults(self.sock.sendall, frame,
                                              self._frame_no, self.plan)
        except OSError as e:
            raise TransportError(f"send failed: {e}") from e
        if severed:
            self.sock.close()
            raise TransportError("fault injection: severed mid-frame")
