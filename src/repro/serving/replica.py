"""The Replica protocol: the router's only view of a serving engine.

The control plane assumes replicas spread across heterogeneous environments
whose operational metrics stream back into it — so the replica boundary must
be a *message protocol* (submit / step / report / scale hooks), never a
Python object reference.  ReplicaRouter is written purely against this
surface; everything engine-shaped lives behind one of three backends:

  InProcessReplica — today's ServingEngine wrapped 1:1 (zero transport).
  ShardedReplica   — ONE engine spanning a local device mesh: the decode
                     tick runs under ``repro.sharding.shard_map`` with the
                     slot/batch axis sharded over the mesh's "data" axis, so
                     a single replica's S slots are served by N devices.
                     Prefill stays replicated (batch-1); only the per-tick
                     batched decode is sharded — that is the hot path.
  ProcessReplica   — the engine lives in a worker subprocess and is driven
                     over the length-prefixed JSON transport
                     (serving/transport.py + serving/worker.py).  Reports
                     stream back as wire messages and are materialized into
                     the same ReplicaReport the collector already consumes;
                     the parent-side stub measures per-call transport
                     latency (EWMA) and stamps it on every report.
  TcpReplica       — the same stub over a TCP connection: the worker is a
                     remote pod (``python -m repro.serving.worker --listen
                     host:port``) the router ATTACHES to rather than forks,
                     with connect/handshake deadlines and keepalive.  When
                     no address is given the stub spawns a local TCP worker
                     (demos/CI) and owns its lifetime.
  DistributedPodReplica — TcpReplica against the HEAD of a multi-process
                     pod: N worker ranks (``--pod-rank/--pod-size``,
                     optionally a jax.distributed ``--coordinator``)
                     jointly back one replica the router addresses as a
                     single unit; rank 0 forwards mutating ops so the
                     ranks step in lockstep (digest-verified).

Attach handshake: a listening worker serves ONE mutating session plus any
number of read-only observers (serving/observe.py) concurrently, so an
external monitor can poll lifetime()/status() without stealing the
router's connection.  SocketReplica claims the mutating session with an
explicit ``attach`` before init; losing the race surfaces as a typed
WorkerBusyError, never a protocol desync.

Remote stubs share SocketReplica: a strict request/reply RPC stream where
every message carries a sequence number the reply must echo — a duplicated,
dropped, or reordered frame (fault injection, a broken proxy) surfaces as a
typed TransportError desync instead of silently mismatched replies.  Per-
tick submits are BATCHED into the step message (``batch_submits``, default
on): a decode round already costs the slowest worker, so the per-request
submit RPCs were the remaining transport term — one step RPC per round per
replica replaces 1 + len(submits) messages.

Protocol semantics the router relies on:

* ``step(now)`` returns the *caller's* completed Request objects (a remote
  backend merges wire results back into the originals), and never hangs —
  a dead peer flips ``failed`` and returns [].
* ``evacuate()`` empties the replica NOW: queued requests plus in-flight
  ones preempted and rewound (Request.reset_generation) — the router
  requeues them through surviving replicas' schedulers, so a downscale
  never strands a mid-generation request.
* ``report(tick)`` must keep flowing after park/evacuate (an explicit empty
  window zeroes the collector's last-report replay) and after failure (an
  ``n_errors > 0`` report is how a crash surfaces as a collector straggler).
* ``lost_requests()`` recovers the submitter-side copies of everything that
  was inside a failed replica.
"""
from __future__ import annotations

import socket
import subprocess
import sys
import time
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.monitoring.collector import ReplicaReport
from repro.serving.engine import EngineCore, ServingEngine, validate_request
from repro.serving.fleet import spawn_worker, worker_env
from repro.serving.scheduler import Request, validate_tier
from repro.serving.transport import (
    Connection,
    TransportError,
    WorkerBusyError,
    apply_request,
    dial,
    encode_config,
    encode_request,
    parse_addr,
)


@runtime_checkable
class Replica(Protocol):
    """What the router is allowed to know about a replica."""

    replica_id: int

    def submit(self, request: Request, now: float = 0.0) -> None: ...
    def step(self, now: float | None = None) -> list[Request]: ...
    # split-phase step: the router begins the round on EVERY replica before
    # collecting ANY result, so remote replicas decode concurrently (one
    # outstanding request per connection) instead of serializing the fleet's
    # decode round.  step(now) ≡ begin_step(now); finish_step().
    def begin_step(self, now: float | None = None) -> None: ...
    def finish_step(self) -> list[Request]: ...
    def report(self, tick: int) -> ReplicaReport: ...
    def lifetime(self) -> dict: ...
    def evacuate(self) -> list[Request]: ...
    def resume(self) -> None: ...
    # control-plane lane gate: while on, the engine admits no batch-tier
    # work (queued batch requests stay queued) — interactive SLO protection
    def gate_batch(self, on: bool) -> None: ...
    def lost_requests(self) -> list[Request]: ...
    def close(self) -> None: ...

    @property
    def load(self) -> float: ...
    @property
    def idle(self) -> bool: ...
    @property
    def queue_depth(self) -> int: ...
    @property
    def pending(self) -> int: ...
    @property
    def draining(self) -> bool: ...
    @property
    def failed(self) -> bool: ...
    @property
    def transport_ms(self) -> float: ...


def _report_from_window(replica_id: int, tick: int, w: dict, *,
                        n_errors: int = 0,
                        transport_ms: float = 0.0) -> ReplicaReport:
    return ReplicaReport(
        replica_id=replica_id, tick=tick,
        latency_ms_samples=w["latency_ms_samples"],
        n_requests=w["n_requests"], n_errors=n_errors,
        flop_util=w["slot_util"],
        hbm_util=w["slot_util"],          # CPU engine: slot occupancy
        ici_util=0.0,                     # stands in for chip signals
        mem_frac=w["slot_util"],
        queue_depth=w["queue_depth"],
        # .get: pre-speculation windows (the empty-window tombstone, a
        # worker running older code) simply report zero speculation
        spec_proposed=int(w.get("spec_proposed", 0)),
        spec_accepted=int(w.get("spec_accepted", 0)),
        # .get → None: pre-tier windows feed the untiered channels only
        lat_tiers=w.get("lat_tiers") or None,
        transport_ms=transport_ms)


_EMPTY_WINDOW = {"latency_ms_samples": [], "n_requests": 0, "n_tokens": 0,
                 "slot_util": 0.0, "queue_depth": 0}


def empty_report(replica_id: int, tick: int) -> ReplicaReport:
    """A clean idle-window report — the router's tombstone for retired
    replicas reuses the one report-shape definition instead of a by-hand
    field list."""
    return _report_from_window(replica_id, tick, dict(_EMPTY_WINDOW))


# ---------------------------------------------------------------------------
# in-process backend
# ---------------------------------------------------------------------------


class InProcessReplica:
    """The protocol over a same-process ServingEngine (zero transport)."""

    kind = "inproc"

    def __init__(self, engine: ServingEngine):
        self.engine = engine
        self.failed = False
        self._step_done: list[Request] = []

    @classmethod
    def build(cls, cfg, *, slots: int, max_seq: int, seed: int = 0,
              prefill_chunk: int | None = None,
              core: EngineCore | None = None,
              replica_id: int = 0, pool: str = "dense",
              block_size: int | None = None,
              num_blocks: int | None = None, spec_k: int = 0,
              spec_ngram: int = 3) -> "InProcessReplica":
        return cls(ServingEngine(cfg, slots=slots, max_seq=max_seq,
                                 seed=seed, prefill_chunk=prefill_chunk,
                                 core=core, replica_id=replica_id,
                                 pool=pool, block_size=block_size,
                                 num_blocks=num_blocks, spec_k=spec_k,
                                 spec_ngram=spec_ngram))

    # ------------------------------------------------------------- protocol

    @property
    def replica_id(self) -> int:
        return self.engine.replica_id

    def submit(self, request: Request, now: float = 0.0):
        self.engine.submit(request, now=now)

    def step(self, now: float | None = None) -> list[Request]:
        return self.engine.step(now=now)

    def begin_step(self, now: float | None = None):
        # in-process: nothing to overlap with — run the round eagerly.
        # EXTEND, don't replace: if the previous round's results were never
        # collected (the driver's collection loop raised mid-way), they are
        # still owed to the caller
        self._step_done.extend(self.engine.step(now=now))

    def finish_step(self) -> list[Request]:
        out, self._step_done = self._step_done, []
        return out

    def report(self, tick: int) -> ReplicaReport:
        return _report_from_window(self.replica_id, tick,
                                   self.engine.stats.drain_window())

    def lifetime(self) -> dict:
        return self.engine.lifetime()

    def evacuate(self) -> list[Request]:
        self.engine.draining = True
        return self.engine.evacuate()

    def resume(self):
        self.engine.draining = False

    def gate_batch(self, on: bool):
        self.engine.scheduler.batch_gated = bool(on)

    def lost_requests(self) -> list[Request]:
        return []                      # an in-process replica cannot crash

    def close(self):
        pass

    @property
    def load(self) -> float:
        return self.engine.load

    @property
    def idle(self) -> bool:
        return self.engine.idle

    @property
    def queue_depth(self) -> int:
        return self.engine.scheduler.depth

    @property
    def pending(self) -> int:
        """Queued + in-flight — everything inside this replica."""
        return self.engine.scheduler.depth + int(self.engine.active.sum())

    @property
    def draining(self) -> bool:
        return self.engine.draining

    @draining.setter
    def draining(self, value: bool):
        self.engine.draining = bool(value)

    @property
    def transport_ms(self) -> float:
        return 0.0


# ---------------------------------------------------------------------------
# sharded backend: one replica spanning a local device mesh
# ---------------------------------------------------------------------------


def _axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None)))
                                        for a in x)


def make_sharded_decode(cfg, mesh, slots: int, max_seq: int, *,
                        pool: str = "dense", block_size: int | None = None,
                        num_blocks: int | None = None):
    """The engine decode step under shard_map: the slot/batch axis of the
    tokens, the cache, and the logits is sharded over EVERY axis of
    ``mesh``; params are replicated.  The body is collective-free (decode
    is purely batch-parallel), so each device serves slots/N rows of the
    same replica — on the classic single-host ("data",) mesh exactly as
    before, and on a pod mesh whose "model" axis spans processes
    (launch.mesh.make_pod_mesh) the pod's whole device set jointly serves
    one replica's slots.  Per-leaf specs are derived from the model's own
    cache_spec logical axes through SERVE_RULES (``pod_decode_rules``) —
    the same rules machinery the multi-host launcher shards by; the
    first-use-wins rule in ``spec_for`` keeps the body collective-free by
    construction (batch leads every decode-state leaf, so the base
    table's model-axis mappings are dropped per-leaf).  The pool's two
    vectorized leaves (per-slot "index" positions, per-slot "cross_len")
    are pinned to the slot axis, which cache_spec declares scalar/batch."""
    import jax

    from repro.models import LM
    from repro.models.steps import cache_axes
    from repro.sharding import pod_decode_rules, shard_map, spec_for

    rules = pod_decode_rules(mesh)
    if pool == "paged":
        # the paged pool swaps the per-slot cache_seq axis for a pooled
        # cache_blocks axis (+ the block table itself); its spec carries
        # the logical axes, so derive per-leaf specs from it.  Geometry
        # defaults resolve through the same helper the engine's pool uses,
        # with partitions = mesh size — the spec and the pool must agree.
        from repro.serving.slots import paged_cache_spec, pool_geometry

        def _spec_leaf(x):
            return (isinstance(x, tuple) and len(x) == 3
                    and isinstance(x[0], tuple))

        bk, nb = pool_geometry(slots, max_seq, block_size=block_size,
                               num_blocks=num_blocks,
                               partitions=int(mesh.devices.size))
        spec = paged_cache_spec(cfg, slots, max_seq, block_size=bk,
                                num_blocks=nb)
        axes = jax.tree.map(lambda leaf: leaf[2], spec, is_leaf=_spec_leaf)
    else:
        axes = cache_axes(cfg, slots, max_seq)
    cache_specs = jax.tree.map(lambda ax: spec_for(ax, rules, mesh), axes,
                               is_leaf=_axes_leaf)
    cache_specs["index"] = spec_for(("batch",), rules, mesh)
    if "cross_len" in cache_specs:
        cache_specs["cross_len"] = spec_for(("batch",), rules, mesh)
    tok_spec = spec_for(("batch", "seq"), rules, mesh)
    logit_spec = spec_for(("batch", "seq", "vocab"), rules, mesh)
    param_spec = spec_for((), rules, mesh)          # replicated

    def local_decode(params, tokens, cache):
        return LM.decode(params, tokens, cfg, cache)

    f = shard_map(local_decode, mesh=mesh,
                  in_specs=(param_spec, tok_spec, cache_specs),
                  out_specs=(logit_spec, cache_specs),
                  check_vma=False)
    return jax.jit(f, donate_argnums=(2,))


class ShardedReplica(InProcessReplica):
    """One engine spanning a device mesh: S slots / N devices.  Any mesh
    works — the classic local ("data",) axis, or a pod mesh whose "model"
    axis spans processes (launch.mesh.make_pod_mesh) on backends that can
    place one program across hosts."""

    kind = "sharded"

    def __init__(self, cfg, *, slots: int, max_seq: int, mesh=None,
                 seed: int = 0, prefill_chunk: int | None = None,
                 core: EngineCore | None = None, replica_id: int = 0,
                 decode_fn=None, pool: str = "dense",
                 block_size: int | None = None,
                 num_blocks: int | None = None, spec_k: int = 0,
                 spec_ngram: int = 3):
        if mesh is None:
            import jax

            from repro.launch.mesh import make_mesh
            mesh = make_mesh((len(jax.devices()),), ("data",))
        n_dev = int(mesh.devices.size)
        if slots % n_dev != 0:
            raise ValueError(f"slots ({slots}) must divide evenly over the "
                             f"mesh ({n_dev} devices)")
        # paged allocator partitions track the mesh: slot s draws blocks
        # only from its own shard's contiguous block range, so the sharded
        # decode body's global→local block-id fold stays exact
        # spec knobs are accepted but inert here: replacing engine.decode
        # below routes every tick down the legacy bulk-pull path (the
        # sharded step is compiled for (slots, 1) decode only)
        engine = ServingEngine(cfg, slots=slots, max_seq=max_seq, seed=seed,
                               prefill_chunk=prefill_chunk, core=core,
                               replica_id=replica_id, pool=pool,
                               block_size=block_size, num_blocks=num_blocks,
                               partitions=n_dev, spec_k=spec_k,
                               spec_ngram=spec_ngram)
        engine.decode = (decode_fn if decode_fn is not None
                         else make_sharded_decode(cfg, mesh, slots, max_seq,
                                                  pool=pool,
                                                  block_size=block_size,
                                                  num_blocks=num_blocks))
        super().__init__(engine)
        self.mesh = mesh


# ---------------------------------------------------------------------------
# remote backends: the engine behind a socket (subprocess pipe or TCP)
# ---------------------------------------------------------------------------


class SocketReplica:
    """Parent-side stub driving a remote engine over the framed JSON
    transport.  The stub tracks every in-system request so (a) routing
    load is computed locally without an RPC per submit, and (b) a worker
    crash loses no submitter state — ``lost_requests()`` rewinds and
    returns the originals for requeue.

    The RPC stream is strict request/reply with per-message sequence
    numbers: the reply must echo the request's ``seq``, so a duplicated or
    dropped frame anywhere on the path surfaces as a TransportError desync
    (→ the router reaps the replica) instead of every later reply landing
    on the wrong call.  With ``batch_submits`` (default), submits buffer
    parent-side and ride the next step message — one RPC per decode round
    per replica; a malformed request still bounces at submit because the
    stub runs the engine's own ``validate_request`` locally.

    Subclasses own transport establishment: ProcessReplica forks a worker
    over a socketpair, TcpReplica dials a listening worker (and optionally
    owns a locally-spawned one).  ``proc`` is the owned worker process, if
    any — its exit is probed at submit so a silently-dead local worker
    fails over immediately rather than a round later."""

    kind = "socket"

    def __init__(self, cfg, conn: Connection, *, slots: int, max_seq: int,
                 seed: int = 0, prefill_chunk: int | None = None,
                 replica_id: int = 0, proc: subprocess.Popen | None = None,
                 rpc_timeout_s: float = 120.0,
                 init_timeout_s: float = 600.0,
                 batch_submits: bool = True, pool: str = "dense",
                 block_size: int | None = None,
                 num_blocks: int | None = None, spec_k: int = 0,
                 spec_ngram: int = 3):
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.replica_id = replica_id
        self.failed = False
        self._closed = False
        self.batch_submits = batch_submits
        self._draining = False
        self.transport_ms = 0.0
        self.rpc_count = 0                # frames sent (the batching metric)
        self._seq = 0
        self._requests: dict[int, Request] = {}   # rid → submitter's object
        self._outbox: list[dict] = []     # encoded submits awaiting a step
        self._queue_depth = 0
        self._active = 0
        self._step_pending = False
        self._step_seq = -1
        self._stepped_once = False
        self._late: list[Request] = []    # completions drained out-of-band
        self._rpc_timeout_s = rpc_timeout_s
        self._init_timeout_s = init_timeout_s
        self._batch_gated = False
        self._gate_dirty = False          # gate change awaiting a step msg
        self._lifetime_cache = {
            "latencies_ms": [], "total_tokens": 0, "total_completed": 0,
            "completed_interactive": 0, "completed_batch": 0,
            "total_ticks": 0, "slot_utilization": 0.0, "queue_depth": 0}
        self._conn = conn
        self._proc = proc
        # two-step handshake: claim the worker's single mutating session
        # (a second router racing us bounces typed as WorkerBusyError —
        # observers attach read-only and are never in contention), then
        # have the worker build the identical engine from the wire
        # (imports jax + jits lazily — give it a generous first deadline)
        self._rpc({"op": "attach", "mode": "mutate"})
        self._rpc({"op": "init", "cfg": encode_config(cfg), "slots": slots,
                   "max_seq": max_seq, "seed": seed,
                   "prefill_chunk": prefill_chunk,
                   "replica_id": replica_id, "pool": pool,
                   "block_size": block_size, "num_blocks": num_blocks,
                   "spec_k": spec_k, "spec_ngram": spec_ngram},
                  timeout=init_timeout_s)

    # ------------------------------------------------------------- plumbing

    # ops whose worker-side cost is negligible: their round trip IS the
    # transport.  step/init RPCs contain real compute (jit, decode work) —
    # folding those in would report model time as fabric overhead.
    _TRANSPORT_OPS = frozenset({"ping", "report", "lifetime", "resume"})

    def _send(self, msg: dict) -> int:
        """Stamp the next sequence number and put one frame on the wire."""
        seq, self._seq = self._seq, self._seq + 1
        msg["seq"] = seq
        self.rpc_count += 1
        self._conn.send(msg)
        return seq

    def _recv_reply(self, seq: int) -> dict:
        reply = self._conn.recv()
        if reply.get("seq") != seq:
            raise TransportError(
                f"replica {self.replica_id} protocol desync: expected reply "
                f"seq {seq}, got {reply.get('seq')!r} (duplicated, dropped, "
                f"or reordered frame)")
        return reply

    def _rpc(self, msg: dict, *, timeout: float | None = None) -> dict:
        if self._closed:
            # a retired replica still answers lifetime() from its mirror —
            # the raise must be typed, not an EBADF from the dead socket
            raise TransportError(f"replica {self.replica_id} is closed")
        if self.failed:
            raise TransportError(f"replica {self.replica_id} is lost")
        if self._step_pending:
            # an unread step reply from an abandoned round: drain it first —
            # otherwise THIS op's recv would read the stale step reply and
            # every later RPC on the connection would be off by one
            self._late.extend(self.finish_step())
            if self.failed:
                raise TransportError(f"replica {self.replica_id} is lost")
        self._conn.sock.settimeout(timeout if timeout is not None
                                   else self._rpc_timeout_s)
        t0 = time.perf_counter()
        try:
            seq = self._send(msg)
            reply = self._recv_reply(seq)
        except TransportError:
            self._mark_failed()
            raise
        if msg["op"] in self._TRANSPORT_OPS:
            dt_ms = (time.perf_counter() - t0) * 1e3
            self.transport_ms = (dt_ms if self.transport_ms == 0.0
                                 else 0.8 * self.transport_ms + 0.2 * dt_ms)
        if "error" in reply:
            if reply.get("etype") == "ValueError":
                raise ValueError(reply["error"])
            if reply.get("etype") == "WorkerBusyError":
                # the worker's mutating session belongs to someone else —
                # this stub never owned the peer, so fail typed and final
                self._mark_failed()
                raise WorkerBusyError(
                    f"replica {self.replica_id}: {reply['error']}")
            if reply.get("etype") == "PodDesyncError":
                # a pod whose ranks diverged retires as a unit — same
                # router-side surface as a lost rank (reap + requeue),
                # NEVER a driver-crashing engine error
                self._mark_failed()
                raise TransportError(
                    f"replica {self.replica_id} pod desync: "
                    f"{reply['error']}")
            raise RuntimeError(
                f"worker {self.replica_id}: {reply['error']}\n"
                f"{reply.get('trace', '')}")
        return reply

    def _mark_failed(self):
        self.failed = True
        self._step_pending = False
        self._conn.close()
        if self._proc is not None:
            if self._proc.poll() is None:
                self._proc.kill()
            try:
                self._proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                # un-reaped zombie; do not let the reap race replace the
                # TransportError the caller's failover path is matching on
                pass

    # ------------------------------------------------------------- protocol

    def submit(self, request: Request, now: float = 0.0):
        if self.failed:
            raise TransportError(f"replica {self.replica_id} is lost")
        if self._proc is not None and self._proc.poll() is not None:
            # owned worker died between steps: one cheap probe turns a
            # doomed buffered submit into an immediate router failover
            self._mark_failed()
            raise TransportError(
                f"replica {self.replica_id} worker exited "
                f"(rc={self._proc.returncode})")
        if self.batch_submits:
            # the submit rides the NEXT step message (one RPC per round,
            # not per request); the engine's own validation runs locally so
            # a malformed request still bounces at the submit call
            validate_tier(request.tier)
            validate_request(self.cfg, self.max_seq,
                             np.asarray(request.prompt).reshape(-1),
                             frames=request.frames)
            self._outbox.append({"request": encode_request(request),
                                 "now": now})
        else:
            self._rpc({"op": "submit", "request": encode_request(request),
                       "now": now})
        if request.t_submit is None:      # mirror the worker-side stamp
            request.t_submit = now
        self._requests[request.rid] = request

    def step(self, now: float | None = None) -> list[Request]:
        self.begin_step(now)
        return self.finish_step()

    def begin_step(self, now: float | None = None):
        """Fire the step message without waiting for the reply — the router
        begins the round on every replica first, so N workers decode
        concurrently and the fleet's round costs max(worker time), not the
        sum.  Buffered submits flush inside this one message."""
        if self._step_pending:
            # an unread reply from an abandoned round (the driver caught an
            # error mid-collection): drain it — dropping it would desync the
            # strict request/reply stream, and its completions are real
            self._late.extend(self.finish_step())
        if self.failed:
            return
        msg: dict = {"op": "step", "now": now}
        if self._gate_dirty:
            # the gate rides the step message like batched submits do: one
            # RPC per round, applied worker-side before this round admits
            msg["batch_gate"] = self._batch_gated
            self._gate_dirty = False
        if self._outbox:
            msg["submits"], self._outbox = self._outbox, []
        # jax.jit is lazy: the worker's prefill/decode COMPILE inside its
        # first step, not inside init — the first round gets the init
        # deadline, every later round the (much tighter) RPC one
        self._conn.sock.settimeout(self._rpc_timeout_s if self._stepped_once
                                   else self._init_timeout_s)
        try:
            self._step_seq = self._send(msg)
            self._step_pending = True
        except TransportError:
            self._mark_failed()

    def finish_step(self) -> list[Request]:
        out, self._late = self._late, []
        if not self._step_pending:
            return out
        self._step_pending = False
        try:
            reply = self._recv_reply(self._step_seq)
        except TransportError:
            self._mark_failed()
            return out
        if reply.get("etype") == "PodDesyncError":
            # the pod's ranks split mid-step: it is dead as a unit — flip
            # failed so the router's normal reap path (evict + requeue via
            # lost_requests) handles it like any other lost replica
            self._mark_failed()
            return out
        if "error" in reply:           # engine bug, not a transport failure
            raise RuntimeError(
                f"worker {self.replica_id}: {reply['error']}\n"
                f"{reply.get('trace', '')}")
        self._stepped_once = True
        self._queue_depth = int(reply["queue_depth"])
        self._active = int(reply["active"])
        fresh = []
        for d in reply["completed"]:
            orig = self._requests.pop(int(d["rid"]), None)
            if orig is not None:
                fresh.append(apply_request(orig, d))
            # an untracked rid cannot reach a submitter anyway (nothing was
            # recorded parent-side) — completions are slim records, so there
            # is no request to reconstruct; drop it
        self._mirror_lifetime(fresh, reply)   # ONLY this reply's — drained
        errs = reply.get("submit_errors")     # _late ones were mirrored then
        if errs:
            # defense in depth: the stub validated these locally, so a
            # worker-side rejection means the two sides disagree — drop the
            # rejected requests from tracking (they are not on the worker)
            # and surface the bug; completions already in hand are parked
            # for redelivery, not lost
            for e in errs:
                orig = self._requests.pop(int(e["rid"]), None)
                if orig is not None:
                    orig.reset_generation()
            self._late = out + fresh
            raise RuntimeError(
                f"worker {self.replica_id} rejected {len(errs)} batched "
                f"submit(s): {errs}")
        return out + fresh

    def _mirror_lifetime(self, completed: list[Request], reply: dict):
        """Keep a parent-side running copy of the worker's lifetime stats —
        every completion flows through this stub, so the mirror equals the
        worker's own accumulators.  A crash must not erase served work from
        fleet metrics; the authoritative 'lifetime' RPC simply replaces the
        mirror when the worker is reachable."""
        lc = self._lifetime_cache
        lc["total_ticks"] = lc.get("total_ticks", 0) + 1
        for r in completed:
            lc["total_completed"] += 1
            key = f"completed_{getattr(r, 'tier', 'interactive')}"
            lc[key] = lc.get(key, 0) + 1
            lc["total_tokens"] += len(r.tokens_out)
            if r.latency_s is not None:
                lc["latencies_ms"].append(r.latency_s * 1e3)
        if "slot_utilization" in reply:
            lc["slot_utilization"] = float(reply["slot_utilization"])
        lc["queue_depth"] = self._queue_depth

    def report(self, tick: int) -> ReplicaReport:
        if not self.failed:
            try:
                w = self._rpc({"op": "report"})["window"]
                return _report_from_window(self.replica_id, tick, w,
                                           transport_ms=self.transport_ms)
            except TransportError:
                pass
        # the crash report: no samples, one error — the collector marks the
        # replica a straggler off this instead of replaying its last window
        return _report_from_window(
            self.replica_id, tick, dict(_EMPTY_WINDOW,
                                        queue_depth=len(self._requests)),
            n_errors=1, transport_ms=self.transport_ms)

    def lifetime(self) -> dict:
        if not self.failed:
            try:
                self._lifetime_cache = self._rpc({"op": "lifetime"})["lifetime"]
            except TransportError:
                pass
        out = dict(self._lifetime_cache)
        # snapshot the nested list too: _mirror_lifetime appends to the
        # cache in place, and a shallow copy would retroactively mutate
        # every lifetime() result already handed to a caller
        out["latencies_ms"] = list(out.get("latencies_ms", ()))
        return out

    def evacuate(self) -> list[Request]:
        self._draining = True
        # buffered submits never reached the worker — recover them locally
        # (the evacuate RPC can only return what the worker has)
        local: list[Request] = []
        outbox, self._outbox = self._outbox, []
        for d in outbox:
            orig = self._requests.pop(int(d["request"]["rid"]), None)
            if orig is not None:
                orig.reset_generation()
                local.append(orig)
        if self.failed:
            return local + self.lost_requests()
        try:
            reply = self._rpc({"op": "evacuate"})
        except TransportError:
            return local + self.lost_requests()
        for rid in reply["rids"]:
            orig = self._requests.pop(int(rid), None)
            if orig is None:
                continue
            orig.reset_generation()
            local.append(orig)
        return local

    def resume(self):
        self._draining = False
        if not self.failed:
            try:
                self._rpc({"op": "resume"})
            except TransportError:
                pass

    def gate_batch(self, on: bool):
        on = bool(on)
        if on != self._batch_gated:
            self._batch_gated = on
            self._gate_dirty = True

    def lost_requests(self) -> list[Request]:
        self._outbox.clear()           # their originals are in _requests too
        out = []
        for req in self._requests.values():
            req.reset_generation()
            out.append(req)
        self._requests.clear()
        return out

    def close(self):
        if self._closed:
            return
        self._closed = True
        if not self.failed and self._proc is not None:
            # the stub owns the worker's lifetime → ask it to exit.  An
            # ATTACHED worker (proc is None) is somebody else's pod: just
            # drop the connection — it returns to accept for the next
            # router (a detach must not shut the pod down).
            try:
                self._conn.sock.settimeout(5.0)
                self._send({"op": "shutdown"})
                self._conn.recv()
            except (TransportError, OSError):
                pass
        self._conn.close()
        if self._proc is not None and self._proc.poll() is None:
            self._proc.terminate()
            try:
                self._proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                self._proc.kill()
                self._proc.wait(timeout=10)

    def __del__(self):
        try:
            proc = getattr(self, "_proc", None)
            if proc is not None and proc.poll() is None:
                proc.kill()
        except Exception:
            pass

    # ---------------------------------------------------------- properties

    @property
    def load(self) -> float:
        """In-system work over slot capacity.  len(_requests) is exactly the
        engine's (active + queued + about-to-be-queued) at every quiescent
        point — submissions and completions both pass through this stub —
        so routing behaves bit-identically to the in-process backend."""
        return len(self._requests) / max(self.slots, 1)

    @property
    def idle(self) -> bool:
        return not self._requests

    @property
    def queue_depth(self) -> int:
        return max(len(self._requests) - self._active, 0)

    @property
    def pending(self) -> int:
        return len(self._requests)

    @property
    def draining(self) -> bool:
        return self._draining

    @draining.setter
    def draining(self, value: bool):
        self._draining = bool(value)


class ProcessReplica(SocketReplica):
    """SocketReplica over a forked worker subprocess (single-host): the
    transport is an inherited socketpair, so there is no listen/dial step
    and the worker's lifetime is owned by the stub."""

    kind = "proc"

    def __init__(self, cfg, *, slots: int, max_seq: int, seed: int = 0,
                 prefill_chunk: int | None = None, replica_id: int = 0,
                 rpc_timeout_s: float = 120.0,
                 init_timeout_s: float = 600.0,
                 batch_submits: bool = True, pool: str = "dense",
                 block_size: int | None = None,
                 num_blocks: int | None = None, spec_k: int = 0,
                 spec_ngram: int = 3):
        parent_sock, child_sock = socket.socketpair()
        child_sock.set_inheritable(True)
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.serving.worker",
             str(child_sock.fileno())],
            pass_fds=(child_sock.fileno(),), env=worker_env(), close_fds=True)
        child_sock.close()
        super().__init__(cfg, Connection(parent_sock, timeout=rpc_timeout_s),
                         slots=slots, max_seq=max_seq, seed=seed,
                         prefill_chunk=prefill_chunk, replica_id=replica_id,
                         proc=proc, rpc_timeout_s=rpc_timeout_s,
                         init_timeout_s=init_timeout_s,
                         batch_submits=batch_submits, pool=pool,
                         block_size=block_size, num_blocks=num_blocks,
                         spec_k=spec_k, spec_ngram=spec_ngram)


class TcpReplica(SocketReplica):
    """SocketReplica over TCP: the worker is a listening pod the router
    ATTACHES to (``addr``), possibly on another host — or, when no address
    is given, a local worker spawned on a kernel-picked port (demos/CI;
    the stub then owns the worker process).  Connect and init handshake
    each get their own deadline; the socket carries keepalive so a
    vanished peer surfaces as an error, never a wedged fleet."""

    kind = "tcp"

    def __init__(self, cfg, *, slots: int, max_seq: int,
                 addr: str | tuple[str, int] | None = None, seed: int = 0,
                 prefill_chunk: int | None = None, replica_id: int = 0,
                 rpc_timeout_s: float = 120.0,
                 init_timeout_s: float = 600.0,
                 connect_timeout_s: float = 10.0,
                 batch_submits: bool = True, pool: str = "dense",
                 block_size: int | None = None,
                 num_blocks: int | None = None, spec_k: int = 0,
                 spec_ngram: int = 3):
        proc = None
        if addr is None:
            addr, proc = spawn_worker()
        if isinstance(addr, str):
            addr = parse_addr(addr)
        self.addr = (addr[0], int(addr[1]))
        try:
            conn = dial(*self.addr, connect_timeout=connect_timeout_s,
                        timeout=rpc_timeout_s)
            super().__init__(cfg, conn, slots=slots, max_seq=max_seq,
                             seed=seed, prefill_chunk=prefill_chunk,
                             replica_id=replica_id, proc=proc,
                             rpc_timeout_s=rpc_timeout_s,
                             init_timeout_s=init_timeout_s,
                             batch_submits=batch_submits, pool=pool,
                             block_size=block_size, num_blocks=num_blocks,
                             spec_k=spec_k, spec_ngram=spec_ngram)
        except TransportError:
            # dial or handshake died before the stub owned the worker's
            # lifetime — do not leak a locally-spawned process
            if proc is not None and proc.poll() is None:
                proc.kill()
            raise


class DistributedPodReplica(TcpReplica):
    """A TcpReplica whose far side is a MULTI-PROCESS POD: ``pod_size``
    worker ranks (``worker.py --pod-rank R --pod-size N``) jointly backing
    one replica.  The router's view is unchanged — it dials rank 0 (the
    RPC head) and speaks the ordinary replica protocol; the head forwards
    every mutating op to the non-head ranks so the pod steps in lockstep,
    and cross-checks per-step digests (see worker.py "Pod execution").

    ``addr`` is the HEAD's address of a pod somebody else scheduled; with
    no address the stub launches a local pod (fleet.launch_pod — demos,
    CI, the 2-process equivalence tests) and owns every rank's lifetime:
    close() shuts the head down over the wire (which forwards the
    shutdown to the ranks) and then reaps all the rank processes."""

    kind = "pod"

    def __init__(self, cfg, *, slots: int, max_seq: int, pod_size: int = 2,
                 addr: str | tuple[str, int] | None = None, seed: int = 0,
                 prefill_chunk: int | None = None, replica_id: int = 0,
                 rpc_timeout_s: float = 120.0,
                 init_timeout_s: float = 600.0,
                 connect_timeout_s: float = 10.0,
                 batch_submits: bool = True, pool: str = "dense",
                 block_size: int | None = None,
                 num_blocks: int | None = None, spec_k: int = 0,
                 spec_ngram: int = 3):
        from repro.serving.fleet import launch_pod

        self.pod_size = int(pod_size)
        self._pod_handle = None
        if addr is None:
            self._pod_handle = launch_pod(self.pod_size, once=True)
            addr = self._pod_handle.head_addr
        try:
            super().__init__(cfg, slots=slots, max_seq=max_seq, addr=addr,
                             seed=seed, prefill_chunk=prefill_chunk,
                             replica_id=replica_id,
                             rpc_timeout_s=rpc_timeout_s,
                             init_timeout_s=init_timeout_s,
                             connect_timeout_s=connect_timeout_s,
                             batch_submits=batch_submits, pool=pool,
                             block_size=block_size, num_blocks=num_blocks,
                             spec_k=spec_k, spec_ngram=spec_ngram)
        except Exception:
            if self._pod_handle is not None:
                self._pod_handle.close()
            raise
        if self._pod_handle is not None:
            # the stub owns the whole pod's lifetime: the head process
            # carries the liveness probe + shutdown RPC (which it forwards
            # to the other ranks), close()/failure reaps everything
            self._proc = self._pod_handle.head_proc

    def close(self):
        super().close()
        if self._pod_handle is not None:
            self._pod_handle.close()

    def __del__(self):
        try:
            handle = getattr(self, "_pod_handle", None)
            if handle is not None:
                for proc in handle.procs:
                    if proc.poll() is None:
                        proc.kill()
        except Exception:
            pass
        super().__del__()


