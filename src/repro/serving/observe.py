"""Read-only observation of a live worker or pod — without its router.

A listening worker serves ONE mutating session (the router's SocketReplica)
plus any number of concurrent read-only sessions.  MetricsObserver is the
client side of the read-only kind: it dials a worker, attaches with
``mode="observe"``, and may then poll

  ping()      liveness round trip
  lifetime()  the engine's lifetime accumulators — the SAME counters the
              router's ``lifetime`` RPC reads, so an external monitor and
              the control plane can never disagree about served work
  status()    a non-draining snapshot: initialized / queue_depth / active /
              draining / lifetime, plus pod rank+mode for pod ranks

None of these drain the mutator's metric window (``report`` stays
mutator-only — an observer draining it would corrupt the control loop's
ReplicaReport stream), and the worker bounces any mutating op from an
observer with a typed PermissionError reply, so a misbehaving monitor
cannot perturb the serving session it is watching.

The observer speaks the same strict seq-echoed request/reply stream as the
router stub: a duplicated or dropped frame surfaces as a TransportError
desync, never as silently shifted replies.
"""
from __future__ import annotations

from repro.serving.transport import Connection, TransportError, dial, parse_addr


class MetricsObserver:
    """One read-only session on a listening worker (or a pod's head)."""

    def __init__(self, addr: str | tuple[str, int], *,
                 connect_timeout_s: float = 10.0,
                 rpc_timeout_s: float = 60.0):
        if isinstance(addr, str):
            addr = parse_addr(addr)
        self.addr = (addr[0], int(addr[1]))
        self._seq = 0
        self._conn: Connection | None = dial(
            *self.addr, connect_timeout=connect_timeout_s,
            timeout=rpc_timeout_s)
        self._rpc({"op": "attach", "mode": "observe"})

    def _rpc(self, msg: dict) -> dict:
        if self._conn is None:
            raise TransportError(f"observer on {self.addr} is closed")
        seq, self._seq = self._seq, self._seq + 1
        msg = dict(msg, seq=seq)
        try:
            self._conn.send(msg)
            reply = self._conn.recv()
        except TransportError:
            self.close()
            raise
        if reply.get("seq") != seq:
            self.close()
            raise TransportError(
                f"observer protocol desync: expected reply seq {seq}, "
                f"got {reply.get('seq')!r}")
        if "error" in reply:
            if reply.get("etype") == "PermissionError":
                raise PermissionError(reply["error"])
            raise RuntimeError(f"worker at {self.addr}: {reply['error']}")
        return reply

    # ------------------------------------------------------------- polls

    def ping(self) -> bool:
        return bool(self._rpc({"op": "ping"}).get("ok"))

    def lifetime(self) -> dict:
        return self._rpc({"op": "lifetime"})["lifetime"]

    def status(self) -> dict:
        reply = self._rpc({"op": "status"})
        reply.pop("seq", None)
        return reply

    def close(self):
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    def __enter__(self) -> "MetricsObserver":
        return self

    def __exit__(self, *exc):
        self.close()
