"""Local TCP worker fleets: spawn N listening workers for demos and CI.

In production a TcpReplica attaches to a worker pod somebody else scheduled
(k8s, a launcher) — the router never forks it.  For demos, CI, and the
cross-host tests, this module stands in for that scheduler: it spawns
``python -m repro.serving.worker --listen host:0`` subprocesses, reads the
kernel-picked port off each worker's banner line, and hands back dialable
addresses.  A Fleet outlives any one router (a router detaching leaves the
pod listening, unless the worker was started ``--once``), so the same
two-terminal flow in the README works in one process.
"""
from __future__ import annotations

import dataclasses
import os
import select
import subprocess
import sys
import time
from pathlib import Path

from repro.serving.transport import TransportError

BANNER = "WORKER_LISTENING"


def worker_env() -> dict:
    """The spawned worker must resolve ``repro`` exactly like this process
    (the repo is run from a source tree, not an installed wheel)."""
    env = os.environ.copy()
    src_root = str(Path(__file__).resolve().parents[2])
    env["PYTHONPATH"] = (src_root + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src_root)
    return env


def spawn_worker(host: str = "127.0.0.1", port: int = 0, *,
                 once: bool = True, start_timeout_s: float = 60.0,
                 ) -> tuple[tuple[str, int], subprocess.Popen]:
    """Spawn one listening TCP worker; → ((host, port), process).

    The worker prints ``WORKER_LISTENING host:port`` after binding (port 0
    → kernel-picked); we scan its stdout for the banner under a deadline so
    a worker that dies at import surfaces as a TransportError with its exit
    code, never a hang.  ``once`` ties the worker's lifetime to its first
    connection (right for stub-owned workers); pass ``once=False`` for a
    pod-like worker that keeps listening across router attach/detach."""
    cmd = [sys.executable, "-m", "repro.serving.worker",
           "--listen", f"{host}:{port}"]
    if once:
        cmd.append("--once")
    proc = subprocess.Popen(cmd, env=worker_env(), stdout=subprocess.PIPE,
                            text=True)
    deadline = time.monotonic() + start_timeout_s
    try:
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TransportError(
                    f"worker did not report a listen address within "
                    f"{start_timeout_s}s")
            ready, _, _ = select.select([proc.stdout], [], [], remaining)
            if not ready:
                continue
            line = proc.stdout.readline()
            if not line:                   # EOF: the worker died at startup
                raise TransportError(
                    f"worker exited before listening "
                    f"(rc={proc.wait(timeout=10)})")
            if line.startswith(BANNER):
                addr = line.split(None, 1)[1].strip()
                h, _, p = addr.rpartition(":")
                return (h, int(p)), proc
    except Exception:
        if proc.poll() is None:
            proc.kill()
        raise


@dataclasses.dataclass
class Fleet:
    """N spawned workers: the addresses a router attaches to, plus the
    process handles this stand-in scheduler owns."""

    workers: list[tuple[tuple[str, int], subprocess.Popen]]

    @property
    def addrs(self) -> list[tuple[str, int]]:
        return [addr for addr, _ in self.workers]

    def close(self):
        for _, proc in self.workers:
            if proc.poll() is None:
                proc.terminate()
        for _, proc in self.workers:
            try:
                proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait(timeout=10)

    def __enter__(self) -> "Fleet":
        return self

    def __exit__(self, *exc):
        self.close()


def launch_fleet(n: int, *, host: str = "127.0.0.1") -> Fleet:
    """Spawn ``n`` pod-like local TCP workers (``once=False`` — they keep
    listening across router attach/detach) and return their addresses."""
    workers = []
    try:
        for _ in range(n):
            workers.append(spawn_worker(host, once=False))
    except Exception:
        Fleet(workers).close()
        raise
    return Fleet(workers)
