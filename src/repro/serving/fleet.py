"""Local worker fleets and pods: spawn listening workers for demos and CI.

In production a TcpReplica attaches to a worker pod somebody else scheduled
(k8s, a launcher) — the router never forks it.  For demos, CI, and the
cross-host tests, this module stands in for that scheduler: it spawns
``python -m repro.serving.worker --listen host:0`` subprocesses, reads the
kernel-picked port off each worker's banner line, and hands back dialable
addresses.  A Fleet outlives any one router (a router detaching leaves the
pod listening, unless the worker was started ``--once``), so the same
two-terminal flow in the README works in one process.

``launch_pod`` stands in for a MULTI-HOST pod scheduler: it spawns
``pod_size`` ranks of one model-parallel pod (``--pod-rank/--pod-size``
plus a shared jax.distributed ``--coordinator``) on localhost — non-head
ranks first (they must be listening before the head can claim their
mutating sessions), then the head with ``--pod-peers`` pointing at them.
Only the HEAD's address is dialable by a router; the returned PodHandle
owns every rank process.
"""
from __future__ import annotations

import dataclasses
import os
import select
import socket
import subprocess
import sys
import time
from pathlib import Path
from typing import Sequence

from repro.serving.transport import TransportError

BANNER = "WORKER_LISTENING"


def worker_env() -> dict:
    """The spawned worker must resolve ``repro`` exactly like this process
    (the repo is run from a source tree, not an installed wheel)."""
    env = os.environ.copy()
    src_root = str(Path(__file__).resolve().parents[2])
    env["PYTHONPATH"] = (src_root + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src_root)
    return env


def spawn_worker(host: str = "127.0.0.1", port: int = 0, *,
                 once: bool = True, start_timeout_s: float = 60.0,
                 extra_args: Sequence[str] = (),
                 ) -> tuple[tuple[str, int], subprocess.Popen]:
    """Spawn one listening TCP worker; → ((host, port), process).

    The worker prints ``WORKER_LISTENING host:port`` after binding (port 0
    → kernel-picked); we scan its stdout for the banner under a deadline so
    a worker that dies at import surfaces as a TransportError with its exit
    code, never a hang.  ``once`` ties the worker's lifetime to its first
    mutating session (right for stub-owned workers); pass ``once=False``
    for a pod-like worker that keeps listening across router attach/detach.
    ``extra_args`` rides extra worker flags (the pod-rank plumbing)."""
    cmd = [sys.executable, "-m", "repro.serving.worker",
           "--listen", f"{host}:{port}", *extra_args]
    if once:
        cmd.append("--once")
    proc = subprocess.Popen(cmd, env=worker_env(), stdout=subprocess.PIPE,
                            text=True)
    deadline = time.monotonic() + start_timeout_s
    try:
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TransportError(
                    f"worker did not report a listen address within "
                    f"{start_timeout_s}s")
            ready, _, _ = select.select([proc.stdout], [], [], remaining)
            if not ready:
                continue
            line = proc.stdout.readline()
            if not line:                   # EOF: the worker died at startup
                raise TransportError(
                    f"worker exited before listening "
                    f"(rc={proc.wait(timeout=10)})")
            if line.startswith(BANNER):
                addr = line.split(None, 1)[1].strip()
                h, _, p = addr.rpartition(":")
                return (h, int(p)), proc
    except Exception:
        if proc.poll() is None:
            proc.kill()
        raise


@dataclasses.dataclass
class Fleet:
    """N spawned workers: the addresses a router attaches to, plus the
    process handles this stand-in scheduler owns."""

    workers: list[tuple[tuple[str, int], subprocess.Popen]]

    @property
    def addrs(self) -> list[tuple[str, int]]:
        return [addr for addr, _ in self.workers]

    def close(self):
        for _, proc in self.workers:
            if proc.poll() is None:
                proc.terminate()
        for _, proc in self.workers:
            try:
                proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait(timeout=10)

    def __enter__(self) -> "Fleet":
        return self

    def __exit__(self, *exc):
        self.close()


def launch_fleet(n: int, *, host: str = "127.0.0.1") -> Fleet:
    """Spawn ``n`` pod-like local TCP workers (``once=False`` — they keep
    listening across router attach/detach) and return their addresses."""
    workers = []
    try:
        for _ in range(n):
            workers.append(spawn_worker(host, once=False))
    except Exception:
        Fleet(workers).close()
        raise
    return Fleet(workers)


# ---------------------------------------------------------------------------
# multi-process pods
# ---------------------------------------------------------------------------


def free_port(host: str = "127.0.0.1") -> int:
    """A kernel-picked free port for the jax.distributed coordinator.  The
    bind-then-release dance is racy in principle; for the localhost
    demo/CI scheduler stand-in it is the standard trade — a real scheduler
    assigns the coordinator endpoint itself."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind((host, 0))
        return s.getsockname()[1]


@dataclasses.dataclass
class PodHandle:
    """One spawned multi-process pod: rank-ordered addresses and process
    handles (index 0 is the head — the only rank a router dials)."""

    rank_addrs: list[tuple[str, int]]
    procs: list[subprocess.Popen]
    coordinator: str

    @property
    def head_addr(self) -> tuple[str, int]:
        return self.rank_addrs[0]

    @property
    def head_proc(self) -> subprocess.Popen:
        return self.procs[0]

    def close(self):
        for proc in self.procs:
            if proc.poll() is None:
                proc.terminate()
        for proc in self.procs:
            try:
                proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait(timeout=10)

    def __enter__(self) -> "PodHandle":
        return self

    def __exit__(self, *exc):
        self.close()


def launch_pod(pod_size: int, *, host: str = "127.0.0.1",
               once: bool = True,
               start_timeout_s: float = 120.0) -> PodHandle:
    """Spawn one ``pod_size``-rank pod on localhost; → PodHandle.

    Non-head ranks come up first (the head claims their mutating sessions
    at startup, so they must already be listening), each handed the shared
    coordinator address and its rank; the head comes up last with
    ``--pod-peers`` naming the ranks.  The head's banner therefore means
    the whole pod is wired.  ``once`` ties the HEAD's lifetime to its
    first router session (stub-owned pods); non-head ranks always follow
    the head — a forwarded shutdown or the handle's close() retires them."""
    if pod_size < 1:
        raise ValueError(f"pod_size must be >= 1, got {pod_size}")
    coordinator = f"{host}:{free_port(host)}"
    addrs: list[tuple[str, int]] = []
    procs: list[subprocess.Popen] = []
    try:
        for rank in range(1, pod_size):
            addr, proc = spawn_worker(
                host, once=False, start_timeout_s=start_timeout_s,
                extra_args=["--pod-rank", str(rank),
                            "--pod-size", str(pod_size),
                            "--coordinator", coordinator])
            addrs.append(addr)
            procs.append(proc)
        peers = ",".join(f"{h}:{p}" for h, p in addrs)
        head_args = ["--pod-rank", "0", "--pod-size", str(pod_size),
                     "--coordinator", coordinator]
        if peers:
            head_args += ["--pod-peers", peers]
        head_addr, head_proc = spawn_worker(
            host, once=once, start_timeout_s=start_timeout_s,
            extra_args=head_args)
    except Exception:
        PodHandle(addrs, procs, coordinator).close()
        raise
    return PodHandle([head_addr] + addrs, [head_proc] + procs, coordinator)

