"""Replica profiles: what makes one replica NOT interchangeable with another.

The fleet's capacity is heterogeneous on three axes the router must see:

* **economics** — an on-demand replica and a preemptible (spot) one differ
  in cost per tick, and the provider may reclaim the spot one without
  notice mid-decode; under a ``SpotMarket`` the spot price is a *process*,
  not a constant — mean-reverting with occasional demand spikes;
* **capability** — replicas on different hardware serve different relative
  tokens/s, so "least loaded" is wrong unless load is normalized by speed;
* **geography** — replicas live in regions, and reaching a remote region
  costs a round trip.  The plan's RTT matrix is what the router injects
  into the replica fabric as deterministic transport delay, and what makes
  region-aware placement measurable against region-blind.

``ReplicaProfile`` is the router's static prior for one replica: its cost
per tick, its relative speed (1.0 = the fleet baseline), whether the
capacity is volatile, and which region it lives in.  In simulation the
prior is seeded from the roofline DB's ``ServiceProfile``
(``ReplicaProfile.from_service``); live, the router refines the speed axis
from each replica's measured lifetime tokens/tick — the profile is a
prior, the measurement wins once there is enough of it.

``FleetPlan`` is the deployment shape the operator actually buys: the first
``reserved`` replica ids are on-demand (stable, expensive), every id past
them is preemptible (cheap, volatile); ``regions`` assigns each id a
geography (cycled, so a 2-region tuple stripes the fleet).  It doubles as
the planner's cost model — ``cost_of(n, tick)`` is what the profile-aware
ScalingOptimizer minimizes instead of a flat per-replica price, priced at
the market's spot rate for that tick when a ``market`` is attached.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Inter-region round-trip times (ms) between the sim's five regions
# (repro/sim/workload.py REGIONS).  Symmetric; same-region is free.  The
# numbers are representative public-cloud medians, not measurements — what
# matters for the benchmark is that cross-region >> one decode tick.
DEFAULT_RTT_MS = {
    ("na", "eu"): 90.0, ("na", "apac"): 150.0, ("na", "sa"): 120.0,
    ("na", "au"): 160.0, ("eu", "apac"): 200.0, ("eu", "sa"): 180.0,
    ("eu", "au"): 250.0, ("apac", "sa"): 280.0, ("apac", "au"): 110.0,
    ("sa", "au"): 300.0,
}


def rtt_between(a: str, b: str, matrix: dict | None = None) -> float:
    """RTT in ms between two region tags: 0 for same/unknown regions, the
    matrix entry (either key order) otherwise."""
    if not a or not b or a == b:
        return 0.0
    m = DEFAULT_RTT_MS if matrix is None else matrix
    return float(m.get((a, b)) or m.get((b, a)) or 0.0)


@dataclasses.dataclass(frozen=True)
class ReplicaProfile:
    """Static prior for one replica's economics, capability, geography."""
    cost_per_tick: float = 1.0
    # relative throughput vs the fleet baseline (2.0 = twice the tokens/s);
    # routing divides load by it, so a fast replica looks emptier
    speed: float = 1.0
    # volatile capacity: may be reclaimed without notice.  The router never
    # places interactive-tier work here and does not replace it on loss —
    # the scaler re-provisions when the forecast still needs the capacity
    preemptible: bool = False
    # geography: "" = region-less (the pre-region default — routing is
    # bit-identical to the legacy key).  When tagged, the router prefers
    # in-region capacity for interactive traffic (region_spills counts
    # forced cross-region placements)
    region: str = ""

    @classmethod
    def from_service(cls, service, baseline=None, *,
                     cost_per_tick: float = 1.0,
                     preemptible: bool = False,
                     region: str = "") -> "ReplicaProfile":
        """Seed a profile from a sim ServiceProfile (repro.sim.serving):
        speed is the service's tokens/s relative to ``baseline`` (another
        ServiceProfile, default: itself → 1.0)."""
        base = baseline if baseline is not None else service
        return cls(cost_per_tick=cost_per_tick,
                   speed=service.relative_speed(base),
                   preemptible=preemptible, region=region)


@dataclasses.dataclass(frozen=True)
class SpotMarket:
    """A seeded spot-price process: mean-reverting walk around ``base``
    with occasional multiplicative demand spikes that decay over
    ``spike_ticks``.  ``price(tick)`` is deterministic in (seed, tick) —
    the path is extended lazily and cached, so query order never changes
    it — and never drops below ``floor`` (prices stay positive).

    This is the difference between a planner that buys spot at a catalog
    constant and one that faces a market: under a spike the marginal spot
    replica can briefly cost MORE than on-demand, and the optimizer should
    stop buying it."""
    seed: int = 0
    base: float = 0.35        # the level the walk reverts to
    sigma: float = 0.03       # per-tick gaussian noise
    revert: float = 0.25      # mean-reversion strength (0..1)
    spike_prob: float = 0.02  # per-tick chance a demand spike starts
    spike_mult: float = 3.5   # price multiple at a spike's peak
    spike_ticks: int = 6      # ticks a spike takes to decay
    floor: float = 0.05       # hard lower bound (prices stay positive)

    def __post_init__(self):
        # lazily-extended price path + walk state.  Mutable caches on a
        # frozen dataclass: the *parameters* are immutable identity, the
        # cache is pure memoization of a deterministic function of them.
        object.__setattr__(self, "_path",
                           [max(float(self.base), float(self.floor))])
        object.__setattr__(self, "_rng", np.random.default_rng(self.seed))
        object.__setattr__(self, "_spike_left", [0])

    def price(self, tick: int) -> float:
        """Spot price at ``tick`` (tick 0 = ``base``).  Extends the cached
        path sequentially, so any access order yields the same series."""
        tick = max(int(tick), 0)
        path, spike = self._path, self._spike_left
        while len(path) <= tick:
            p = path[-1]
            p = p + self.revert * (self.base - p) \
                + self.sigma * float(self._rng.normal())
            if float(self._rng.random()) < self.spike_prob:
                spike[0] = self.spike_ticks
            if spike[0] > 0:
                # a spike pins the price to a decaying multiple of base —
                # reversion resumes once it has burnt down
                frac = spike[0] / max(self.spike_ticks, 1)
                p = max(p, self.base * (1.0 + (self.spike_mult - 1.0) * frac))
                spike[0] -= 1
            path.append(max(float(p), float(self.floor)))
        return path[tick]

    def prices(self, ticks: int) -> list[float]:
        """The first ``ticks`` prices (extends the cache once)."""
        self.price(max(int(ticks) - 1, 0))
        return list(self._path[:max(int(ticks), 0)])


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """The capacity mix the operator buys: ``reserved`` on-demand replicas
    (ids 0..reserved-1), preemptible ones past that, each id assigned a
    region by cycling ``regions``.  Serves as the router's profile_fn AND
    the optimizer's marginal-cost model; with a ``market`` attached the
    spot leg of ``cost_of`` is priced per tick."""
    reserved: int = 1
    cost_on_demand: float = 1.0
    cost_preemptible: float = 0.35
    speed_on_demand: float = 1.0
    speed_preemptible: float = 1.0
    # geography: region per replica id, cycled — ("na","eu") stripes the
    # fleet na,eu,na,eu,…  () keeps the plan region-less (no RTT, routing
    # bit-identical to the legacy key)
    regions: tuple = ()
    # where the router / traffic origin sits; defaults to regions[0]
    home_region: str = ""
    # {(a,b): ms} RTT overrides; None = DEFAULT_RTT_MS
    rtt_ms: dict | None = None
    # spot-price process; None keeps cost_preemptible a constant
    market: SpotMarket | None = None

    def region_of(self, replica_id: int) -> str:
        if not self.regions:
            return ""
        return self.regions[int(replica_id) % len(self.regions)]

    @property
    def origin(self) -> str:
        """The region traffic originates from (router's vantage point)."""
        return self.home_region or (self.regions[0] if self.regions else "")

    def transport_ms_for(self, replica_id: int) -> float:
        """Deterministic RTT the fabric injects in front of this replica:
        the matrix entry between the traffic origin and the replica's
        region (0 in-region / region-less)."""
        return rtt_between(self.origin, self.region_of(replica_id),
                           self.rtt_ms)

    def spot_price(self, tick: int | None = None) -> float:
        """The spot rate: the market's price at ``tick`` when both exist,
        else the constant ``cost_preemptible`` (backward compatible)."""
        if self.market is None or tick is None:
            return self.cost_preemptible
        return self.market.price(tick)

    def price_of(self, replica_id: int, tick: int | None = None) -> float:
        """What one replica id costs per tick — reserved ids at the
        on-demand rate, spot ids at the (possibly time-varying) spot
        rate."""
        if replica_id < self.reserved:
            return self.cost_on_demand
        return self.spot_price(tick)

    def profile_for(self, replica_id: int) -> ReplicaProfile:
        if replica_id < self.reserved:
            return ReplicaProfile(cost_per_tick=self.cost_on_demand,
                                  speed=self.speed_on_demand,
                                  preemptible=False,
                                  region=self.region_of(replica_id))
        return ReplicaProfile(cost_per_tick=self.cost_preemptible,
                              speed=self.speed_preemptible,
                              preemptible=True,
                              region=self.region_of(replica_id))

    # FleetPlan IS callable as a router profile_fn
    __call__ = profile_for

    def cost_of(self, n: int, tick: int | None = None) -> float:
        """Cost per tick of running ``n`` replicas under this plan — the
        profile-aware ScalingOptimizer's cost term.  Scale-up past the
        reserved pool is priced at the SPOT rate — cheap volatile capacity
        is exactly what batch headroom should be bought with — and when a
        market is attached that rate is the market's price at ``tick``, so
        the planner stops buying spot into a price spike."""
        n = max(int(n), 0)
        on_demand = min(n, self.reserved)
        return (on_demand * self.cost_on_demand
                + (n - on_demand) * self.spot_price(tick))
