"""Replica profiles: what makes one replica NOT interchangeable with another.

The fleet's capacity is heterogeneous on two axes the router must see:

* **economics** — an on-demand replica and a preemptible (spot) one differ
  in cost per tick, and the provider may reclaim the spot one without
  notice mid-decode;
* **capability** — replicas on different hardware serve different relative
  tokens/s, so "least loaded" is wrong unless load is normalized by speed.

``ReplicaProfile`` is the router's static prior for one replica: its cost
per tick, its relative speed (1.0 = the fleet baseline), and whether the
capacity is volatile.  In simulation the prior is seeded from the roofline
DB's ``ServiceProfile`` (``ReplicaProfile.from_service``); live, the router
refines the speed axis from each replica's measured lifetime tokens/tick —
the profile is a prior, the measurement wins once there is enough of it.

``FleetPlan`` is the deployment shape the operator actually buys: the first
``reserved`` replica ids are on-demand (stable, expensive), every id past
them is preemptible (cheap, volatile).  It doubles as the planner's cost
model — ``cost_of(n)`` is what the profile-aware ScalingOptimizer minimizes
instead of a flat per-replica price, which is exactly the difference the
BENCH_tiers benchmark measures between the aware and blind arms.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ReplicaProfile:
    """Static prior for one replica's economics and capability."""
    cost_per_tick: float = 1.0
    # relative throughput vs the fleet baseline (2.0 = twice the tokens/s);
    # routing divides load by it, so a fast replica looks emptier
    speed: float = 1.0
    # volatile capacity: may be reclaimed without notice.  The router never
    # places interactive-tier work here and does not replace it on loss —
    # the scaler re-provisions when the forecast still needs the capacity
    preemptible: bool = False

    @classmethod
    def from_service(cls, service, baseline=None, *,
                     cost_per_tick: float = 1.0,
                     preemptible: bool = False) -> "ReplicaProfile":
        """Seed a profile from a sim ServiceProfile (repro.sim.serving):
        speed is the service's tokens/s relative to ``baseline`` (another
        ServiceProfile, default: itself → 1.0)."""
        base = baseline if baseline is not None else service
        return cls(cost_per_tick=cost_per_tick,
                   speed=service.relative_speed(base),
                   preemptible=preemptible)


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """The capacity mix the operator buys: ``reserved`` on-demand replicas
    (ids 0..reserved-1), preemptible ones past that.  Serves as the
    router's profile_fn AND the optimizer's marginal-cost model."""
    reserved: int = 1
    cost_on_demand: float = 1.0
    cost_preemptible: float = 0.35
    speed_on_demand: float = 1.0
    speed_preemptible: float = 1.0

    def profile_for(self, replica_id: int) -> ReplicaProfile:
        if replica_id < self.reserved:
            return ReplicaProfile(cost_per_tick=self.cost_on_demand,
                                  speed=self.speed_on_demand,
                                  preemptible=False)
        return ReplicaProfile(cost_per_tick=self.cost_preemptible,
                              speed=self.speed_preemptible,
                              preemptible=True)

    # FleetPlan IS callable as a router profile_fn
    __call__ = profile_for

    def cost_of(self, n: int) -> float:
        """Cost per tick of running ``n`` replicas under this plan — the
        profile-aware ScalingOptimizer's cost term.  Scale-up past the
        reserved pool is priced at the SPOT rate: cheap volatile capacity
        is exactly what batch headroom should be bought with."""
        n = max(int(n), 0)
        on_demand = min(n, self.reserved)
        return (on_demand * self.cost_on_demand
                + (n - on_demand) * self.cost_preemptible)
