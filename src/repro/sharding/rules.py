"""Logical-axis partition rules → jax.sharding specs (MaxText-style).

Every parameter / activation dimension in the model code is tagged with a
*logical* axis name ("embed", "heads", "vocab", ...).  A rule table maps each
logical name to zero or more *mesh* axes.  The same model code therefore runs
under any mesh by swapping the rule table — this is what makes the 40
(arch × shape) dry-run cells and the elastic re-mesh path share one model
definition.

Mesh axes (launch/mesh.py):
  pod    — data parallelism across pods (crosses DCI)
  data   — data parallelism / FSDP within a pod
  model  — tensor / expert parallelism within a pod

Rules may map a logical axis to an axis that does not exist in the current
mesh (e.g. "pod" on the single-pod mesh) — such entries are silently dropped,
and a logical dim whose mesh-axis product does not divide the actual dim size
falls back to replication (GQA KV heads with kv < model-axis size).

Model code calls ``constrain(x, ("batch", None, "heads", None))``; the ambient
shard context (set by the step builders in launch/) supplies (rules, mesh).
With no ambient context ``constrain`` is a no-op, so smoke tests run unsharded
on one device.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping logical axis name -> tuple of mesh axis names (in order)."""

    rules: Mapping[str, tuple[str, ...]]

    def get(self, name: str | None) -> tuple[str, ...]:
        if name is None:
            return ()
        return tuple(self.rules.get(name, ()))

    def replace(self, **kw: tuple[str, ...]) -> "AxisRules":
        d = dict(self.rules)
        d.update(kw)
        return AxisRules(d)


# Training: FSDP over ("data",) on the embed dim of weights, tensor parallel
# over ("model",) on heads / ff / vocab / experts; batch over (pod, data).
TRAIN_RULES = AxisRules({
    "batch": ("pod", "data"),
    "seq": (),
    "embed": ("data",),          # FSDP shard dim of weight matrices
    "embed_act": (),             # activations keep d_model replicated
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "qkv": ("model",),           # fused qkv output dim
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_ff": (),
    "layers": (),                # scan-stacked leading layer dim
    "d_inner": ("model",),       # mamba inner channels
    "d_state": (),
    "conv_kernel": (),
    "cache_seq": (),             # decode KV cache sequence dim
    "enc_seq": (),
})

# Serving: pure tensor parallelism — weights sharded over "model" only and
# REPLICATED over "data"/"pod" (weights are served in bf16, so the biggest
# assigned arch fits: qwen2-72b = 144 GB bf16 / 16 model-ranks = 9 GB/chip).
# FSDP-style "embed" sharding would all-gather every weight on every decoded
# token (~250 MB/layer measured on qwen2-72b decode_32k — EXPERIMENTS.md
# §Perf); with TP-only layout the per-token collectives are the attention
# split-K psums and FFN output psums (~KBs).  The decode KV cache shards its
# sequence dim over "model" (split-K decode).
SERVE_RULES = TRAIN_RULES.replace(cache_seq=("model",), embed=())

# Weight-distributed serving for tiny batches (long_500k: global_batch=1):
# with nothing to amortize weight reads over, reading w/256 per step +
# cheap activation psums beats TP-only's w/16 per step (measured 34× on
# falcon-mamba long_500k — EXPERIMENTS.md §Perf).
SERVE_RULES_SMALL_BATCH = SERVE_RULES.replace(embed=("data",))


def serve_rules(global_batch: int) -> AxisRules:
    """Layout choice is batch-dependent: big-batch decode amortizes local
    weight reads (TP-only); tiny-batch decode wants weights spread over
    every chip (weight-distributed)."""
    return SERVE_RULES if global_batch >= 16 else SERVE_RULES_SMALL_BATCH


def pod_decode_rules(mesh, base: AxisRules = SERVE_RULES) -> AxisRules:
    """SERVE_RULES specialized for a replica's shard_map decode tick on
    ``mesh`` (ShardedReplica, single-host or a multi-process pod).

    The decode body is run under shard_map and is collective-free — purely
    batch-parallel — so the slot/batch axis must absorb EVERY mesh axis.
    Mapping "batch" to all of them does two things at once: the pod's full
    device set (the "model" axis included, even when it spans hosts)
    jointly serves one replica's S slots, and ``spec_for``'s first-use-wins
    rule then DROPS the base table's model-axis mappings (cache_seq,
    kv_heads, vocab) on every cache/logits leaf — batch is the leading
    sharded dim of every decode-state leaf, so no leaf can demand a
    collective the body doesn't perform.  The spec derivation itself is the
    same rules machinery the multi-host launcher shards by.

    "cache_blocks" (the physical-block axis of a paged KV pool) maps to the
    same axes as "batch": a shard owns a contiguous range of blocks exactly
    as it owns a contiguous range of slots, and the paged allocator pins a
    slot's blocks to its own partition, so the decode body stays
    collective-free in the paged layout too."""
    axes = tuple(mesh.axis_names)
    return base.replace(batch=axes, cache_blocks=axes)


def _mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for(logical_axes: Sequence[str | None], rules: AxisRules,
             mesh: Mesh | None = None,
             dim_sizes: Sequence[int] | None = None) -> P:
    """PartitionSpec for one array whose dims are named by ``logical_axes``.

    If ``mesh``/``dim_sizes`` are given, any mapping that would not divide the
    dim size (or names a mesh axis that doesn't exist) is dropped → replicate.
    Also guarantees no mesh axis is used twice across dims (first wins).
    """
    sizes = _mesh_axis_sizes(mesh) if mesh is not None else None
    used: set[str] = set()
    out: list = []
    for i, name in enumerate(logical_axes):
        axes = [a for a in rules.get(name) if (sizes is None or a in sizes)]
        axes = [a for a in axes if a not in used]
        if sizes is not None and dim_sizes is not None and axes:
            total = int(np.prod([sizes[a] for a in axes]))
            if dim_sizes[i] % total != 0:
                # keep the largest divisible prefix of the axis list
                keep: list[str] = []
                prod = 1
                for a in axes:
                    if dim_sizes[i] % (prod * sizes[a]) == 0:
                        keep.append(a)
                        prod *= sizes[a]
                    else:
                        break
                axes = keep
        used.update(axes)
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    while out and out[-1] is None:   # canonical form
        out.pop()
    return P(*out)


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)


def tree_specs(axes_tree, rules: AxisRules, mesh: Mesh | None = None,
               shapes_tree=None):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs.

    ``axes_tree`` mirrors the params pytree with tuples of logical names as
    leaves.  ``shapes_tree`` (optional, same structure, tuples of ints — use
    jax.eval_shape output) enables the divisibility fallback.
    """
    if shapes_tree is None:
        return jax.tree.map(lambda ax: spec_for(ax, rules, mesh), axes_tree,
                            is_leaf=_is_axes_leaf)
    shapes = jax.tree.map(lambda s: tuple(s.shape) if hasattr(s, "shape") else tuple(s),
                          shapes_tree,
                          is_leaf=lambda x: hasattr(x, "shape") or _is_axes_leaf(x))
    return jax.tree.map(
        lambda ax, shp: spec_for(ax, rules, mesh, shp), axes_tree, shapes,
        is_leaf=_is_axes_leaf)


def tree_shardings(axes_tree, rules: AxisRules, mesh: Mesh, shapes_tree=None):
    specs = tree_specs(axes_tree, rules, mesh, shapes_tree)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Ambient shard context: model code calls constrain() without knowing the mesh.
# ---------------------------------------------------------------------------

_TLS = threading.local()


@contextlib.contextmanager
def shard_ctx(rules: AxisRules, mesh: Mesh):
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = (rules, mesh)
    try:
        yield
    finally:
        _TLS.ctx = prev


@contextlib.contextmanager
def no_shard_ctx():
    """Suspend the ambient context — used inside shard_map bodies, where
    per-array with_sharding_constraint no longer applies (the body already
    works on explicit per-device blocks)."""
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = None
    try:
        yield
    finally:
        _TLS.ctx = prev


def current_ctx():
    return getattr(_TLS, "ctx", None)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """jax.shard_map across jax versions: new jax exposes it top-level with
    ``check_vma``; older releases (≤0.4.x) only have
    jax.experimental.shard_map with the equivalent ``check_rep`` flag."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def constrain(x, logical_axes: Sequence[str | None]):
    """with_sharding_constraint through the ambient logical-axis table.

    No-op when no shard context is active (single-device smoke tests)."""
    ctx = current_ctx()
    if ctx is None:
        return x
    rules, mesh = ctx
    spec = spec_for(logical_axes, rules, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


class logical:
    """Helper namespace: shorthand constructors for axis tuples."""

    @staticmethod
    def act(*names: str | None):
        return tuple(names)
