"""Sharded, async, preemption-safe checkpointing with elastic restore.

Layout (one directory per step):
  <root>/step_<N>.tmp/        — written first
    manifest.json             — tree structure, shapes, dtypes, mesh topology
    <leaf-key>.npy            — one file per pytree leaf (host-gathered)
  <root>/step_<N>/            — atomic rename commit (crash ⇒ no partial ckpt)

Design notes for 1000+-node deployment (DESIGN.md §8):
  * per-leaf files mirror a per-host-group shard layout — on a real pod each
    host writes only its addressable shards; here (single process) the leaf
    is the degenerate single shard.  The manifest is the coordination point.
  * save() is ASYNC: the device→host transfer happens on the caller thread
    (cheap), serialization happens on a worker thread, so the train loop
    returns to the next step immediately; wait() joins before exit.
  * restore(..., shardings=...) re-shards on load: reading a checkpoint onto
    a *different* mesh (elastic restart after node loss) is the same code
    path as same-mesh restore — jax.device_put with the target sharding.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, root: str | os.PathLike, *, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: list[BaseException] = []

    # ----------------------------------------------------------- save

    def save(self, step: int, state, *, meta: dict | None = None,
             blocking: bool = False):
        """Host-gather + async write.  Returns immediately unless blocking."""
        self.wait()
        flat, treedef = _flatten(state)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        manifest = {
            "step": int(step),
            "treedef": jax.tree_util.tree_structure(state).__repr__(),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()},
            "meta": meta or {},
        }

        def _write():
            try:
                tmp = self.root / f"step_{step}.tmp"
                final = self.root / f"step_{step}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                for k, v in host.items():
                    fn = tmp / (k.replace("/", "__") + ".npy")
                    np.save(fn, v)
                (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
                if final.exists():
                    shutil.rmtree(final)
                os.replace(tmp, final)          # atomic commit
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error.append(e)

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error.pop()

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.root / f"step_{s}", ignore_errors=True)

    # ----------------------------------------------------------- restore

    def steps(self):
        out = []
        for p in self.root.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp"):
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self):
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, like, *, step: int | None = None, shardings=None):
        """``like``: pytree matching the saved structure (arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedShardings — the elastic-reshard path."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat_like, _ = _flatten(like)
        flat_sh = None
        if shardings is not None:
            flat_sh, _ = _flatten(shardings)
        vals = {}
        for k in flat_like:
            arr = np.load(d / (k.replace("/", "__") + ".npy"))
            want = manifest["leaves"].get(k)
            if want is not None and list(arr.shape) != want["shape"]:
                raise ValueError(f"shape mismatch for {k}")
            if flat_sh is not None and k in flat_sh:
                vals[k] = jax.device_put(arr, flat_sh[k])
            else:
                vals[k] = jax.numpy.asarray(arr)
        # rebuild in the structure of `like`
        leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
        keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                         for p in path) for path, _ in leaves]
        return jax.tree_util.tree_unflatten(treedef, [vals[k] for k in keys]), \
            manifest


def save_checkpoint(root, step, state, **kw):
    CheckpointManager(root).save(step, state, blocking=True, **kw)


def restore_checkpoint(root, like, **kw):
    return CheckpointManager(root).restore(like, **kw)
