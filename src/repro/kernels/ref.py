"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

These are deliberately naive: full-materialization attention and a
step-by-step SSD recurrence.  Tests sweep shapes/dtypes and assert the
kernels (interpret mode on CPU) match these within dtype tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd) — GQA, fp32 softmax."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    ok = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    scores = scores + jnp.where(ok, 0.0, NEG_INF)[None, None, None]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, index):
    """q: (B, 1, H, hd); caches: (B, Smax, KV, hd); slots > index masked.

    ``index`` is a scalar or a (B,) vector — with a vector, every batch row
    is masked against its own validity horizon (continuous batching)."""
    B, _, H, hd = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k_cache,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (B,))
    ok = jnp.arange(Smax)[None, :] <= idx[:, None]             # (B, Smax)
    scores = scores + jnp.where(ok, 0.0, NEG_INF)[:, None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def decode_attention_paged_ref(q, k_cache, v_cache, tbl, index):
    """q: (B, 1, H, hd); caches: (NB, bk, KV, hd) physical block pools;
    tbl: (B, nk) int32 block table; index: scalar or (B,).

    The oracle gathers each row's logical sequence out of the block pool
    (``pool[tbl[b]]`` → (nk, bk, KV, hd) → (nk·bk, KV, hd)) and then runs
    the dense masked decode attention on it — paged attention must equal
    dense attention over the gathered view."""
    B = q.shape[0]
    nk = tbl.shape[1]
    bk = k_cache.shape[1]
    tbl = jnp.asarray(tbl, jnp.int32)
    kg = k_cache[tbl].reshape(B, nk * bk, *k_cache.shape[2:])
    vg = v_cache[tbl].reshape(B, nk * bk, *v_cache.shape[2:])
    return decode_attention_ref(q, kg, vg, index)


def cache_paged_update_ref(cache, new, blk, off):
    """cache: (NB, bk, KV, hd); new: (B, KV, hd); blk/off: (B,) — the jnp
    scatter the Pallas table-routed write must reproduce exactly."""
    return cache.at[jnp.asarray(blk, jnp.int32),
                    jnp.asarray(off, jnp.int32)].set(new.astype(cache.dtype))


def cache_ring_update_ref(cache, new, slot):
    """cache: (B, Smax, KV, hd); new: (B, KV, hd); slot: (B,) — the jnp
    scatter the Pallas per-row ring write must reproduce exactly."""
    B = cache.shape[0]
    rows = jnp.arange(B)
    return cache.at[rows, jnp.asarray(slot, jnp.int32)].set(
        new.astype(cache.dtype))


def fused_sample_ref(logits, seed, rid, pos, temperature, *,
                     top_k: int = 0):
    """logits: (B, V); seed/rid/pos: (B,) int32 counters; temperature:
    (B,) float32 → (B,) int32 sampled tokens.

    Gumbel-max over a murmur3-finalizer counter hash of (seed, rid, pos,
    column) — written independently of the kernel (tests pin the two
    BITWISE equal on the shared ``top_k == 0`` space).  ``temperature == 0``
    rows take a plain f32 argmax, bit-compatible with the host
    ``sampling.sample_token`` greedy path.  ``top_k > 0`` masks scaled
    logits below the per-row k-th largest before the Gumbel perturbation —
    the sort is why this path lives in the reference only.
    """
    B, V = logits.shape
    x = jnp.asarray(logits, jnp.float32)

    def mix(v):
        v = v ^ (v >> jnp.uint32(16))
        v = v * jnp.uint32(0x85EBCA6B)
        v = v ^ (v >> jnp.uint32(13))
        v = v * jnp.uint32(0xC2B2AE35)
        return v ^ (v >> jnp.uint32(16))

    def u32(v):
        return jnp.asarray(v, jnp.int32).astype(jnp.uint32)

    key = mix(jnp.uint32(0x9E3779B9) ^ u32(seed))
    key = mix(key ^ u32(rid))
    key = mix(key ^ u32(pos))                                  # (B,)
    bits = mix(key[:, None] ^ jnp.arange(V, dtype=jnp.uint32)[None, :])
    u = ((bits >> jnp.uint32(8)).astype(jnp.float32) + 0.5) \
        * (1.0 / (1 << 24))
    g = -jnp.log(-jnp.log(u))
    t = jnp.asarray(temperature, jnp.float32)[:, None]
    scaled = x / jnp.maximum(t, 1e-30)
    if top_k > 0:
        k = min(top_k, V)
        kth = jnp.sort(scaled, axis=1)[:, V - k][:, None]
        scaled = jnp.where(scaled >= kth, scaled, -jnp.inf)
    score = jnp.where(t > 0.0, scaled + g, x)
    return jnp.argmax(score, axis=1).astype(jnp.int32)


def ssm_scan_ref(x, dt, A, B, C):
    """SSD (Mamba2) recurrence, step by step.

    x: (Bsz, L, H, hd) fp32; dt: (Bsz, L, H); A: (H,) (negative);
    B/C: (Bsz, L, H, N).  Returns y: (Bsz, L, H, hd)
    with h_t = exp(dt_t A) h_{t-1} + dt_t x_t ⊗ B_t and y_t = h_t · C_t."""
    Bsz, L, H, hd = x.shape
    N = B.shape[-1]

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        a = jnp.exp(dt_t * A[None])                       # (Bsz, H)
        h = a[..., None, None] * h + \
            (dt_t[..., None] * x_t)[..., None] * B_t[:, :, None, :]
        y_t = jnp.einsum("bhdn,bhn->bhd", h, C_t)
        return h, y_t

    h0 = jnp.zeros((Bsz, H, hd, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0,
                         (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
                          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0)))
    return jnp.moveaxis(ys, 0, 1)
