"""Flash attention for TPU (Pallas): block-tiled online softmax.

TPU adaptation of the FlashAttention idea (DESIGN.md §6): the (block_q ×
block_k) score tile lives in VMEM, the running (m, l, acc) statistics live in
VMEM scratch that persists across the sequential k-block grid dimension (TPU
grids execute the innermost dimension sequentially per core — no atomics /
shared-memory reductions as on GPU), and the two matmuls per tile hit the MXU
with 128-aligned shapes.  Causal and sliding-window masking skip
fully-masked tiles via pl.when.

Layouts: q (B, H, Sq, hd); k/v (B, KV, Sk, hd) — GQA folds q-head groups onto
the same KV block through the index map (kv = h // group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window, bq: int, bk: int,
                  nk: int):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window

    # Tile-level skip: causal/window tiles that are fully masked cost nothing.
    q_lo, q_hi = qi * bq, qi * bq + bq - 1
    k_lo, k_hi = ki * bk, ki * bk + bk - 1
    live = jnp.asarray(True)
    if causal:
        live &= k_lo <= q_hi
    if window is not None:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                    # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                    # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(ok, s, NEG)
        m_prev = m_ref[:, 0]                                   # (bq,)
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)                    # (bk, hd)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)[None, None]


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                              "interpret"))
def flash_attention_bhsd(q, k, v, *, causal: bool = True, window=None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False):
    """q: (B, H, Sq, hd); k/v: (B, KV, Sk, hd) → (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    nq, nk = Sq // bq, Sk // bk
    grid = (B, H, nq, nk)

    kernel = functools.partial(_flash_kernel, scale=hd ** -0.5, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
