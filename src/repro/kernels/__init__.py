"""Pallas TPU kernels for the data plane's compute hot spots.

Each kernel ships as <name>.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), with ops.py as the jit'd public wrapper and ref.py as the pure-jnp
oracle used by the allclose test sweeps.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
