"""Chunked SSD (Mamba2) scan for TPU (Pallas).

TPU adaptation of the Mamba2 "state-space duality" algorithm (DESIGN.md §6):
the recurrence h_t = a_t·h + dt_t·x_t⊗B_t, y_t = C_t·h_t is evaluated in
chunks of T tokens.  Within a chunk the contribution is the *quadratic* form
  Y_intra = (L ∘ (C Bᵀ)) · (dt ⊙ X),   L[i,j] = exp(P_i − P_j)·1[i≥j],
two (T×N)(N×T) / (T×T)(T×hd) matmuls that map straight onto the MXU —
instead of the sequential elementwise recurrence a GPU scan would use.  The
inter-chunk state (N × hd) is carried in VMEM scratch across the sequential
innermost grid dimension (chunks), exactly like the flash-attention (m, l,
acc) carry.  All decay exponents are differences of the cumulative log-decay
P (non-positive), so nothing overflows.

Layouts: x (B, L, H, hd); dt (B, L, H); A (H, 1); B/C (B, L, H, N);
out (B, L, H, hd).  Grid (B, H, n_chunks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state_ref, *,
                T: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (T, hd)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (T,)
    A = a_ref[0, 0]                                  # scalar (negative)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)       # (T, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)       # (T, N)

    lA = dt * A                                      # (T,) log-decay ≤ 0
    P = jnp.cumsum(lA)                               # inclusive prefix

    # intra-chunk quadratic form
    S = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (T, T)
    ii = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    Lmat = jnp.where(ii >= jj, jnp.exp(P[:, None] - P[None, :]), 0.0)
    M = S * Lmat * dt[None, :]
    y = jax.lax.dot(M, x, preferred_element_type=jnp.float32)     # (T, hd)

    # inter-chunk contribution from the carried state (N, hd)
    state = state_ref[...]
    y += jax.lax.dot(Cm * jnp.exp(P)[:, None], state,
                     preferred_element_type=jnp.float32)

    # state update: decay full chunk + accumulate inputs
    w = (dt * jnp.exp(P[T - 1] - P))[:, None] * x                 # (T, hd)
    state_ref[...] = jnp.exp(P[T - 1]) * state + jax.lax.dot_general(
        Bm, w, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                       # (N, hd)

    o_ref[...] = y.astype(o_ref.dtype)[None, :, None, :]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan_ssd(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = False):
    """x: (Bsz, L, H, hd); dt: (Bsz, L, H); A: (H,); B/C: (Bsz, L, H, N)."""
    Bsz, L, H, hd = x.shape
    N = B.shape[-1]
    T = min(chunk, L)
    assert L % T == 0, (L, T)
    nc = L // T
    grid = (Bsz, H, nc)

    kernel = functools.partial(_ssd_kernel, T=T)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, T, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, T, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, T, 1, N), lambda b, h, c: (b, c, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, T, 1, hd), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, L, H, hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, hd), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.reshape(H, 1).astype(jnp.float32), B, C)
