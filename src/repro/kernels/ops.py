"""jit'd public wrappers around the Pallas kernels.

Model code calls these with model-native layouts; the wrappers transpose to
kernel layouts, pick interpret mode automatically (Pallas TPU kernels execute
their body in Python on CPU when interpret=True — that is how this
container validates them), and fall back to the jnp reference for shapes the
kernels don't tile (ragged block sizes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.decode_attention import (
    cache_paged_update_bs,
    cache_ring_update_bs,
    decode_attention_bkgd,
    decode_attention_paged_bkgd,
)
from repro.kernels.sample import fused_sample_bv
from repro.kernels.ssm_scan import ssm_scan_ssd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = 128, block_k: int = 128, interpret=None):
    """q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd) → (B, Sq, H, hd)."""
    interpret = _interpret_default() if interpret is None else interpret
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    if Sq % bq or Sk % bk:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    qt = jnp.swapaxes(q, 1, 2)          # (B, H, Sq, hd)
    kt = jnp.swapaxes(k, 1, 2)          # (B, KV, Sk, hd)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               block_q=bq, block_k=bk, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)


def decode_attention(q, k_cache, v_cache, index, *, block_k: int = 512,
                     interpret=None):
    """q: (B, 1, H, hd); caches: (B, Smax, KV, hd) → (B, 1, H, hd).

    ``index`` is a scalar or a (B,) per-row position vector — both dispatch
    to the same split-K kernel (the scalar broadcasts); only a ragged Smax
    (not divisible by any block) falls back to the jnp reference."""
    interpret = _interpret_default() if interpret is None else interpret
    B, _, H, hd = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    bk = min(block_k, Smax)
    if Smax % bk:
        return ref.decode_attention_ref(q, k_cache, v_cache, index)
    G = H // KV
    qt = q[:, 0].reshape(B, KV, G, hd)  # head h = kv·G + g, as in sdpa_ref
    kt = jnp.swapaxes(k_cache, 1, 2)    # (B, KV, Smax, hd)
    vt = jnp.swapaxes(v_cache, 1, 2)
    out = decode_attention_bkgd(qt, kt, vt, index, block_k=bk,
                                interpret=interpret)
    return out.reshape(B, 1, H, hd)


def decode_attention_paged(q, k_cache, v_cache, tbl, index, *, interpret=None):
    """q: (B, 1, H, hd); caches: (NB, bk, KV, hd) physical block pools;
    tbl: (B, nk) int32 block table; index: scalar or (B,) → (B, 1, H, hd).

    The paged analogue of ``decode_attention``: each batch row's logical
    sequence is the concatenation of the pool blocks its table row names,
    so the kernel streams ``tbl[b, ki]`` where the dense kernel streamed
    block ki of row b's private ring."""
    interpret = _interpret_default() if interpret is None else interpret
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qt = q[:, 0].reshape(B, KV, G, hd)  # head h = kv·G + g, as in sdpa_ref
    kt = jnp.swapaxes(k_cache, 1, 2)    # (NB, KV, bk, hd)
    vt = jnp.swapaxes(v_cache, 1, 2)
    out = decode_attention_paged_bkgd(qt, kt, vt, tbl, index,
                                      interpret=interpret)
    return out.reshape(B, 1, H, hd)


def cache_paged_update(cache, new, blk, off, *, interpret=None):
    """Scatter ``new[b]`` into ``cache[blk[b], off[b]]`` — the table-routed
    K/V write.  cache: (NB, bk, KV, hd); new: (B, KV, hd); blk/off: (B,)
    int32 physical block id and in-block offset."""
    interpret = _interpret_default() if interpret is None else interpret
    return cache_paged_update_bs(cache, new, blk, off, interpret=interpret)


def cache_ring_update(cache, new, slot, *, interpret=None):
    """Scatter ``new[b]`` into ``cache[b, slot[b]]`` — the fused per-row
    ring-buffer K/V write.  cache: (B, Smax, KV, hd); new: (B, KV, hd);
    slot: (B,) int32 (already reduced mod Smax)."""
    interpret = _interpret_default() if interpret is None else interpret
    return cache_ring_update_bs(cache, new, slot, interpret=interpret)


def fused_sample(logits, seed, rid, pos, temperature, *, top_k: int = 0,
                 interpret=None):
    """logits: (B, V) float; seed/rid/pos: (B,) int32 stateless RNG
    counters; temperature: (B,) float32 (0 → greedy argmax, bit-compatible
    with the host ``sampling.sample_token``) → (B,) int32 tokens.

    ``top_k`` is static per call (0 = full vocabulary); ``top_k > 0``
    needs a per-row k-th order statistic, which the kernel doesn't tile —
    it dispatches to the jnp reference, still entirely on device."""
    interpret = _interpret_default() if interpret is None else interpret
    if top_k > 0:
        return ref.fused_sample_ref(logits, seed, rid, pos, temperature,
                                    top_k=top_k)
    return fused_sample_bv(logits, seed, rid, pos, temperature,
                           interpret=interpret)


def ssm_scan(x, dt, A, B, C, *, chunk: int = 128, interpret=None):
    """SSD scan — x: (Bsz, L, H, hd); dt: (Bsz, L, H); A: (H,);
    B/C: (Bsz, L, H, N) → y (Bsz, L, H, hd) fp32."""
    interpret = _interpret_default() if interpret is None else interpret
    L = x.shape[1]
    T = min(chunk, L)
    if L % T:
        return ref.ssm_scan_ref(x, dt, A, B, C)
    y = ssm_scan_ssd(x.astype(jnp.float32), dt.astype(jnp.float32), A,
                     B.astype(jnp.float32), C.astype(jnp.float32),
                     chunk=T, interpret=interpret)
    return y
