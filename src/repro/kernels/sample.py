"""Fused in-kernel token sampling — the decode tail.

One grid row per batch element: greedy argmax or Gumbel-max temperature
sampling over that row's (V,) logits, with a counter-based RNG hashed from
scalar-prefetched ``(seed, rid, pos)`` — Philox-style stateless counters:
no RNG state lives on device, every (request, position) pair draws an
independent stream, and replays/retraces are bit-reproducible.

Greedy (``temperature == 0``) is bit-compatible with the host path
(``serving.sampling.sample_token``): both reduce to first-index argmax
over the f32 logits row (the host's f32→f64 cast is monotonic and
injective, so the winning index agrees), which is what lets a serving tick
keep its sampled tokens on device — the engine pulls (B,) int32 tokens
instead of (B, 1, V) logits.

Top-k thresholding needs a per-row k-th order statistic (a sort); that
lives in the jnp reference (``ref.fused_sample_ref``) and ``ops.
fused_sample`` routes ``top_k > 0`` there — the same "shapes the kernel
doesn't tile fall back to ref" contract the attention wrappers use.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# murmur3 finalizer constants — the avalanche the jnp oracle reimplements
# independently; tests pin kernel == ref BITWISE on the shared space
M1 = 0x85EBCA6B
M2 = 0xC2B2AE35
GOLDEN = 0x9E3779B9


def _mix(x):
    """uint32 → uint32 avalanche (murmur3 fmix32)."""
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(M1)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(M2)
    return x ^ (x >> jnp.uint32(16))


def _u32(v):
    return jnp.asarray(v, jnp.int32).astype(jnp.uint32)


def _sample_kernel(seed_ref, rid_ref, pos_ref, logits_ref, temp_ref,
                   out_ref, *, V: int):
    b = pl.program_id(0)
    x = logits_ref[0].astype(jnp.float32)[None, :]            # (1, V)
    t = temp_ref[0, 0]
    key = _mix(jnp.uint32(GOLDEN) ^ _u32(seed_ref[b]))
    key = _mix(key ^ _u32(rid_ref[b]))
    key = _mix(key ^ _u32(pos_ref[b]))
    col = jax.lax.broadcasted_iota(jnp.uint32, (1, V), 1)
    bits = _mix(key ^ col)
    u = ((bits >> jnp.uint32(8)).astype(jnp.float32) + 0.5) \
        * (1.0 / (1 << 24))                                   # (0, 1)
    g = -jnp.log(-jnp.log(u))
    score = jnp.where(t > 0.0, x / jnp.maximum(t, 1e-30) + g, x)
    out_ref[0, 0] = jnp.argmax(score, axis=1).astype(jnp.int32)[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_sample_bv(logits, seed, rid, pos, temperature, *,
                    interpret: bool = False):
    """logits: (B, V) float; seed/rid/pos: (B,) int32 RNG counters;
    temperature: (B,) float32 (0 → greedy argmax) → (B,) int32 tokens."""
    B, V = logits.shape
    kernel = functools.partial(_sample_kernel, V=V)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, V), lambda b, s, r, p: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, s, r, p: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, s, r, p: (b, 0)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        interpret=interpret,
    )(jnp.asarray(seed, jnp.int32), jnp.asarray(rid, jnp.int32),
      jnp.asarray(pos, jnp.int32), logits,
      jnp.asarray(temperature, jnp.float32)[:, None])
    return out[:, 0]
