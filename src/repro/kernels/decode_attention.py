"""Decode attention for TPU (Pallas): split-K accumulation over the KV cache.

Flash-decoding adapted to the TPU execution model (DESIGN.md §6): on GPU,
split-K shards the KV range across SMs and combines partials with a second
kernel; a TPU core executes grid steps sequentially, so split-K becomes
K-block accumulation in VMEM scratch — the (m, l, acc) running statistics
carry across the innermost (k-block) grid dimension and the output is
normalized on the last block.  Decode is memory-bound KV streaming: each
(bk × hd) cache tile is read exactly once from HBM.

The GQA q-head group (G = H/KV heads sharing one KV head) forms the q tile —
(G, hd) — so the score matmul is (G, hd) × (hd, bk): MXU-shaped when G ≥ 8,
and still a single VREG broadcast otherwise.

Layouts (vector-index contract)::

    q        (B, KV, G, hd)   one query token per batch row
    k/v      (B, KV, Smax, hd) ring-buffer caches
    index    scalar or (B,)   per-row absolute position (scalar broadcasts)
    out      (B, KV, G, hd)

``index`` is scalar-prefetched (SMEM) so each grid row ``b`` reads its own
position before the K/V pipeline issues: row ``b`` masks slots against its own
validity horizon ``slot <= index[b]`` (ring-buffer validity — once a row has
wrapped, ``index >= Smax`` and every slot is live), and the K/V index map
clamps dead blocks to the row's last live block, so the sequential pipeline
re-visits a resident tile instead of streaming dead cache from HBM — a short
row in a continuous batch only pays for its own live KV blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _decode_kernel(idx_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, scale: float, bk: int, nk: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    index = idx_ref[b]
    G = q_ref.shape[2]
    slot = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (G, bk), 1)
    ok = slot <= index

    # skip blocks entirely past this row's valid region
    @pl.when(ki * bk <= index)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                    # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)                    # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(ok, s, NEG)                              # (G, bk)
        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)[None, None]


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention_bkgd(q, k_cache, v_cache, index, *, block_k: int = 512,
                          interpret: bool = False):
    """q: (B, KV, G, hd); caches: (B, KV, Smax, hd); index: scalar or (B,)
    int32 — each batch row is masked against its own position."""
    B, KV, G, hd = q.shape
    Smax = k_cache.shape[2]
    bk = min(block_k, Smax)
    assert Smax % bk == 0, (Smax, bk)
    nk = Smax // bk
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (B,))

    def kv_map(b, h, ki, idx_ref):
        # dead blocks re-map to the row's last live block: the sequential
        # pipeline sees an unchanged block index and skips the HBM fetch
        last = jnp.minimum(idx_ref[b] // bk, nk - 1)
        return (b, h, jnp.minimum(ki, last), 0)

    kernel = functools.partial(_decode_kernel, scale=hd ** -0.5, bk=bk, nk=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ki, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), kv_map),
            pl.BlockSpec((1, 1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ki, i: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(idx, q, k_cache, v_cache)


# ---------------------------------------------------------------------------
# paged decode: the KV pool is (NB, KV, bk, hd) physical blocks and each
# batch row walks its own (nk,) row of a scalar-prefetched block table
# ---------------------------------------------------------------------------


def _decode_paged_kernel(tbl_ref, idx_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, scale: float, bk: int,
                         nk: int):
    """Body identical to ``_decode_kernel`` — only the K/V routing differs
    (the index maps below translate logical block ki through the table)."""
    del tbl_ref
    _decode_kernel(idx_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, scale=scale, bk=bk, nk=nk)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention_paged_bkgd(q, k_cache, v_cache, tbl, index, *,
                                interpret: bool = False):
    """q: (B, KV, G, hd); caches: (NB, KV, bk, hd) shared physical blocks;
    tbl: (B, nk) int32 block table (row b's logical block j lives in physical
    block tbl[b, j]); index: (B,) int32 per-row absolute position.

    This is ``decode_attention_bkgd`` with one generalization: the K/V index
    map reads the scalar-prefetched table, so logical block ki of row b
    streams physical block ``tbl[b, ki]`` from the pool — the same per-row
    dead-block clamping applies (blocks past the row's validity horizon
    re-map to its last live block and the pipeline skips the HBM fetch)."""
    B, KV, G, hd = q.shape
    NB, _, bk, _ = k_cache.shape
    nk = tbl.shape[1]
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (B,))
    tbl = jnp.asarray(tbl, jnp.int32)

    def kv_map(b, h, ki, tbl_ref, idx_ref):
        last = jnp.minimum(idx_ref[b] // bk, nk - 1)
        return (tbl_ref[b, jnp.minimum(ki, last)], h, 0, 0)

    kernel = functools.partial(_decode_paged_kernel, scale=hd ** -0.5,
                               bk=bk, nk=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ki, t, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), kv_map),
            pl.BlockSpec((1, 1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, ki, t, i: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(tbl, idx, q, k_cache, v_cache)


def _paged_update_kernel(blk_ref, off_ref, new_ref, cache_ref, out_ref):
    del blk_ref, off_ref, cache_ref   # routing happens in the out index map
    out_ref[...] = new_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cache_paged_update_bs(cache, new, blk, off, *, interpret: bool = False):
    """Scatter ``new[b]`` into ``cache[blk[b], off[b]]`` in place.

    cache: (NB, bk, KV, hd) physical block pool (model layout); new:
    (B, KV, hd); blk/off: (B,) int32 physical block id and in-block offset.
    The table-resolved coordinates are scalar-prefetched and consumed by the
    output index map — ``cache_ring_update_bs`` with the row's ring slot
    replaced by a (block, offset) pair routed through the block table."""
    NB, bk, KV, hd = cache.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(new.shape[0],),
        in_specs=[
            pl.BlockSpec((1, 1, KV, hd), lambda b, k, o: (b, 0, 0, 0)),
            pl.BlockSpec((1, 1, KV, hd), lambda b, k, o: (k[b], o[b], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, KV, hd),
                               lambda b, k, o: (k[b], o[b], 0, 0)),
    )
    return pl.pallas_call(
        _paged_update_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(cache.shape, cache.dtype),
        input_output_aliases={3: 0},     # cache operand aliases the output
        interpret=interpret,
    )(jnp.asarray(blk, jnp.int32), jnp.asarray(off, jnp.int32),
      new[:, None], cache)


# ---------------------------------------------------------------------------
# per-row ring-buffer K/V write
# ---------------------------------------------------------------------------


def _ring_update_kernel(slot_ref, new_ref, cache_ref, out_ref):
    del slot_ref, cache_ref      # routing happens in the out index map
    out_ref[...] = new_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cache_ring_update_bs(cache, new, slot, *, interpret: bool = False):
    """Scatter ``new[b]`` into ``cache[b, slot[b]]`` in place.

    cache: (B, Smax, KV, hd) (model layout); new: (B, KV, hd); slot: (B,)
    int32 ring slots.  The slot vector is scalar-prefetched and consumed by
    the output index map, so grid step ``b`` touches exactly one (KV, hd)
    cache row; ``input_output_aliases`` makes every untouched row free —
    the donation-friendly form of the jnp ``.at[rows, slot].set`` scatter.
    """
    B, Smax, KV, hd = cache.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, 1, KV, hd), lambda b, s: (b, 0, 0, 0)),
            pl.BlockSpec((1, 1, KV, hd), lambda b, s: (b, s[b], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, KV, hd), lambda b, s: (b, s[b], 0, 0)),
    )
    return pl.pallas_call(
        _ring_update_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(cache.shape, cache.dtype),
        input_output_aliases={2: 0},     # cache operand aliases the output
        interpret=interpret,
    )(jnp.asarray(slot, jnp.int32), new[:, None], cache)
