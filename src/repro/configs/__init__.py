"""Assigned-architecture registry: ``--arch <id>`` resolves here.

Each module defines ``config()`` (the exact published configuration) and
``smoke_config()`` (a reduced same-family variant for CPU smoke tests).
"""
from repro.configs import (
    falcon_mamba_7b,
    h2o_danube_1p8b,
    olmoe_1b_7b,
    phi3p5_moe_42b,
    qwen2_72b,
    qwen2_vl_7b,
    qwen2p5_3b,
    qwen2p5_14b,
    seamless_m4t_medium,
    zamba2_2p7b,
)

_MODULES = [
    zamba2_2p7b,
    qwen2_vl_7b,
    qwen2p5_3b,
    h2o_danube_1p8b,
    qwen2_72b,
    qwen2p5_14b,
    olmoe_1b_7b,
    phi3p5_moe_42b,
    falcon_mamba_7b,
    seamless_m4t_medium,
]

REGISTRY = {m.ARCH_ID: m.config for m in _MODULES}
SMOKE_REGISTRY = {m.ARCH_ID: m.smoke_config for m in _MODULES}
ARCH_IDS = list(REGISTRY)


def get_config(arch_id: str, **kw):
    return REGISTRY[arch_id](**kw)


def get_smoke_config(arch_id: str, **kw):
    return SMOKE_REGISTRY[arch_id](**kw)
