"""Qwen2-72B — dense GQA with QKV bias [arXiv:2407.10671].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
"""
from repro.models import ModelConfig

ARCH_ID = "qwen2-72b"


def config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID, family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
        vocab=152064, qkv_bias=True, rope_theta=1e6,
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=32, n_heads=8, n_kv_heads=2, d_ff=64, vocab=128,
        qkv_bias=True, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)
