"""Phi-3.5-MoE (42B total / 6.6B active) — 16-expert top-2 MoE
[hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) expert d_ff=6400 vocab=32064, MoE 16e top-2.
"""
from repro.models import ModelConfig, MoECfg

ARCH_ID = "phi3.5-moe-42b-a6.6b"


def config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID, family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
        vocab=32064, rope_theta=1e4,
        moe=MoECfg(n_experts=16, top_k=2, d_ff_expert=6400, norm_topk=False),
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke", family="moe",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
        dtype="float32",
        moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=64, norm_topk=False),
    )
    base.update(kw)
    return ModelConfig(**base)
