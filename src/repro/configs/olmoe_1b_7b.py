"""OLMoE-1B-7B — 64-expert top-8 MoE [arXiv:2409.02060].

16L d_model=2048 16H (kv=16) expert d_ff=1024 vocab=50304, MoE 64e top-8,
normalized top-k routing.
"""
from repro.models import ModelConfig, MoECfg

ARCH_ID = "olmoe-1b-7b"


def config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID, family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024,
        vocab=50304, rope_theta=1e4,
        moe=MoECfg(n_experts=64, top_k=8, d_ff_expert=1024, norm_topk=True),
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke", family="moe",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=4, d_ff=32, vocab=128,
        dtype="float32",
        moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=32, norm_topk=True),
    )
    base.update(kw)
    return ModelConfig(**base)
