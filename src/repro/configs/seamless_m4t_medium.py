"""SeamlessM4T-medium backbone — enc-dec, multimodal [arXiv:2308.11596].

12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.  The audio frontend is
a stub per the assignment: input_specs() provides precomputed frame
embeddings for the encoder; decode shapes run on the decoder with
cross-attention to the encoder output.
"""
from repro.models import ModelConfig

ARCH_ID = "seamless-m4t-medium"


def config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID, family="audio",
        n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
        vocab=256206, enc_dec=True, n_enc_layers=12, tie_embeddings=True,
        norm_eps=1e-5,
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke", family="audio",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=4, d_ff=64, vocab=128,
        enc_dec=True, n_enc_layers=2, tie_embeddings=True, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)
