"""Qwen2.5-14B — dense GQA with QKV bias [hf:Qwen/Qwen2.5].

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
"""
from repro.models import ModelConfig

ARCH_ID = "qwen2.5-14b"


def config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID, family="dense",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824,
        vocab=152064, qkv_bias=True, rope_theta=1e6,
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=40, n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
        qkv_bias=True, head_dim=10, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)
