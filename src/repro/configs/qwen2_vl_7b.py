"""Qwen2-VL-7B backbone — M-RoPE, dynamic resolution [arXiv:2409.12191].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.  The vision
frontend is a stub per the assignment: input_specs() provides precomputed
patch embeddings; M-RoPE runs on the backbone with a synthetic patch grid.
"""
from repro.models import ModelConfig

ARCH_ID = "qwen2-vl-7b"


def config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID, family="vlm",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
        vocab=152064, qkv_bias=True, rope_theta=1e6,
        m_rope=True, m_rope_sections=(16, 24, 24), n_vision_patches=1024,
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke", family="vlm",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
        qkv_bias=True, m_rope=True, m_rope_sections=(2, 1, 1),
        n_vision_patches=4, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)
