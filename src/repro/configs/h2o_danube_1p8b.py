"""H2O-Danube-1.8B — llama+mistral mix with sliding-window attention
[arXiv:2401.16818].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, window 4096.
The bounded window is why this dense arch still runs long_500k decode
(ring-buffer KV of 4096 slots — see models/attention.py).
"""
from repro.models import ModelConfig

ARCH_ID = "h2o-danube-1.8b"


def config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID, family="dense",
        n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, d_ff=6912,
        vocab=32000, head_dim=80, rope_theta=1e4, sliding_window=4096,
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
        head_dim=8, sliding_window=8, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)
