"""Zamba2-2.7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000 ssm_state=64.
"""
from repro.models import HybridCfg, ModelConfig, SSMCfg

ARCH_ID = "zamba2-2.7b"


def config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID, family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
        vocab=32000, head_dim=80, rope_theta=1e4, tie_embeddings=True,
        ssm=SSMCfg(d_state=64, version=2, headdim=64, n_groups=1),
        hybrid=HybridCfg(attn_every=6, n_shared_blocks=2),
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke", family="hybrid",
        n_layers=4, d_model=32, n_heads=4, n_kv_heads=4, d_ff=64, vocab=128,
        head_dim=8, tie_embeddings=True, dtype="float32",
        ssm=SSMCfg(d_state=8, version=2, headdim=8, n_groups=1),
        hybrid=HybridCfg(attn_every=2, n_shared_blocks=2),
    )
    base.update(kw)
    return ModelConfig(**base)
