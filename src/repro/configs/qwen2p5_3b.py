"""Qwen2.5-3B — dense GQA with QKV bias, tied embeddings [hf:Qwen/Qwen2.5].

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936.
"""
from repro.models import ModelConfig

ARCH_ID = "qwen2.5-3b"


def config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID, family="dense",
        n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, d_ff=11008,
        vocab=151936, qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
        qkv_bias=True, tie_embeddings=True, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)
