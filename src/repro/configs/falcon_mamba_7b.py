"""Falcon-Mamba-7B — pure Mamba1 SSM, attention-free [arXiv:2410.05355].

64L d_model=4096 (attn-free) vocab=65024 ssm_state=16.  Decode state is O(1)
in sequence length (h: d_inner×16 + conv tail) ⇒ long_500k runs; seq_len
enters only through prefill.
"""
from repro.models import ModelConfig, SSMCfg

ARCH_ID = "falcon-mamba-7b"


def config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID, family="ssm",
        n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=0,
        vocab=65024, tie_embeddings=True,
        ssm=SSMCfg(d_state=16, version=1, expand=2),
    )
    base.update(kw)
    return ModelConfig(**base)


def smoke_config(**kw) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke", family="ssm",
        n_layers=2, d_model=32, n_heads=0, n_kv_heads=0, d_ff=0, vocab=128,
        tie_embeddings=True, dtype="float32",
        ssm=SSMCfg(d_state=4, version=1, expand=2),
    )
    base.update(kw)
    return ModelConfig(**base)
