"""Reinforcement-learning allocator core (paper §3.3.1): double DQN.

The paper specifies "reinforcement learning techniques" over a state of
(utilization, workload, environment) with a reward balancing utilization /
latency / cost [Wang et al. 10].  We implement a compact double-DQN:

  * Q-network = the multi-stream DNN's Q head (shared trunk with the other
    heads — the paper's single optimization engine);
  * replay buffer (uniform), target network with soft updates;
  * double-DQN target: argmax from the online net, value from the target net
    — removes maximization bias, which matters here because the reward is
    noisy (workload stochasticity).

Actions are discrete replica deltas; the allocator maps them onto concrete
ReMesh/scale events (core/allocation/allocator.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dnn.model import DNNConfig, MultiStreamDNN
from repro.optim import adamw, apply_updates

ACTIONS = (-4, -2, -1, 0, 1, 2, 4)      # replica deltas


@dataclasses.dataclass
class DQNConfig:
    gamma: float = 0.95
    lr: float = 5e-4
    buffer_size: int = 20_000
    batch_size: int = 64
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_steps: int = 3_000
    target_tau: float = 0.01
    train_every: int = 4
    warmup: int = 200


class ReplayBuffer:
    def __init__(self, size: int, stream_shapes):
        self.size = size
        self.n = 0
        self.i = 0
        self.data = {
            k: np.zeros((size,) + tuple(s), np.float32)
            for k, s in stream_shapes.items()}
        self.data2 = {
            k: np.zeros((size,) + tuple(s), np.float32)
            for k, s in stream_shapes.items()}
        self.action = np.zeros(size, np.int32)
        self.reward = np.zeros(size, np.float32)
        self.done = np.zeros(size, np.float32)

    def push(self, s, a, r, s2, done):
        j = self.i
        for k in self.data:
            self.data[k][j] = s[k][0]
            self.data2[k][j] = s2[k][0]
        self.action[j] = a
        self.reward[j] = r
        self.done[j] = float(done)
        self.i = (self.i + 1) % self.size
        self.n = min(self.n + 1, self.size)

    def sample(self, rng: np.random.Generator, batch: int):
        idx = rng.integers(0, self.n, size=batch)
        s = {k: jnp.asarray(v[idx]) for k, v in self.data.items()}
        s2 = {k: jnp.asarray(v[idx]) for k, v in self.data2.items()}
        return (s, jnp.asarray(self.action[idx]), jnp.asarray(self.reward[idx]),
                s2, jnp.asarray(self.done[idx]))


def reward_fn(*, utilization: float, latency_ms: float, slo_ms: float,
              cost_per_tick: float, cost_scale: float,
              w_util: float = 1.0, w_lat: float = 1.0,
              w_cost: float = 1.0) -> float:
    """The paper's three-term reward: utilization up, SLO violations down,
    cost down.  Latency enters as a hinge on the SLO (violations dominate)."""
    r_util = utilization                       # ∈ [0, 1]
    r_lat = -max(latency_ms / slo_ms - 1.0, 0.0) * 4.0
    r_cost = -cost_per_tick / max(cost_scale, 1e-9)
    return w_util * r_util + w_lat * r_lat + w_cost * r_cost


class DQNAgent:
    def __init__(self, dnn_cfg: DNNConfig, cfg: DQNConfig = DQNConfig(), *,
                 seed: int = 0):
        self.cfg = cfg
        self.dnn_cfg = dnn_cfg
        self.rng = np.random.default_rng(seed)
        self.params, self.bn_state = MultiStreamDNN.init(
            jax.random.PRNGKey(seed), dnn_cfg)
        self.target_params = jax.tree.map(lambda x: x, self.params)
        self.opt_init, self.opt_update = adamw(cfg.lr)
        self.opt_state = self.opt_init(self.params)
        shapes = {
            "resource": (dnn_cfg.window, dnn_cfg.n_resource_features),
            "perf": (dnn_cfg.window, dnn_cfg.n_perf_features),
            "deploy": (dnn_cfg.n_deploy_features,),
        }
        self.buffer = ReplayBuffer(cfg.buffer_size, shapes)
        self.step_count = 0
        self._train_step = self._make_train_step()

    # ------------------------------------------------------------- acting

    def epsilon(self) -> float:
        c = self.cfg
        frac = min(self.step_count / max(c.eps_decay_steps, 1), 1.0)
        return c.eps_start + (c.eps_end - c.eps_start) * frac

    _q_jit = None

    def q_values(self, streams) -> np.ndarray:
        if DQNAgent._q_jit is None:
            DQNAgent._q_jit = jax.jit(
                lambda p, st, s: MultiStreamDNN.apply(p, st, s,
                                                      training=False)[0]["q"])
        q = DQNAgent._q_jit(self.params, self.bn_state,
                            {k: jnp.asarray(v) for k, v in streams.items()})
        return np.asarray(q[0])

    def act(self, streams, *, greedy: bool = False) -> int:
        """→ action index into ACTIONS."""
        if not greedy and self.rng.random() < self.epsilon():
            return int(self.rng.integers(len(ACTIONS)))
        return int(np.argmax(self.q_values(streams)))

    # ------------------------------------------------------------- learning

    def _make_train_step(self):
        gamma = self.cfg.gamma
        tau = self.cfg.target_tau

        def loss_fn(params, bn_state, target_params, s, a, r, s2, done):
            # the gradient pass runs in TRAINING mode so the deploy-stream
            # BatchNorm's running stats track the data the net is fitted on;
            # the bootstrap passes (next-state / target net) are evaluation
            q, new_bn = MultiStreamDNN.apply(params, bn_state, s,
                                             training=True)
            q_sa = jnp.take_along_axis(q["q"], a[:, None], axis=1)[:, 0]
            q2_online, _ = MultiStreamDNN.apply(params, bn_state, s2,
                                                training=False)
            a2 = jnp.argmax(q2_online["q"], axis=1)            # double-DQN
            q2_target, _ = MultiStreamDNN.apply(target_params, bn_state, s2,
                                                training=False)
            q2 = jnp.take_along_axis(q2_target["q"], a2[:, None], axis=1)[:, 0]
            target = r + gamma * (1.0 - done) * jax.lax.stop_gradient(q2)
            err = q_sa - target
            return jnp.mean(jnp.where(jnp.abs(err) < 1.0, 0.5 * err ** 2,
                                      jnp.abs(err) - 0.5)), new_bn

        @jax.jit
        def train_step(params, bn_state, target_params, opt_state, batch):
            s, a, r, s2, done = batch
            (loss, new_bn), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(
                params, bn_state, target_params, s, a, r, s2, done)
            updates, opt_state = self.opt_update(grads, opt_state, params)
            params = apply_updates(params, updates)
            target_params = jax.tree.map(
                lambda t, p: (1 - tau) * t + tau * p, target_params, params)
            return params, target_params, opt_state, new_bn, loss

        return train_step

    def _train_on_batch(self, batch) -> float:
        (self.params, self.target_params, self.opt_state, self.bn_state,
         loss) = self._train_step(self.params, self.bn_state,
                                  self.target_params, self.opt_state, batch)
        return float(loss)

    def observe(self, s, a, r, s2, done=False):
        self.buffer.push(s, a, r, s2, done)
        self.step_count += 1
        loss = None
        if (self.buffer.n >= self.cfg.warmup
                and self.step_count % self.cfg.train_every == 0):
            loss = self._train_on_batch(
                self.buffer.sample(self.rng, self.cfg.batch_size))
        return loss

    def train_offline(self, steps: int, *, batch_size: int = None) -> list:
        """Replay-only training (no new transitions): used to fit the Q head
        on a recorded trace before the agent ever acts live.  Ignores the
        online warmup/train_every gating — the buffer IS the dataset."""
        if self.buffer.n == 0:
            return []
        bs = min(batch_size or self.cfg.batch_size, self.buffer.n)
        return [self._train_on_batch(self.buffer.sample(self.rng, bs))
                for _ in range(steps)]

    def imitate(self, streams, actions, *, epochs: int = 20, lr: float = 1e-3,
                batch_size: int = 64) -> list:
        """Supervised pretraining of the Q head: cross-entropy of
        softmax(q) against recorded (planner) actions — the cold-start
        imitation the allocator's hybrid mode relies on before enough
        operational reward has accumulated (paper §5.3)."""
        opt_init, opt_update = adamw(lr)
        opt_state = opt_init(self.params)

        @jax.jit
        def step(params, bn_state, opt_state, s, a):
            def loss_fn(p, bn):
                out, new_bn = MultiStreamDNN.apply(p, bn, s, training=True)
                logp = jax.nn.log_softmax(out["q"])
                nll = -jnp.take_along_axis(logp, a[:, None], axis=1)[:, 0]
                return jnp.mean(nll), new_bn

            (loss, new_bn), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, bn_state)
            updates, opt_state = opt_update(grads, opt_state, params)
            return apply_updates(params, updates), new_bn, opt_state, loss

        actions = np.asarray(actions, np.int32)
        n = len(actions)
        bs = max(1, min(batch_size, n))
        losses = []
        for _ in range(epochs):
            order = self.rng.permutation(n)
            for i in range(0, n - bs + 1, bs):
                idx = order[i:i + bs]
                s = {k: jnp.asarray(v[idx]) for k, v in streams.items()}
                (self.params, self.bn_state, opt_state, loss) = step(
                    self.params, self.bn_state, opt_state, s,
                    jnp.asarray(actions[idx]))
                losses.append(float(loss))
        # the pretrained policy is the starting point for bootstrapping too
        self.target_params = jax.tree.map(lambda x: x, self.params)
        return losses
