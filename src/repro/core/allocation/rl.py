"""Reinforcement-learning allocator core (paper §3.3.1): double DQN.

The paper specifies "reinforcement learning techniques" over a state of
(utilization, workload, environment) with a reward balancing utilization /
latency / cost [Wang et al. 10].  We implement a compact double-DQN:

  * Q-network = the multi-stream DNN's Q head (shared trunk with the other
    heads — the paper's single optimization engine);
  * replay buffer (uniform), target network with soft updates;
  * double-DQN target: argmax from the online net, value from the target net
    — removes maximization bias, which matters here because the reward is
    noisy (workload stochasticity).

Actions are discrete replica deltas; the allocator maps them onto concrete
ReMesh/scale events (core/allocation/allocator.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dnn.model import DNNConfig, MultiStreamDNN
from repro.optim import adamw, apply_updates

ACTIONS = (-4, -2, -1, 0, 1, 2, 4)      # replica deltas


@dataclasses.dataclass
class DQNConfig:
    gamma: float = 0.95
    lr: float = 5e-4
    buffer_size: int = 20_000
    batch_size: int = 64
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_steps: int = 3_000
    target_tau: float = 0.01
    train_every: int = 4
    warmup: int = 200


class ReplayBuffer:
    def __init__(self, size: int, stream_shapes):
        self.size = size
        self.n = 0
        self.i = 0
        self.data = {
            k: np.zeros((size,) + tuple(s), np.float32)
            for k, s in stream_shapes.items()}
        self.data2 = {
            k: np.zeros((size,) + tuple(s), np.float32)
            for k, s in stream_shapes.items()}
        self.action = np.zeros(size, np.int32)
        self.reward = np.zeros(size, np.float32)
        self.done = np.zeros(size, np.float32)

    def push(self, s, a, r, s2, done):
        j = self.i
        for k in self.data:
            self.data[k][j] = s[k][0]
            self.data2[k][j] = s2[k][0]
        self.action[j] = a
        self.reward[j] = r
        self.done[j] = float(done)
        self.i = (self.i + 1) % self.size
        self.n = min(self.n + 1, self.size)

    def sample(self, rng: np.random.Generator, batch: int):
        idx = rng.integers(0, self.n, size=batch)
        s = {k: jnp.asarray(v[idx]) for k, v in self.data.items()}
        s2 = {k: jnp.asarray(v[idx]) for k, v in self.data2.items()}
        return (s, jnp.asarray(self.action[idx]), jnp.asarray(self.reward[idx]),
                s2, jnp.asarray(self.done[idx]))


def reward_fn(*, utilization: float, latency_ms: float, slo_ms: float,
              cost_per_tick: float, cost_scale: float,
              w_util: float = 1.0, w_lat: float = 1.0,
              w_cost: float = 1.0) -> float:
    """The paper's three-term reward: utilization up, SLO violations down,
    cost down.  Latency enters as a hinge on the SLO (violations dominate)."""
    r_util = utilization                       # ∈ [0, 1]
    r_lat = -max(latency_ms / slo_ms - 1.0, 0.0) * 4.0
    r_cost = -cost_per_tick / max(cost_scale, 1e-9)
    return w_util * r_util + w_lat * r_lat + w_cost * r_cost


class DQNAgent:
    def __init__(self, dnn_cfg: DNNConfig, cfg: DQNConfig = DQNConfig(), *,
                 seed: int = 0):
        self.cfg = cfg
        self.dnn_cfg = dnn_cfg
        self.rng = np.random.default_rng(seed)
        self.params, self.bn_state = MultiStreamDNN.init(
            jax.random.PRNGKey(seed), dnn_cfg)
        self.target_params = jax.tree.map(lambda x: x, self.params)
        self.opt_init, self.opt_update = adamw(cfg.lr)
        self.opt_state = self.opt_init(self.params)
        shapes = {
            "resource": (dnn_cfg.window, dnn_cfg.n_resource_features),
            "perf": (dnn_cfg.window, dnn_cfg.n_perf_features),
            "deploy": (dnn_cfg.n_deploy_features,),
        }
        self.buffer = ReplayBuffer(cfg.buffer_size, shapes)
        self.step_count = 0
        self._train_step = self._make_train_step()

    # ------------------------------------------------------------- acting

    def epsilon(self) -> float:
        c = self.cfg
        frac = min(self.step_count / max(c.eps_decay_steps, 1), 1.0)
        return c.eps_start + (c.eps_end - c.eps_start) * frac

    _q_jit = None

    def q_values(self, streams) -> np.ndarray:
        if DQNAgent._q_jit is None:
            DQNAgent._q_jit = jax.jit(
                lambda p, st, s: MultiStreamDNN.apply(p, st, s,
                                                      training=False)[0]["q"])
        q = DQNAgent._q_jit(self.params, self.bn_state,
                            {k: jnp.asarray(v) for k, v in streams.items()})
        return np.asarray(q[0])

    def act(self, streams, *, greedy: bool = False) -> int:
        """→ action index into ACTIONS."""
        if not greedy and self.rng.random() < self.epsilon():
            return int(self.rng.integers(len(ACTIONS)))
        return int(np.argmax(self.q_values(streams)))

    # ------------------------------------------------------------- learning

    def _make_train_step(self):
        gamma = self.cfg.gamma
        tau = self.cfg.target_tau

        def loss_fn(params, bn_state, target_params, s, a, r, s2, done):
            q, _ = MultiStreamDNN.apply(params, bn_state, s, training=False)
            q_sa = jnp.take_along_axis(q["q"], a[:, None], axis=1)[:, 0]
            q2_online, _ = MultiStreamDNN.apply(params, bn_state, s2,
                                                training=False)
            a2 = jnp.argmax(q2_online["q"], axis=1)            # double-DQN
            q2_target, _ = MultiStreamDNN.apply(target_params, bn_state, s2,
                                                training=False)
            q2 = jnp.take_along_axis(q2_target["q"], a2[:, None], axis=1)[:, 0]
            target = r + gamma * (1.0 - done) * jax.lax.stop_gradient(q2)
            err = q_sa - target
            return jnp.mean(jnp.where(jnp.abs(err) < 1.0, 0.5 * err ** 2,
                                      jnp.abs(err) - 0.5))

        @jax.jit
        def train_step(params, bn_state, target_params, opt_state, batch):
            s, a, r, s2, done = batch
            loss, grads = jax.value_and_grad(loss_fn)(
                params, bn_state, target_params, s, a, r, s2, done)
            updates, opt_state = self.opt_update(grads, opt_state, params)
            params = apply_updates(params, updates)
            target_params = jax.tree.map(
                lambda t, p: (1 - tau) * t + tau * p, target_params, params)
            return params, target_params, opt_state, loss

        return train_step

    def observe(self, s, a, r, s2, done=False):
        self.buffer.push(s, a, r, s2, done)
        self.step_count += 1
        loss = None
        if (self.buffer.n >= self.cfg.warmup
                and self.step_count % self.cfg.train_every == 0):
            batch = self.buffer.sample(self.rng, self.cfg.batch_size)
            (self.params, self.target_params, self.opt_state,
             loss) = self._train_step(self.params, self.bn_state,
                                      self.target_params, self.opt_state,
                                      batch)
            loss = float(loss)
        return loss
