"""Workload forecasting (paper §3.3.2): statistics + learning combined.

The paper: "The prediction model employs a combination of statistical
analysis and machine learning techniques".  Components:

  * seasonal-naive — daily and weekly profile tables (the paper's §4.2.2
    "daily and weekly workload patterns"), updated online with EWMA;
  * local trend — robust linear fit over the recent window;
  * EWMA level — fast-reacting base level;
  * learned residual — a small ridge-regression on (hour-of-day, day-of-week,
    recent lags) fitted online, capturing what the statistical parts miss.

Predictions are blended with inverse-error weights learned from realized
one-step errors, so whichever component tracks the current regime best
dominates — this is the "continuously refined" behaviour §2.2 describes.
"""
from __future__ import annotations

import numpy as np


class WorkloadForecaster:
    def __init__(self, *, ticks_per_day: int = 288, alpha: float = 0.3,
                 trend_window: int = 24, n_lags: int = 6):
        self.tpd = ticks_per_day
        self.alpha = alpha
        self.trend_window = trend_window
        self.n_lags = n_lags
        self.daily = np.zeros(ticks_per_day)
        self.daily_n = np.zeros(ticks_per_day)
        self.weekly = np.zeros(7)
        self.weekly_n = np.zeros(7)
        self.level = 0.0
        self.hist: list[float] = []
        # ridge residual model on (sin/cos tod, dow one-hot-ish, lags)
        d = 4 + n_lags
        self._A = np.eye(d) * 1.0
        self._b = np.zeros(d)
        self._comp_err = np.ones(4)     # ewma |err| per component
        self.t = 0

    # ------------------------------------------------------------- helpers

    def _phase(self, t):
        tod = t % self.tpd
        dow = (t // self.tpd) % 7
        return tod, dow

    def _feat(self, t):
        tod, dow = self._phase(t)
        ang = 2 * np.pi * tod / self.tpd
        lags = [self.hist[-k] if len(self.hist) >= k else self.level
                for k in range(1, self.n_lags + 1)]
        return np.array([np.sin(ang), np.cos(ang), dow / 6.0, 1.0] + lags)

    def _components(self, t_next) -> np.ndarray:
        tod, dow = self._phase(t_next)
        seas_d = self.daily[tod] if self.daily_n[tod] > 0 else self.level
        seas_w = (seas_d * (self.weekly[dow] /
                            max(np.mean(self.weekly[self.weekly_n > 0]), 1e-9))
                  if self.weekly_n[dow] > 0 else seas_d)
        if len(self.hist) >= 3:
            w = min(self.trend_window, len(self.hist))
            y = np.array(self.hist[-w:])
            x = np.arange(w)
            slope = (np.mean((x - x.mean()) * (y - y.mean()))
                     / (np.var(x) + 1e-9))
            trend = y[-1] + slope
        else:
            trend = self.level
        ridge = float(self._feat(t_next) @ np.linalg.solve(self._A, self._b))
        return np.array([seas_d, seas_w, trend, ridge])

    # ------------------------------------------------------------- API

    def update(self, value: float):
        """Observe this tick's realized load."""
        t = self.t
        # score the previous prediction's components
        comps = self._components(t)
        self._comp_err = 0.95 * self._comp_err + 0.05 * np.abs(comps - value)
        tod, dow = self._phase(t)
        # first-observation seeding is gated on the SEEN COUNTS, never on
        # truthiness: a legitimately observed 0.0 load makes the stored EWMA
        # 0.0, and the next value must DECAY toward it, not reset the profile
        prev_d = self.daily[tod] if self.daily_n[tod] > 0 else value
        self.daily[tod] = self.alpha * value + (1 - self.alpha) * prev_d
        self.daily_n[tod] += 1
        prev_w = self.weekly[dow] if self.weekly_n[dow] > 0 else value
        self.weekly[dow] = self.alpha * value + (1 - self.alpha) * prev_w
        self.weekly_n[dow] += 1
        prev_l = self.level if self.t > 0 else value
        self.level = self.alpha * value + (1 - self.alpha) * prev_l
        f = self._feat(t)
        self._A += np.outer(f, f)
        self._b += f * value
        self.hist.append(float(value))
        if len(self.hist) > 8 * self.tpd:
            del self.hist[:self.tpd]
        self.t += 1

    def predict(self, horizon: int = 1) -> float:
        """Forecast the load ``horizon`` ticks ahead (inverse-error blend)."""
        comps = self._components(self.t + horizon - 1)
        w = 1.0 / (self._comp_err + 1e-6)
        w /= w.sum()
        return float(max(comps @ w, 0.0))

    def predict_peak(self, horizon: int) -> float:
        """Max forecast over the next ``horizon`` ticks (proactive scaling
        targets the peak, not the mean)."""
        return max(self.predict(h) for h in range(1, horizon + 1))
