"""Predictive resource allocation (paper §3.3.1).

The PredictiveAllocator fuses three signals into one scaling action per tick:

  1. the workload forecaster's peak prediction (proactive component),
  2. the DynamicScaler's constrained optimum (model-based planner),
  3. the DQN's learned Q-values over the same state (learning component,
     trained online from realized reward — "continuously improve allocation
     decisions based on deployment outcomes").

Mode "planner" uses (2) alone — this is the ablation baseline; mode "rl"
acts with the DQN but is *shielded* by the constraints (never violates
min/max/step); mode "hybrid" (default) lets the DQN choose among actions
whose planner-predicted latency meets the SLO — learned cost/utilization
trade-off inside a safety envelope.  The DQN is additionally pretrained by
imitating planner decisions (supervised Q-margin), which is what lets it act
sensibly before enough operational data accumulates (paper §5.3 notes the
cold-start limitation).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.allocation.forecaster import WorkloadForecaster
from repro.core.allocation.rl import ACTIONS, DQNAgent, DQNConfig, reward_fn
from repro.core.dnn.features import StreamBuilder, deploy_vector
from repro.core.dnn.model import DNNConfig
from repro.core.scaling.scaler import (
    DynamicScaler, ScalingConstraints, ScalingDecision,
)


@dataclasses.dataclass
class AllocatorConfig:
    mode: str = "hybrid"            # planner | rl | hybrid
    horizon_ticks: int = 3
    w_util: float = 1.0
    w_lat: float = 1.0
    w_cost: float = 1.0


class PredictiveAllocator:
    def __init__(self, perf_model, constraints: ScalingConstraints,
                 deploy_vec: np.ndarray, *, cfg: AllocatorConfig = None,
                 dnn_cfg: DNNConfig = None, seed: int = 0):
        self.cfg = cfg or AllocatorConfig()
        self.constraints = constraints
        self.perf_model = perf_model
        self.deploy_vec = deploy_vec
        self.forecaster = WorkloadForecaster()
        self.scaler = DynamicScaler(self.forecaster, perf_model,
                                    horizon_ticks=self.cfg.horizon_ticks)
        self.dnn_cfg = dnn_cfg or DNNConfig()
        self.agent = DQNAgent(self.dnn_cfg, DQNConfig(), seed=seed)
        self.streams = StreamBuilder(window=self.dnn_cfg.window)
        self._prev = None               # (state, action_idx)
        self.replicas = constraints.min_replicas

    # ------------------------------------------------------------- tick

    def observe(self, metrics: dict):
        """Feed one monitoring tick (before deciding)."""
        self.forecaster.update(metrics.get("rps", 0.0))
        self.streams.push(metrics)

    def decide(self, metrics: dict) -> ScalingDecision:
        planner = self.scaler.compute_scaling_decision(
            metrics, self.constraints, current_replicas=self.replicas)
        if self.cfg.mode == "planner":
            decision = planner
        else:
            state = self.streams.streams(self.deploy_vec)
            q = self.agent.q_values(state)
            explore = (self.cfg.mode == "rl"
                       and self.agent.rng.random() < self.agent.epsilon())
            order = (self.agent.rng.permutation(len(ACTIONS)) if explore
                     else np.argsort(-q))
            chosen = None
            c = self.constraints
            for ai in order:
                r = self.replicas + ACTIONS[ai]
                if not (c.min_replicas <= r <= c.max_replicas):
                    continue
                lat, util = self.perf_model(r, planner.predicted_load)
                if lat <= c.slo_ms or ACTIONS[ai] > 0:
                    chosen = (int(ai), r, lat, util)
                    break
            if chosen is None:
                decision = planner
            else:
                ai, r, lat, util = chosen
                decision = ScalingDecision(
                    target_replicas=r, delta=r - self.replicas,
                    reason=f"dqn:{ACTIONS[ai]}",
                    predicted_load=planner.predicted_load,
                    predicted_latency_ms=lat, efficiency=planner.efficiency)
                self._pending_action = ai
        self._pending_state = self.streams.streams(self.deploy_vec)
        if self.cfg.mode == "planner":
            self._pending_action = int(np.argmin(
                [abs(a - decision.delta) for a in ACTIONS]))
        return decision

    def apply(self, decision: ScalingDecision):
        self.replicas = decision.target_replicas

    def learn(self, metrics: dict, cost_per_tick: float):
        """Reward from the realized outcome of the last action."""
        if self._prev is None:
            self._prev = (self._pending_state, self._pending_action)
            return None
        r = reward_fn(
            utilization=metrics.get("flop_util", 0.0),
            latency_ms=metrics.get("latency_p95", 0.0),
            slo_ms=self.constraints.slo_ms,
            cost_per_tick=cost_per_tick,
            cost_scale=(self.constraints.max_replicas
                        * self.constraints.cost_per_replica),
            w_util=self.cfg.w_util, w_lat=self.cfg.w_lat,
            w_cost=self.cfg.w_cost)
        s, a = self._prev
        s2 = self.streams.streams(self.deploy_vec)
        loss = self.agent.observe(s, a, r, s2)
        self._prev = (self._pending_state, self._pending_action)
        return loss
