"""Predictive resource allocation (paper §3.3.1).

The PredictiveAllocator fuses three signals into one scaling action per tick:

  1. the workload forecaster's peak prediction (proactive component),
  2. the DynamicScaler's constrained optimum (model-based planner),
  3. the DQN's learned Q-values over the same state (learning component,
     trained online from realized reward — "continuously improve allocation
     decisions based on deployment outcomes").

Mode "planner" uses (2) alone — this is the ablation baseline; mode "rl"
acts with the DQN but is *shielded* by the constraints (never violates
min/max/step); mode "hybrid" (default) lets the DQN choose among actions
whose planner-predicted latency meets the SLO — learned cost/utilization
trade-off inside a safety envelope.  The DQN is additionally pretrained by
imitating planner decisions (supervised Q-margin), which is what lets it act
sensibly before enough operational data accumulates (paper §5.3 notes the
cold-start limitation).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.allocation.forecaster import WorkloadForecaster
from repro.core.allocation.rl import ACTIONS, DQNAgent, DQNConfig, reward_fn
from repro.core.dnn.features import StreamBuilder, deploy_vector
from repro.core.dnn.model import DNNConfig
from repro.core.scaling.scaler import (
    DynamicScaler, ScalingConstraints, ScalingDecision,
)


@dataclasses.dataclass
class AllocatorConfig:
    mode: str = "hybrid"            # planner | rl | hybrid
    horizon_ticks: int = 3
    w_util: float = 1.0
    w_lat: float = 1.0
    w_cost: float = 1.0


class PredictiveAllocator:
    def __init__(self, perf_model, constraints: ScalingConstraints,
                 deploy_vec: np.ndarray, *, cfg: AllocatorConfig = None,
                 dnn_cfg: DNNConfig = None, seed: int = 0):
        self.cfg = cfg or AllocatorConfig()
        self.constraints = constraints
        self.perf_model = perf_model
        self.deploy_vec = deploy_vec
        self.forecaster = WorkloadForecaster()
        self.scaler = DynamicScaler(self.forecaster, perf_model,
                                    horizon_ticks=self.cfg.horizon_ticks)
        self.dnn_cfg = dnn_cfg or DNNConfig()
        self.agent = DQNAgent(self.dnn_cfg, DQNConfig(), seed=seed)
        self.streams = StreamBuilder(window=self.dnn_cfg.window)
        self._prev = None               # (state, action_idx)
        # the action credit-assignment chain starts defined: before the first
        # decide() the "last action" is hold (delta 0), and every decide()
        # path — DQN-chosen OR planner fallback — overwrites both fields
        self._pending_action = int(ACTIONS.index(0))
        self._pending_state = None
        self.replicas = constraints.min_replicas

    # ------------------------------------------------------------- tick

    def observe(self, metrics: dict):
        """Feed one monitoring tick (before deciding)."""
        self.forecaster.update(metrics.get("rps", 0.0))
        self.streams.push(metrics)

    def decide(self, metrics: dict) -> ScalingDecision:
        planner = self.scaler.compute_scaling_decision(
            metrics, self.constraints, current_replicas=self.replicas)
        state = self.streams.streams(self.deploy_vec)
        chosen = None
        if self.cfg.mode != "planner":
            q = self.agent.q_values(state)
            explore = (self.cfg.mode == "rl"
                       and self.agent.rng.random() < self.agent.epsilon())
            order = (self.agent.rng.permutation(len(ACTIONS)) if explore
                     else np.argsort(-q))
            c = self.constraints
            for ai in order:
                r = self.replicas + ACTIONS[ai]
                if not (c.min_replicas <= r <= c.max_replicas):
                    continue
                lat, util = self.perf_model(r, planner.predicted_load)
                # hybrid's envelope is the SLO itself: when NO action meets
                # it (infeasible spike), the DQN must not get to pick a
                # smaller scale-up than the planner's max-headroom response
                # — fall through to the planner instead.  "rl" is shielded
                # by the min/max range only (the pure learned policy).
                if self.cfg.mode == "rl" or lat <= c.slo_ms:
                    chosen = (int(ai), r, lat, util)
                    break
        self._pending_state = state
        if chosen is None:
            # planner mode, or the DQN path fell through its safety envelope:
            # the planner's decision is what gets actuated, so the action the
            # next reward credits is the planner's delta — NOT whatever the
            # DQN picked on some earlier tick
            decision = planner
            self._pending_action = int(np.argmin(
                [abs(a - decision.delta) for a in ACTIONS]))
        else:
            ai, r, lat, util = chosen
            decision = ScalingDecision(
                target_replicas=r, delta=r - self.replicas,
                reason=f"dqn:{ACTIONS[ai]}",
                predicted_load=planner.predicted_load,
                predicted_latency_ms=lat, efficiency=planner.efficiency)
            self._pending_action = ai
        return decision

    def apply(self, decision: ScalingDecision):
        self.replicas = decision.target_replicas

    def learn(self, metrics: dict, cost_per_tick: float):
        """Reward from the realized outcome of the last action."""
        if self._pending_state is None:
            return None                 # no decide() yet — nothing to credit
        if self._prev is None:
            self._prev = (self._pending_state, self._pending_action)
            return None
        r = reward_fn(
            utilization=metrics.get("flop_util", 0.0),
            latency_ms=metrics.get("latency_p95", 0.0),
            slo_ms=self.constraints.slo_ms,
            cost_per_tick=cost_per_tick,
            cost_scale=(self.constraints.max_replicas
                        * self.constraints.cost_per_replica),
            w_util=self.cfg.w_util, w_lat=self.cfg.w_lat,
            w_cost=self.cfg.w_cost)
        s, a = self._prev
        s2 = self.streams.streams(self.deploy_vec)
        loss = self.agent.observe(s, a, r, s2)
        self._prev = (self._pending_state, self._pending_action)
        return loss
