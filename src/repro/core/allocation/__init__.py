from repro.core.allocation.forecaster import WorkloadForecaster
from repro.core.allocation.rl import (
    ACTIONS, DQNAgent, DQNConfig, ReplayBuffer, reward_fn,
)
from repro.core.allocation.allocator import AllocatorConfig, PredictiveAllocator
