"""Distributed metric collection (paper §3.5.1).

MetricsCollector aggregates per-replica reports into temporally-aligned
fleet-level records: ring buffers per (replica, metric), tick-aligned
aggregation (mean / p50 / p95 / max), and staleness handling (a replica that
missed a tick contributes its last value, decayed — the paper's "data
consistency and temporal alignment").  Straggler detection lives here too:
per-replica latency EWMAs flagged against the fleet median (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np


@dataclasses.dataclass
class ReplicaReport:
    replica_id: int
    tick: int
    latency_ms_samples: list
    n_requests: int
    n_errors: int
    flop_util: float
    hbm_util: float
    ici_util: float
    mem_frac: float
    queue_depth: int
    # round-trip cost of reaching this replica (0 for in-process ones) —
    # the control plane's view of how remote the replica is.  Streamed
    # reports carry it so the scaler/selector can budget for it.
    transport_ms: float = 0.0
    # speculative decoding events this window: draft tokens proposed and
    # accepted (defaulted so report producers without speculation — older
    # workers, hand-built test reports — keep constructing cleanly)
    spec_proposed: int = 0
    spec_accepted: int = 0
    # the window's latency samples keyed by admission tier ("interactive" /
    # "batch") — the per-tier SLO channels aggregate from these; None from
    # report producers that predate tiers (single-tier fleets lose nothing)
    lat_tiers: dict | None = None


# router-level lifetime counters the collector turns into per-tick EVENT
# channels (delta vs the previous aggregate): spot reclaims, interactive
# work forced onto volatile capacity, interactive work forced out of its
# region.  These ride the fleet record into the DNN feature streams.
FLEET_EVENT_KEYS = ("preemptions", "tier_spills", "region_spills")


class MetricsCollector:
    def __init__(self, *, window: int = 512, straggler_factor: float = 1.8,
                 max_staleness: int = 8):
        self.window = window
        self.straggler_factor = straggler_factor
        # a replica silent for more than this many ticks leaves the fleet
        # aggregate entirely: decayed-toward-zero ghosts (retired replicas'
        # tombstones) must not keep diluting unweighted channels like
        # transport_ms / queue_depth for the rest of the run
        self.max_staleness = max_staleness
        self.reports: dict[int, list[ReplicaReport]] = defaultdict(list)
        self.fleet_records: list[dict] = []
        self._lat_ewma: dict[int, float] = {}
        self._errored: dict[int, int] = {}
        # per-replica watermark: report ticks whose EVENT channels have been
        # folded into an aggregate — each event is counted exactly once,
        # even when a report lands an aggregate tick late
        self._consumed: dict[int, int] = {}
        # fleet-level lifetime counters (observe_fleet) and the totals
        # already folded into an aggregate — same exactly-once contract as
        # the per-replica event watermark, but for router-side counters
        # that no single replica can report
        self._fleet_totals: dict[str, float] = {}
        self._fleet_consumed: dict[str, float] = {}

    def observe_fleet(self, counters: dict):
        """Publish router-level LIFETIME counters (monotonic totals —
        preemptions, tier_spills, region_spills).  The next ``aggregate``
        emits each as a per-tick event count: total minus what previous
        aggregates already consumed, never re-counting and never negative
        (a counter reset after a router swap just re-bases)."""
        for k in FLEET_EVENT_KEYS:
            if k in counters:
                self._fleet_totals[k] = float(counters[k])

    def submit(self, report: ReplicaReport):
        buf = self.reports[report.replica_id]
        buf.append(report)
        if len(buf) > self.window:
            del buf[:-self.window]
        # a report carrying errors marks the replica unhealthy until a clean
        # report arrives — this is how a crashed remote replica surfaces as a
        # straggler instead of silently vanishing from the fleet view
        self._errored[report.replica_id] = report.n_errors
        if report.latency_ms_samples:
            m = float(np.mean(report.latency_ms_samples))
            prev = self._lat_ewma.get(report.replica_id, m)
            self._lat_ewma[report.replica_id] = 0.8 * prev + 0.2 * m
        elif report.n_requests == 0:
            # an idle window (parked / evacuated / tombstoned replica) ends
            # the replica's latency evidence: without this, a parked
            # straggler's stale high EWMA would keep it flagged forever,
            # skew the fleet median, and re-condemn it the moment a
            # scale-up revives it
            self._lat_ewma.pop(report.replica_id, None)

    def aggregate(self, tick: int, *, n_replicas: int,
                  max_replicas: int) -> dict:
        """Fleet-level record for this tick (the DNN's input record).

        Staleness is handled per channel KIND.  Gauges (util, queue depth,
        transport) decay by 0.5**stale — a silent replica's last level is
        still weak evidence of its current level.  EVENT channels (latency
        samples, request/error counts) are folded in exactly once, tracked
        by a per-replica consumed-tick watermark: those events happened
        once, in the window they were reported — replaying them every
        aggregate counted each completed request and its latency once per
        tick of silence, while keying on ``stale == 0`` would silently drop
        any report that lands an aggregate tick late (transport delay, tick
        misalignment), permanently undercounting fleet throughput/errors.

        Replicas silent past max_staleness are PRUNED outright — reports,
        error flags, and latency EWMAs: a retired replica's state must not
        hold collector memory (or a straggler flag) for the rest of the
        run."""
        lat, reqs, errs = [], 0, 0
        spec_prop, spec_acc = 0, 0
        lat_tiers: dict[str, list] = {"interactive": [], "batch": []}
        util = {"flop_util": [], "hbm_util": [], "ici_util": [], "mem_frac": []}
        qd, transport = [], []
        dead = []
        for rid, buf in self.reports.items():
            if not buf:
                dead.append(rid)
                continue
            r = buf[-1]
            stale = tick - r.tick
            if stale > self.max_staleness:
                dead.append(rid)      # long-gone replica: age out entirely
                continue
            w = 0.5 ** stale          # decay stale replicas' gauges
            last = self._consumed.get(rid)
            fresh = [rep for rep in buf
                     if (last is None or rep.tick > last) and rep.tick <= tick]
            for rep in fresh:
                lat.extend(rep.latency_ms_samples)
                for t, samples in (rep.lat_tiers or {}).items():
                    lat_tiers.setdefault(t, []).extend(samples)
                reqs += rep.n_requests
                errs += rep.n_errors
                # EVENT channel, same exactly-once fold: speculation counts
                # happened once, in the window they were reported
                spec_prop += rep.spec_proposed
                spec_acc += rep.spec_accepted
            if fresh:
                # watermark = highest CONSUMED report tick (not the aggregate
                # tick): a report delayed past an intervening aggregate is
                # still folded in once it finally lands
                self._consumed[rid] = max(rep.tick for rep in fresh)
            for k in util:
                util[k].append(getattr(r, k) * w)
            qd.append(r.queue_depth * w)
            transport.append(r.transport_ms * w)
        for rid in dead:
            del self.reports[rid]
            self._errored.pop(rid, None)
            self._lat_ewma.pop(rid, None)
            self._consumed.pop(rid, None)
        lat_arr = np.asarray(lat) if lat else np.zeros(1)
        rec = {
            "tick": tick,
            "latency_p50": float(np.percentile(lat_arr, 50)),
            "latency_p95": float(np.percentile(lat_arr, 95)),
            "latency_mean": float(np.mean(lat_arr)),
            "throughput": float(reqs),
            "error_rate": errs / max(reqs, 1),
            "rps": float(reqs),
            "queue_depth": float(np.mean(qd)) if qd else 0.0,
            "transport_ms": float(np.mean(transport)) if transport else 0.0,
            # acceptance this tick; a fleet with speculation off (or no
            # drafts found) reads 0.0, never NaN
            "accept_rate": spec_acc / max(spec_prop, 1),
            # per-tier SLO channels: 0.0 when a tier completed nothing this
            # tick (a single-tier fleet reads a flat 0 on the other lane)
            "latency_p95_interactive": (
                float(np.percentile(np.asarray(lat_tiers["interactive"]), 95))
                if lat_tiers["interactive"] else 0.0),
            "latency_p95_batch": (
                float(np.percentile(np.asarray(lat_tiers["batch"]), 95))
                if lat_tiers["batch"] else 0.0),
            "replicas_frac": n_replicas / max(max_replicas, 1),
            **{k: float(np.mean(v)) if v else 0.0 for k, v in util.items()},
        }
        # fleet-level event channels: per-tick deltas of the router's
        # lifetime counters (0.0 when observe_fleet was never called — old
        # traces and bare-collector tests read flat zeros)
        for k in FLEET_EVENT_KEYS:
            total = self._fleet_totals.get(k, 0.0)
            rec[k] = max(total - self._fleet_consumed.get(k, 0.0), 0.0)
            self._fleet_consumed[k] = total
        self.fleet_records.append(rec)
        if len(self.fleet_records) > 4 * self.window:
            del self.fleet_records[:-2 * self.window]
        return rec

    def stragglers(self) -> list[int]:
        """Replicas whose latency EWMA exceeds straggler_factor × median,
        plus any replica whose latest report carried errors (a crashed
        remote replica reports n_errors > 0 via its parent-side stub — it
        must show up here even in a fleet too small for the median test)."""
        out = [rid for rid, e in self._errored.items() if e > 0]
        if len(self._lat_ewma) >= 3:
            med = float(np.median(list(self._lat_ewma.values())))
            out.extend(rid for rid, v in self._lat_ewma.items()
                       if v > self.straggler_factor * med and rid not in out)
        return out

    def window_values(self, key: str, n: int = 32) -> np.ndarray:
        return np.asarray([r.get(key, 0.0) for r in self.fleet_records[-n:]])
