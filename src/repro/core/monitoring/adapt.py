"""Adaptive optimization (paper §3.5.2): a feedback loop that tunes control
parameters from realized performance.

The paper: "automatically adjusts system parameters to maintain optimal
performance under varying conditions".  Concretely tuned here:

  * forecast horizon (ticks ahead the scaler provisions for) — longer when
    adaptation keeps arriving late (SLO violations after load rises),
    shorter when utilization chronically undershoots;
  * target-utilization band — widened when the workload is stable, narrowed
    (more headroom) when anomalies are frequent;
  * scale-down cooldown — lengthened when flapping is detected (scale-down
    promptly followed by scale-up).

One-factor-at-a-time hill-climbing with hysteresis: each knob moves one step
per evaluation window and only if the composite objective (paper's reward)
improved the previous time that knob moved in that direction.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.scaling.scaler import ScalingConstraints


@dataclasses.dataclass
class AdaptState:
    horizon: int = 3
    util_lo: float = 0.55
    util_hi: float = 0.85
    cooldown: int = 3


class AdaptiveOptimizer:
    def __init__(self, *, eval_window: int = 48):
        self.state = AdaptState()
        self.window = eval_window
        self._records: list[dict] = []
        self._last_obj: float | None = None
        self._knobs = ("horizon", "cooldown", "util_hi", "util_lo")
        self._knob_idx = 0
        self._last_dir = {k: +1 for k in self._knobs}

    def push(self, record: dict, *, flapped: bool = False,
             violations: int = 0, cost: float = 0.0):
        self._records.append({**record, "flapped": float(flapped),
                              "violations": float(violations), "cost": cost})

    def _objective(self, recs) -> float:
        util = np.mean([r.get("flop_util", 0.0) for r in recs])
        viol = np.mean([r["violations"] for r in recs])
        cost = np.mean([r["cost"] for r in recs])
        flap = np.mean([r["flapped"] for r in recs])
        return float(util - 4.0 * viol - 0.2 * cost - 0.5 * flap)

    def maybe_adapt(self) -> AdaptState | None:
        """Every eval_window records: evaluate, move one knob."""
        if len(self._records) < self.window:
            return None
        recs, self._records = self._records[:self.window], \
            self._records[self.window:]
        obj = self._objective(recs)
        knob = self._knobs[self._knob_idx]
        self._knob_idx = (self._knob_idx + 1) % len(self._knobs)
        direction = self._last_dir[knob]
        if self._last_obj is not None and obj < self._last_obj:
            direction = -direction            # last move hurt: reverse
        self._last_dir[knob] = direction
        s = self.state
        if knob == "horizon":
            s.horizon = int(np.clip(s.horizon + direction, 1, 12))
        elif knob == "cooldown":
            s.cooldown = int(np.clip(s.cooldown + direction, 1, 12))
        elif knob == "util_hi":
            s.util_hi = float(np.clip(s.util_hi + 0.05 * direction, 0.6, 0.95))
        else:
            # the consolidation floor: live since the optimizer's key ranks
            # feasible under-utilized fleets behind in-band ones — the knob
            # stays strictly below util_hi so the band never inverts
            s.util_lo = float(np.clip(s.util_lo + 0.05 * direction,
                                      0.3, s.util_hi - 0.1))
        self._last_obj = obj
        return s

    def constraints(self, base: ScalingConstraints) -> ScalingConstraints:
        import dataclasses as dc
        return dc.replace(base, cooldown_ticks=self.state.cooldown,
                          target_util=(self.state.util_lo, self.state.util_hi))
