"""Statistical anomaly detection + trend analysis (paper §3.5.1 pipeline
stages 2-3): EWMA-residual z-scores with a MAD scale (robust to the very
outliers being hunted), plus rolling linear trend estimation used by the
forecaster and the adaptive optimizer.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Anomaly:
    tick: int
    metric: str
    value: float
    zscore: float
    kind: str          # "spike" | "drop" | "level_shift"


class AnomalyDetector:
    def __init__(self, *, alpha: float = 0.2, z_threshold: float = 4.0,
                 min_history: int = 16):
        self.alpha = alpha
        self.z = z_threshold
        self.min_history = min_history
        self.level: dict[str, float] = {}
        self.resid: dict[str, list[float]] = {}
        self.n: dict[str, int] = {}

    def update(self, tick: int, metrics: dict) -> list[Anomaly]:
        out = []
        for k, v in metrics.items():
            if not isinstance(v, (int, float)):
                continue
            lvl = self.level.get(k, v)
            resid = v - lvl
            hist = self.resid.setdefault(k, [])
            n = self.n.get(k, 0)
            v_eff = v
            if n >= self.min_history:
                mad = np.median(np.abs(np.asarray(hist))) * 1.4826 + 1e-9
                z = resid / mad
                if abs(z) > self.z:
                    out.append(Anomaly(tick, k, float(v), float(z),
                                       "spike" if z > 0 else "drop"))
                    # a flagged outlier must not contaminate the baseline:
                    # clamp its influence on the level / residual history to
                    # the detection threshold (otherwise one spike drags the
                    # EWMA up and every following normal tick fires as "drop")
                    v_eff = lvl + float(np.sign(resid)) * self.z * mad
            hist.append(float(v_eff - lvl))
            if len(hist) > 256:
                del hist[:128]
            self.level[k] = (1 - self.alpha) * lvl + self.alpha * v_eff
            self.n[k] = n + 1
        return out


def trend(values: np.ndarray) -> float:
    """Robust slope (Theil–Sen on a decimated window) per tick."""
    v = np.asarray(values, float)
    if len(v) < 4:
        return 0.0
    idx = np.arange(len(v))
    slopes = []
    step = max(len(v) // 16, 1)
    for i in range(0, len(v) - step, step):
        for j in range(i + step, len(v), step):
            slopes.append((v[j] - v[i]) / (j - i))
    return float(np.median(slopes)) if slopes else 0.0
