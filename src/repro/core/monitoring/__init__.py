from repro.core.monitoring.collector import MetricsCollector, ReplicaReport
from repro.core.monitoring.anomaly import Anomaly, AnomalyDetector, trend
from repro.core.monitoring.adapt import AdaptiveOptimizer, AdaptState
