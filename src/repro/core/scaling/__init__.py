from repro.core.scaling.scaler import (
    DynamicScaler, PerfModel, ScalingConstraints, ScalingDecision,
    ScalingOptimizer,
)
