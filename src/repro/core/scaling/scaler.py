"""Dynamic scaling (paper §3.3.2) — the DynamicScaler, faithfully.

The paper's pseudocode:

    scaling_decision = self.optimizer.optimize(
        current_load=current_load, predicted_load=predicted_load,
        efficiency=resource_efficiency, constraints=constraints)

analyze_current_load → windowed load statistics; predict_future_load → the
workload forecaster (§3.3.2 time-series component); calculate_efficiency →
multi-resource utilization score; optimize → constrained cost minimization:
the smallest replica count whose *predicted* latency meets the SLO at the
*forecast peak* load, within min/max/step/cooldown constraints.

The performance model is injected (PerfModel protocol): the simulator wires
in the roofline-grounded queueing model (sim/serving.py), so the control
plane optimizes against the very models this repo defines.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Protocol

import numpy as np


class PerfModel(Protocol):
    def __call__(self, replicas: int, load_rps: float) -> tuple[float, float]:
        """→ (latency_ms, utilization ∈ [0,1]) at this operating point."""


@dataclasses.dataclass(frozen=True)
class ScalingConstraints:
    min_replicas: int = 1
    max_replicas: int = 64
    max_step: int = 8               # largest replica delta per decision
    slo_ms: float = 200.0
    target_util: tuple[float, float] = (0.55, 0.85)
    cooldown_ticks: int = 3         # min ticks between scale-downs
    cost_per_replica: float = 1.0
    # per-tier SLOs: the batch lane tolerates queueing the interactive one
    # must not; the gate trips when interactive p95 crosses this fraction
    # of its SLO (and releases with hysteresis — see batch_gate_decision)
    slo_batch_ms: float = 2000.0
    batch_gate_frac: float = 0.9
    # replica-fabric transport latency below this fraction of the SLO is
    # ignored (deadband): loopback-socket noise must not flip a knife-edge
    # feasibility test, but a genuinely remote fleet's round-trip cost
    # tightens the latency budget the optimizer plans against.
    transport_deadband_frac: float = 0.02


@dataclasses.dataclass
class ScalingDecision:
    target_replicas: int
    delta: int
    reason: str
    predicted_load: float
    predicted_latency_ms: float
    efficiency: float


class ScalingOptimizer:
    """Constrained optimizer: min cost s.t. predicted latency ≤ SLO.

    ``cost_fn(replicas) -> cost`` replaces the flat per-replica price when
    the fleet is heterogeneous (serving/profiles.py FleetPlan.cost_of:
    reserved capacity at the on-demand rate, headroom past it at the spot
    rate) — the profile-aware planner buys batch headroom cheap."""

    def __init__(self, perf_model: PerfModel,
                 cost_fn: Callable[[int], float] | None = None):
        self.perf_model = perf_model
        self.cost_fn = cost_fn

    def optimize(self, *, current_load: dict, predicted_load: float,
                 efficiency: float, constraints: ScalingConstraints,
                 current_replicas: int,
                 transport_ms: float = 0.0) -> ScalingDecision:
        """``transport_ms`` is the replica fabric's round-trip cost (from
        the streamed ReplicaReports): it is pure overhead the compute model
        can't see, so it comes off the SLO budget before the feasibility
        test."""
        c = constraints
        lo = max(c.min_replicas, current_replicas - c.max_step)
        hi = min(c.max_replicas, current_replicas + c.max_step)
        budget_ms = c.slo_ms - max(transport_ms, 0.0)
        best = None
        for r in range(lo, hi + 1):
            lat, util = self.perf_model(r, predicted_load)
            feasible = lat <= budget_ms and util <= c.target_util[1]
            cost = (self.cost_fn(r) if self.cost_fn is not None
                    else r * c.cost_per_replica)
            # the LOW water mark ranks ahead of cost: of the feasible
            # points, those keeping the fleet inside the utilization band
            # beat under-utilized ones — without this term the adaptation
            # engine's util_lo knob never influences a decision and a flat
            # cost curve lets the latency tie-break overprovision forever
            key = (not feasible, util < c.target_util[0], cost, lat)
            if best is None or key < best[0]:
                best = (key, r, lat, util, feasible)
        _, r, lat, util, feasible = best
        reason = "optimal" if feasible else "infeasible:max_headroom"
        if not feasible:
            # no point meets SLO within step bounds → go as big as allowed
            r = hi
            lat, util = self.perf_model(r, predicted_load)
        return ScalingDecision(target_replicas=r, delta=r - current_replicas,
                               reason=reason, predicted_load=predicted_load,
                               predicted_latency_ms=lat, efficiency=efficiency)


class EvictionPolicy:
    """Closed-loop straggler eviction: flag → sustain → actuate.

    The collector's ``stragglers()`` feed is noisy by design (one bad
    window flags a replica), so the policy only proposes an eviction after
    ``k_windows`` CONSECUTIVE flagged control windows — a replica that
    recovers (or whose stale EWMA the collector prunes) resets its streak.
    Per update at most ``fleet_size - min_fleet`` evictions are proposed:
    the router replaces every evicted replica, but a one-replica fleet must
    never be evicted at all (there is nowhere to drain to while the
    replacement warms, and the "straggler" IS the fleet median)."""

    def __init__(self, k_windows: int = 3, min_fleet: int = 1):
        self.k_windows = max(int(k_windows), 1)
        self.min_fleet = max(int(min_fleet), 1)
        self._streak: dict[int, int] = {}

    def update(self, flagged_ids, fleet_size: int) -> list[int]:
        """One control window: advance streaks; → replica ids to evict."""
        flagged = set(flagged_ids)
        for rid in list(self._streak):
            if rid not in flagged:
                del self._streak[rid]      # recovered → streak resets
        evict: list[int] = []
        budget = max(int(fleet_size) - self.min_fleet, 0)
        for rid in sorted(flagged):
            self._streak[rid] = self._streak.get(rid, 0) + 1
            if self._streak[rid] >= self.k_windows and len(evict) < budget:
                evict.append(rid)
                del self._streak[rid]      # actuated: the replacement
        return evict                       # starts from a clean slate

    def streak(self, replica_id: int) -> int:
        return self._streak.get(replica_id, 0)


class DynamicScaler:
    def __init__(self, forecaster, perf_model: PerfModel, *,
                 horizon_ticks: int = 3, down_sustain: int = 3,
                 cost_fn: Callable[[int], float] | None = None):
        self.forecaster = forecaster
        self.optimizer = ScalingOptimizer(perf_model, cost_fn=cost_fn)
        self.horizon = horizon_ticks
        self.down_sustain = down_sustain
        self._last_downscale = -10**9
        self._below_count = 0
        self._tick = 0
        self._batch_gated = False

    # --- the paper's three analysis phases -------------------------------

    def analyze_current_load(self, metrics: dict) -> dict:
        rps = metrics.get("rps_window", [metrics.get("rps", 0.0)])
        return {
            "mean": float(np.mean(rps)),
            "peak": float(np.max(rps)),
            "std": float(np.std(rps)),
            "current": float(rps[-1]),
        }

    def predict_future_load(self, metrics: dict) -> float:
        del metrics  # forecaster already observed the window via update()
        return self.forecaster.predict_peak(self.horizon)

    def calculate_efficiency(self, current_load: dict,
                             metrics: dict | None = None) -> float:
        """Multi-resource efficiency: mean of the utilization channels."""
        if not metrics:
            return 0.0
        chans = [metrics.get(k, 0.0)
                 for k in ("flop_util", "hbm_util", "ici_util", "mem_frac")]
        return float(np.mean([c for c in chans if c is not None]))

    def batch_gate_decision(self, metrics: dict,
                            constraints: ScalingConstraints) -> bool:
        """Should the fleet's batch lane be gated this tick?  Trips when
        the interactive lane's p95 (the collector's per-tier channel)
        crosses ``batch_gate_frac`` of its SLO; releases with 2:1
        hysteresis so a knife-edge tick doesn't flap the gate — batch
        requests stay queued while gated, they are never dropped."""
        p95_i = float(metrics.get("latency_p95_interactive", 0.0))
        trip = constraints.batch_gate_frac * constraints.slo_ms
        self._batch_gated = (p95_i > 0.5 * trip if self._batch_gated
                             else p95_i > trip)
        return self._batch_gated

    # --- the decision step (paper pseudocode shape) ----------------------

    def compute_scaling_decision(self, metrics: dict,
                                 constraints: ScalingConstraints,
                                 *, current_replicas: int) -> ScalingDecision:
        current_load = self.analyze_current_load(metrics)
        predicted_load = self.predict_future_load(metrics)
        resource_efficiency = self.calculate_efficiency(current_load, metrics)
        # per-replica transport latency, streamed in via the collector's
        # fleet record; sub-deadband values (loopback noise) are dropped so
        # in-process and local-socket fleets plan identically
        transport_ms = float(metrics.get("transport_ms", 0.0))
        if transport_ms < constraints.transport_deadband_frac \
                * constraints.slo_ms:
            transport_ms = 0.0

        decision = self.optimizer.optimize(
            current_load=current_load,
            predicted_load=predicted_load,
            efficiency=resource_efficiency,
            constraints=constraints,
            current_replicas=current_replicas,
            transport_ms=transport_ms,
        )
        # scale-down damping: up fast, down slow.  A down decision must be
        # (a) SUSTAINED — the optimizer proposed a lower target for
        # `down_sustain` consecutive ticks (one-tick dips from forecast noise
        # or adaptation knob moves must not drain warm replicas), and
        # (b) rate-limited by the cooldown (never faster than provisioning).
        self._tick += 1
        if decision.delta < 0:
            self._below_count += 1
            sustained = self._below_count >= self.down_sustain
            cooled = (self._tick - self._last_downscale
                      >= constraints.cooldown_ticks)
            if not (sustained and cooled):
                return ScalingDecision(
                    target_replicas=current_replicas, delta=0,
                    reason="cooldown" if sustained else "down_hysteresis",
                    predicted_load=predicted_load,
                    predicted_latency_ms=decision.predicted_latency_ms,
                    efficiency=resource_efficiency)
            self._last_downscale = self._tick
            self._below_count = 0
        else:
            self._below_count = 0
        return decision
