from repro.core.orchestration.strategies import (
    CATALOG, STRATEGY_NAMES, DeployEnv, Strategy, stage_deploy_seconds,
    total_deploy_seconds,
)
from repro.core.orchestration.selector import (
    DecisionTreeSelector, DeploymentContext, DNNSelector, OutcomeStats,
)
from repro.core.orchestration.rollout import (
    CanaryAnalyzer, CanarySample, HealthPolicy, Phase, RolloutManager,
    binomial_z_pvalue, welch_t_pvalue_one_sided,
)
