"""Deployment-strategy catalog (paper §3.4.1).

Each strategy describes how a new model version reaches full traffic on a
TPU-slice fleet: staged traffic fractions, resource overhead while both
versions coexist, and the per-stage deployment work.  Deployment *time* is
modelled from first principles for a TPU pod (DESIGN.md §3 hardware
adaptation): slice provisioning + sharded-checkpoint streaming (bytes /
aggregate HBM-fill bandwidth) + compile-cache warmup + per-stage health
soak — this replaces the paper's cloud-VM container-pull model.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Strategy:
    name: str
    # traffic fraction served by the new version at each stage (ends at 1.0)
    stages: tuple[float, ...]
    # extra capacity (fraction of fleet) held during the rollout
    resource_overhead: float
    # soak time per stage (ticks) for canary health evaluation
    soak_ticks: int
    # blast radius: fraction of traffic exposed if the version is bad
    risk: float


CATALOG: dict[str, Strategy] = {
    "all_at_once":        Strategy("all_at_once", (1.0,), 0.0, 0, 1.00),
    "rolling":            Strategy("rolling", (0.25, 0.5, 0.75, 1.0), 0.10, 1, 0.25),
    "blue_green":         Strategy("blue_green", (1.0,), 1.00, 1, 0.10),
    "canary_10":          Strategy("canary_10", (0.10, 1.0), 0.10, 2, 0.10),
    "canary_progressive": Strategy("canary_progressive",
                                   (0.01, 0.05, 0.25, 1.0), 0.05, 2, 0.01),
    "shadow":             Strategy("shadow", (0.0, 1.0), 0.50, 3, 0.00),
}

STRATEGY_NAMES = tuple(CATALOG)


@dataclasses.dataclass(frozen=True)
class DeployEnv:
    """Environment facts the time model needs."""
    params_bytes: float             # checkpoint size
    chips_per_replica: int
    n_replicas: int
    hbm_fill_gbps: float = 100.0    # per-chip sustained restore bandwidth
    provision_s: float = 180.0      # slice acquisition / reschedule
    compile_warmup_s: float = 120.0 # persistent-cache miss penalty
    compile_cache_hit: bool = True
    tick_s: float = 10.0


def stage_deploy_seconds(env: DeployEnv, frac_replicas: float) -> float:
    """Time to bring up `frac_replicas` of the fleet on the new version."""
    n = max(1, round(env.n_replicas * frac_replicas))
    # replicas restore in parallel; each streams its shard-set onto HBM
    stream_s = (env.params_bytes / env.chips_per_replica
                / (env.hbm_fill_gbps * 1e9))
    warmup = 0.0 if env.compile_cache_hit else env.compile_warmup_s
    del n  # parallel across replicas — wall time is per-replica
    return env.provision_s + stream_s + warmup


def total_deploy_seconds(strategy: Strategy, env: DeployEnv) -> float:
    """Wall-clock for a healthy rollout (no rollback)."""
    total = 0.0
    prev = 0.0
    for frac in strategy.stages:
        total += stage_deploy_seconds(env, frac - prev)
        total += strategy.soak_ticks * env.tick_s
        prev = frac
    return total
