"""Automated strategy selection (paper §3.4.1, Fig. 7 decision tree).

Two selectors:
  * DecisionTreeSelector — the paper's Fig. 7 tree over model size, traffic
    criticality, risk tolerance, and spare capacity (the explainable
    baseline, and the teacher for DNN pretraining);
  * DNNSelector — the multi-stream DNN's strategy head, refined online from
    realized deployment outcomes (time, SLO impact, rollback events).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.orchestration.strategies import CATALOG, STRATEGY_NAMES


@dataclasses.dataclass(frozen=True)
class DeploymentContext:
    model_params_b: float            # billions
    traffic_rps: float
    slo_ms: float
    error_budget: float              # fraction of requests allowed to fail
    spare_capacity_frac: float       # free fleet fraction right now
    cost_sensitivity: float          # 0 = perf-first, 1 = cost-first
    is_critical: bool                # user-facing production traffic?
    # per-replica transport latency (ms) from the replica fabric's streamed
    # reports — how remote the fleet is.  0 for an in-process fleet.
    transport_ms: float = 0.0


class DecisionTreeSelector:
    """Fig. 7: size gate → criticality gate → capacity gate → cost gate,
    extended with a transport gate: when reaching a replica already costs a
    material slice of the SLO, strategies that double cross-fleet traffic
    (shadow mirroring, blue/green full-fleet flips) are off the table —
    in-place rolling/canary deploys touch each remote replica once."""

    def select(self, ctx: DeploymentContext) -> str:
        if not ctx.is_critical and ctx.traffic_rps < 10:
            # internal / low-traffic: speed over safety
            return "all_at_once"
        if ctx.transport_ms > 0.1 * ctx.slo_ms:
            return "canary_10" if ctx.is_critical else "rolling"
        if ctx.model_params_b >= 40:
            # huge models: capacity for blue/green rarely exists
            if ctx.spare_capacity_frac >= 0.10:
                return "canary_progressive"
            return "rolling"
        if ctx.error_budget < 0.001 and ctx.spare_capacity_frac >= 0.5:
            # strict budget + lots of headroom: shadow first
            return "shadow" if ctx.cost_sensitivity < 0.5 else "canary_progressive"
        if ctx.spare_capacity_frac >= 1.0 and ctx.cost_sensitivity < 0.3:
            return "blue_green"
        if ctx.is_critical:
            return "canary_10" if ctx.error_budget >= 0.001 else "canary_progressive"
        return "rolling"


class OutcomeStats:
    """Per-strategy EWMA of realized outcomes; lets the DNN selector and the
    adaptive optimizer rank strategies by evidence, not priors."""

    def __init__(self):
        self.deploy_s = {s: None for s in STRATEGY_NAMES}
        self.rollbacks = {s: 0 for s in STRATEGY_NAMES}
        self.runs = {s: 0 for s in STRATEGY_NAMES}

    def record(self, strategy: str, *, deploy_s: float, rolled_back: bool):
        prev = self.deploy_s[strategy]
        self.deploy_s[strategy] = (deploy_s if prev is None
                                   else 0.7 * prev + 0.3 * deploy_s)
        self.runs[strategy] += 1
        if rolled_back:
            self.rollbacks[strategy] += 1

    def rollback_rate(self, strategy: str) -> float:
        return self.rollbacks[strategy] / max(self.runs[strategy], 1)


class DNNSelector:
    """Strategy head of the multi-stream DNN + decision-tree fallback.

    Until the head has been trained on enough outcomes (min_trained), the
    tree decides and its choices are the training labels — the supervised
    pretraining path noted in DESIGN.md §10."""

    def __init__(self, agent, deploy_vec_fn, *, min_trained: int = 64):
        self.agent = agent            # shares the allocator's DQNAgent trunk
        self.deploy_vec_fn = deploy_vec_fn
        self.tree = DecisionTreeSelector()
        self.stats = OutcomeStats()
        self.n_labels = 0
        self.min_trained = min_trained
        self.labels: list[tuple[dict, int]] = []

    def select(self, ctx: DeploymentContext, streams) -> str:
        tree_choice = self.tree.select(ctx)
        self.labels.append((streams, STRATEGY_NAMES.index(tree_choice)))
        self.n_labels += 1
        if self.n_labels < self.min_trained:
            return tree_choice
        import jax.numpy as jnp
        from repro.core.dnn.model import MultiStreamDNN
        out, _ = MultiStreamDNN.apply(
            self.agent.params, self.agent.bn_state,
            {k: jnp.asarray(v) for k, v in streams.items()}, training=False)
        scores = np.asarray(out["strategy_logits"][0]).copy()
        # evidence penalty: strategies that rolled back get demoted
        for i, s in enumerate(STRATEGY_NAMES):
            scores[i] -= 4.0 * self.stats.rollback_rate(s)
        return STRATEGY_NAMES[int(np.argmax(scores))]
