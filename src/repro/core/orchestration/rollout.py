"""Rollout management with canary analysis and automatic rollback (§3.4.2).

The paper's RolloutManager:

    canary_metrics = await self.deploy_canary(deployment_config)
    if self.analyze_canary_health(canary_metrics):
        return await self.complete_rollout(deployment_config)
    else:
        return await self.initiate_rollback(deployment_config)

Implemented as a tick-driven state machine (the simulator advances time, so
"await" becomes state transitions — semantically identical, and testable).
Canary health is a proper statistical gate (paper: "sophisticated statistical
methods"):

  * latency: one-sided Welch t-test, canary vs control samples, α=0.01,
    plus a practical-significance guard (≥5% regression required to fail —
    pure statistical significance on huge samples must not block);
  * errors: one-sided binomial z-test on error counts;
  * resources: utilization regression beyond tolerance fails the gate.

Rollback restores the previous version on the already-provisioned slices
(fast path: weights still resident → stream only the delta).
"""
from __future__ import annotations

import dataclasses
import math
from enum import Enum

import numpy as np

from repro.core.orchestration.strategies import (
    CATALOG, DeployEnv, Strategy, stage_deploy_seconds,
)


class Phase(Enum):
    IDLE = "idle"
    DEPLOYING = "deploying"
    SOAKING = "soaking"
    COMPLETED = "completed"
    ROLLED_BACK = "rolled_back"


@dataclasses.dataclass
class CanarySample:
    latencies_ms: np.ndarray
    n_requests: int
    n_errors: int
    utilization: float


def welch_t_pvalue_one_sided(a: np.ndarray, b: np.ndarray) -> float:
    """P(mean(a) > mean(b) by chance) — small p ⇒ canary (a) worse."""
    na, nb = len(a), len(b)
    if na < 3 or nb < 3:
        return 1.0
    va, vb = a.var(ddof=1) + 1e-12, b.var(ddof=1) + 1e-12
    t = (a.mean() - b.mean()) / math.sqrt(va / na + vb / nb)
    df = (va / na + vb / nb) ** 2 / (
        (va / na) ** 2 / (na - 1) + (vb / nb) ** 2 / (nb - 1))
    # normal approximation of the t CDF is fine at the sample sizes involved
    return 0.5 * math.erfc(t / math.sqrt(2.0)) if df > 30 else \
        0.5 * math.erfc(t / math.sqrt(2.0) * (1 - 1 / (4 * df)))


def binomial_z_pvalue(err_c: int, n_c: int, err_b: int, n_b: int) -> float:
    """One-sided: canary error rate > baseline error rate?"""
    if n_c == 0 or n_b == 0:
        return 1.0
    p_pool = (err_c + err_b) / (n_c + n_b)
    se = math.sqrt(p_pool * (1 - p_pool) * (1 / n_c + 1 / n_b)) + 1e-12
    z = (err_c / n_c - err_b / n_b) / se
    return 0.5 * math.erfc(z / math.sqrt(2.0))


@dataclasses.dataclass
class HealthPolicy:
    alpha: float = 0.01
    min_latency_regression: float = 0.05     # practical significance
    max_error_rate_delta: float = 0.002
    max_util_regression: float = 0.15


class CanaryAnalyzer:
    def __init__(self, policy: HealthPolicy = HealthPolicy()):
        self.policy = policy

    def analyze(self, canary: CanarySample, control: CanarySample) -> dict:
        p = self.policy
        verdicts = {}
        lat_p = welch_t_pvalue_one_sided(canary.latencies_ms,
                                         control.latencies_ms)
        regression = (canary.latencies_ms.mean()
                      / max(control.latencies_ms.mean(), 1e-9) - 1.0)
        verdicts["latency_ok"] = not (lat_p < p.alpha
                                      and regression > p.min_latency_regression)
        err_p = binomial_z_pvalue(canary.n_errors, canary.n_requests,
                                  control.n_errors, control.n_requests)
        delta = (canary.n_errors / max(canary.n_requests, 1)
                 - control.n_errors / max(control.n_requests, 1))
        verdicts["errors_ok"] = not (err_p < p.alpha
                                     and delta > p.max_error_rate_delta)
        verdicts["resources_ok"] = (
            canary.utilization <= control.utilization * (1 + p.max_util_regression)
            + 0.05)
        verdicts["healthy"] = all(
            verdicts[k] for k in ("latency_ok", "errors_ok", "resources_ok"))
        verdicts["latency_p"] = lat_p
        verdicts["error_p"] = err_p
        return verdicts


@dataclasses.dataclass
class RolloutState:
    phase: Phase = Phase.IDLE
    stage_idx: int = 0
    soak_left: int = 0
    traffic_frac: float = 0.0
    elapsed_s: float = 0.0
    rolled_back: bool = False
    health_log: list = dataclasses.field(default_factory=list)


class RolloutManager:
    """Tick-driven rollout with per-stage canary gates and auto-rollback."""

    def __init__(self, strategy: Strategy | str, env: DeployEnv,
                 analyzer: CanaryAnalyzer | None = None):
        self.strategy = (CATALOG[strategy] if isinstance(strategy, str)
                         else strategy)
        self.env = env
        self.analyzer = analyzer or CanaryAnalyzer()
        self.state = RolloutState()

    def start(self):
        s = self.state
        s.phase = Phase.DEPLOYING
        s.stage_idx = 0
        s.elapsed_s = stage_deploy_seconds(self.env,
                                           self.strategy.stages[0])
        s.traffic_frac = self.strategy.stages[0]
        s.soak_left = self.strategy.soak_ticks
        if s.soak_left:
            s.phase = Phase.SOAKING
        else:
            self._advance_or_finish()
        return s

    def tick(self, canary: CanarySample | None = None,
             control: CanarySample | None = None):
        """Advance one tick; during soak, gate on canary health."""
        s = self.state
        if s.phase != Phase.SOAKING:
            return s
        s.elapsed_s += self.env.tick_s
        if canary is not None and control is not None:
            verdict = self.analyzer.analyze(canary, control)
            s.health_log.append(verdict)
            if not verdict["healthy"]:
                return self._rollback()
        s.soak_left -= 1
        if s.soak_left <= 0:
            self._advance_or_finish()
        return s

    def _advance_or_finish(self):
        s = self.state
        if s.stage_idx + 1 >= len(self.strategy.stages):
            s.phase = Phase.COMPLETED
            s.traffic_frac = 1.0
            return s
        prev = self.strategy.stages[s.stage_idx]
        s.stage_idx += 1
        frac = self.strategy.stages[s.stage_idx]
        s.elapsed_s += stage_deploy_seconds(self.env, frac - prev)
        s.traffic_frac = frac
        s.soak_left = self.strategy.soak_ticks
        s.phase = Phase.SOAKING if s.soak_left else Phase.COMPLETED
        if s.phase == Phase.COMPLETED:
            s.traffic_frac = 1.0
        return s

    def _rollback(self):
        s = self.state
        # previous weights still resident on the untouched fleet: only the
        # canary slices restore — a fraction of one stage's deploy time
        s.elapsed_s += 0.5 * stage_deploy_seconds(
            self.env, self.strategy.stages[s.stage_idx])
        s.phase = Phase.ROLLED_BACK
        s.rolled_back = True
        s.traffic_frac = 0.0
        return s
