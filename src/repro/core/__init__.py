"""THE PAPER: the DNN-powered MLOps control plane.

Subpackages mirror the paper's §3: dnn (multi-stream optimization engine),
allocation (RL predictive allocator + workload forecaster), scaling
(DynamicScaler), orchestration (strategy catalog / selection / rollout with
canary analysis), monitoring (collection, anomaly detection, adaptation).
"""
