"""Training loop + feature-importance analysis for the multi-stream DNN.

Supervised path (paper §3.2): regress the alloc head onto realized next-window
resource utilization / required replicas and classify the retrospectively-best
deployment strategy; the Q head is trained by the DQN (core/allocation/rl.py)
sharing the same trunk.

Feature importance (paper §4.4): permutation importance over the four metric
groups (resource-utilization / performance / workload / network), evaluated
as the increase in validation loss when a group's channels are shuffled.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dnn.model import DNNConfig, MultiStreamDNN
from repro.optim import adamw, apply_updates


def supervised_loss(params, state, batch, *, training=True):
    out, new_state = MultiStreamDNN.apply(params, state, batch["streams"],
                                          training=training)
    # Huber on allocation regression
    err = out["alloc"] - batch["alloc_target"]
    huber = jnp.where(jnp.abs(err) < 1.0, 0.5 * err ** 2, jnp.abs(err) - 0.5)
    alloc_loss = jnp.mean(huber)
    # CE on strategy classification
    logp = jax.nn.log_softmax(out["strategy_logits"])
    strat_loss = -jnp.mean(
        jnp.take_along_axis(logp, batch["strategy_target"][:, None], axis=1))
    loss = alloc_loss + strat_loss
    return loss, (new_state, {"alloc_loss": alloc_loss,
                              "strategy_loss": strat_loss})


def make_sgd_step(lr: float = 1e-3):
    opt_init, opt_update = adamw(lr, weight_decay=1e-4)

    @jax.jit
    def step(params, state, opt_state, batch):
        (loss, (new_state, metrics)), grads = jax.value_and_grad(
            supervised_loss, has_aux=True)(params, state, batch)
        updates, opt_state = opt_update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, new_state, opt_state, loss, metrics

    return opt_init, step


def fit(params, state, dataset, *, epochs: int = 5, lr: float = 1e-3,
        batch_size: int = 64, seed: int = 0, log_every: int = 0):
    """dataset: dict of stacked numpy arrays (streams + targets)."""
    opt_init, step = make_sgd_step(lr)
    opt_state = opt_init(params)
    n = len(dataset["alloc_target"])
    rng = np.random.default_rng(seed)
    losses = []
    # clamp the batch to the dataset: a short recorded trace (n < batch_size)
    # must still take one full-dataset step per epoch — the unclamped range
    # was empty, silently performing ZERO optimizer steps
    bs = max(1, min(batch_size, n))
    for ep in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - bs + 1, bs):
            idx = order[i:i + bs]
            batch = {
                "streams": {k: jnp.asarray(v[idx])
                            for k, v in dataset["streams"].items()},
                "alloc_target": jnp.asarray(dataset["alloc_target"][idx]),
                "strategy_target": jnp.asarray(dataset["strategy_target"][idx]),
            }
            params, state, opt_state, loss, _ = step(params, state, opt_state,
                                                     batch)
            losses.append(float(loss))
        if log_every and (ep % log_every == 0):
            print(f"epoch {ep}: loss={np.mean(losses[-8:]):.4f}")
    return params, state, losses


# ---------------------------------------------------------------------------
# permutation feature importance (paper §4.4.1)
# ---------------------------------------------------------------------------

# channel indices within the streams, by paper metric group
FEATURE_GROUPS = {
    "resource_utilization": ("resource", (0, 1, 2, 3)),   # flop/hbm/ici/mem
    "performance": ("perf", (0, 1, 2, 3)),                # latencies/tp/err
    "workload_patterns": ("perf", (4,)),                  # rps channel
    "network": ("resource", (4, 5)),                      # queue/replica frac
}


def _eval_loss(params, state, dataset):
    batch = {
        "streams": {k: jnp.asarray(v) for k, v in dataset["streams"].items()},
        "alloc_target": jnp.asarray(dataset["alloc_target"]),
        "strategy_target": jnp.asarray(dataset["strategy_target"]),
    }
    loss, _ = supervised_loss(params, state, batch, training=False)
    return float(loss)


def permutation_importance(params, state, dataset, *, seed: int = 0):
    """→ {group: normalized importance} (sums to 1)."""
    rng = np.random.default_rng(seed)
    base = _eval_loss(params, state, dataset)
    raw = {}
    for group, (stream, chans) in FEATURE_GROUPS.items():
        ds = {k: (v.copy() if k != "streams" else None)
              for k, v in dataset.items()}
        streams = {k: v.copy() for k, v in dataset["streams"].items()}
        perm = rng.permutation(len(streams[stream]))
        arr = streams[stream].copy()
        arr[..., list(chans)] = arr[perm][..., list(chans)]
        streams[stream] = arr
        ds["streams"] = streams
        raw[group] = max(_eval_loss(params, state, ds) - base, 0.0)
    total = sum(raw.values()) or 1.0
    return {k: v / total for k, v in raw.items()}
