from repro.core.dnn.model import DNNConfig, MultiStreamDNN
from repro.core.dnn.features import (
    PERF_KEYS, RESOURCE_KEYS, RunningNorm, StreamBuilder, deploy_vector,
)
from repro.core.dnn.train import (
    FEATURE_GROUPS, fit, make_sgd_step, permutation_importance,
    supervised_loss,
)
from repro.core.dnn.traces import (
    TraceRecorder, fill_replay, pretrain_on_trace, replay_streams,
    supervised_dataset, transitions,
)
