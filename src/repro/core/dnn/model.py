"""The paper's multi-stream DNN optimizer (§3.2.1), in pure JAX.

Three dedicated pathways process heterogeneous operational data before
fusion (paper Fig. 5):

  resource-metrics stream   (B, T, F_r) — chip FLOP-util, HBM-BW util, ICI
      util, memory, queue depth …      → temporal Conv1D ×2 (+ max/avg pool)
  performance stream        (B, T, F_p) — latency p50/p95, throughput, error
      rate …                           → GRU, final hidden state
  deployment-params stream  (B, F_d)   — model size, arch family one-hot,
      mesh shape, region, SLO …        → Dense ×2 + BatchNorm

Fusion trunk: concat → MLP(128) → shared features.  Decision heads:
  alloc    — regression: forecast per-resource utilization + required replicas
  strategy — classification over the deployment-strategy catalog (§3.4.1)
  q        — Q-values over discrete scaling actions (the RL allocator §3.3.1)

The paper gives the structure but not layer sizes; sizes here are fixed small
(CPU-trainable) — recorded in DESIGN.md §10.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.dnn.features import PERF_KEYS, RESOURCE_KEYS
from repro.nn import MLP, BatchNorm, Conv1D, GRU, Linear


@dataclasses.dataclass(frozen=True)
class DNNConfig:
    # stream widths default to the feature registry — adding a channel to
    # features.py widens every freshly-built model with it
    n_resource_features: int = len(RESOURCE_KEYS)
    n_perf_features: int = len(PERF_KEYS)
    n_deploy_features: int = 12
    window: int = 32              # T: sliding-window length fed to the nets
    conv_channels: int = 32
    gru_hidden: int = 32
    deploy_hidden: int = 32
    trunk_hidden: int = 128
    feature_dim: int = 64
    n_resources: int = 4          # alloc head: cpu/hbm/ici/replicas
    n_strategies: int = 6         # strategy head: catalog size
    n_actions: int = 7            # q head: replica deltas {-4,-2,-1,0,1,2,4}


class MultiStreamDNN:
    @staticmethod
    def init(key, cfg: DNNConfig):
        ks = jax.random.split(key, 10)
        params = {
            # resource stream: two temporal convs
            "conv1": Conv1D.init(ks[0], cfg.n_resource_features,
                                 cfg.conv_channels, 5),
            "conv2": Conv1D.init(ks[1], cfg.conv_channels, cfg.conv_channels, 3),
            # performance stream: GRU
            "gru": GRU.init(ks[2], cfg.n_perf_features, cfg.gru_hidden),
            # deployment stream: dense + BN ×2
            "dep1": Linear.init(ks[3], cfg.n_deploy_features, cfg.deploy_hidden),
            "bn1": BatchNorm.init(ks[4], cfg.deploy_hidden),
            "dep2": Linear.init(ks[5], cfg.deploy_hidden, cfg.deploy_hidden),
            "bn2": BatchNorm.init(ks[6], cfg.deploy_hidden),
            # fusion trunk
            "trunk": MLP.init(ks[7], (2 * cfg.conv_channels + cfg.gru_hidden
                                      + cfg.deploy_hidden,
                                      cfg.trunk_hidden, cfg.feature_dim)),
            # heads
            "alloc": Linear.init(ks[8], cfg.feature_dim, cfg.n_resources),
            "strategy": Linear.init(ks[9], cfg.feature_dim, cfg.n_strategies),
            "q": Linear.init(jax.random.fold_in(key, 99), cfg.feature_dim,
                             cfg.n_actions),
        }
        state = {"bn1": BatchNorm.init_state(cfg.deploy_hidden),
                 "bn2": BatchNorm.init_state(cfg.deploy_hidden)}
        return params, state

    @staticmethod
    def features(params, state, streams, *, training: bool = False):
        """streams = {"resource": (B,T,F_r), "perf": (B,T,F_p),
        "deploy": (B,F_d)} → ((B, feature_dim), new_state)."""
        res, perf, dep = (streams["resource"], streams["perf"],
                          streams["deploy"])
        # resource: conv → relu → conv → relu → global max+avg pool over T
        h = jax.nn.relu(Conv1D.apply(params["conv1"], res, causal=True))
        h = jax.nn.relu(Conv1D.apply(params["conv2"], h, causal=True))
        r_feat = jnp.concatenate([jnp.max(h, axis=1), jnp.mean(h, axis=1)],
                                 axis=-1)
        # performance: GRU final hidden
        p_final, _ = GRU.apply(params["gru"], perf)
        # deployment: dense + BN ×2
        d, st1 = BatchNorm.apply(params["bn1"],
                                 state["bn1"],
                                 Linear.apply(params["dep1"], dep),
                                 training=training)
        d = jax.nn.relu(d)
        d, st2 = BatchNorm.apply(params["bn2"], state["bn2"],
                                 Linear.apply(params["dep2"], d),
                                 training=training)
        d = jax.nn.relu(d)
        fused = jnp.concatenate([r_feat, p_final, d], axis=-1)
        feat = MLP.apply(params["trunk"], fused, act=jax.nn.relu,
                         final_act=jax.nn.relu)
        return feat, {"bn1": st1, "bn2": st2}

    @staticmethod
    def apply(params, state, streams, *, training: bool = False):
        """→ (outputs dict, new_state)."""
        feat, new_state = MultiStreamDNN.features(params, state, streams,
                                                  training=training)
        out = {
            "alloc": Linear.apply(params["alloc"], feat),
            "strategy_logits": Linear.apply(params["strategy"], feat),
            "q": Linear.apply(params["q"], feat),
            "features": feat,
        }
        return out, new_state
