"""Fleet-trace recording and replay — the paper's learning loop, closed.

The serving fleet emits everything §3.2's feature streams ask for (collector
aggregates, transport_ms, evictions, anomaly flags, paged-pool prefix
counters); the DNN/DQN trained only on simulated features.  This module is
the bridge:

  * ``TraceRecorder`` — one dict per control tick, appended by
    ``run_closed_loop`` (serving/closed_loop.py) when recording is on;
    JSONL-serializable, round-trips through ``save``/``load``.
  * ``replay_streams`` — re-runs a recorded trace through a fresh
    ``StreamBuilder`` (the SAME windowing + running-norm path the live
    allocator feeds ``agent.observe``), yielding one stream snapshot per
    tick — shapes identical to live ``alloc.decide`` inputs.
  * ``supervised_dataset`` — (streams, alloc_target, strategy_target)
    stacks shaped for ``core/dnn/train.fit``: the alloc head regresses the
    realized NEXT-tick utilization + replica fraction; the strategy head is
    labeled by the decision-tree selector evaluated retrospectively.
  * ``transitions`` / ``fill_replay`` — (s, a, r, s2, done) tuples shaped
    exactly like the live ``PredictiveAllocator.learn`` path (reward from
    the next tick's realized metrics, credited to the recorded action),
    pushed into a ``DQNAgent``'s ReplayBuffer.
  * ``pretrain_on_trace`` — the offline training recipe: supervised
    ``train.fit`` on the trace (shared trunk), Q-head imitation of the
    recorded planner actions (cold start, paper §5.3), then DQN replay —
    after which the allocator can act as the scaler in ``mode="hybrid"``.
"""
from __future__ import annotations

import json

import numpy as np

from repro.core.dnn.features import StreamBuilder
from repro.core.dnn.train import fit
from repro.core.orchestration.selector import (
    DecisionTreeSelector, DeploymentContext,
)
from repro.core.orchestration.strategies import STRATEGY_NAMES


class TraceRecorder:
    """Accumulates per-tick fleet records (plain dicts of scalars/lists).

    ``record`` copies the dict so later mutation by the loop can't reach
    back into the trace; ``save``/``load`` round-trip through JSONL — one
    record per line, human-greppable, append-friendly."""

    def __init__(self):
        self.records: list[dict] = []

    def record(self, rec: dict):
        self.records.append(dict(rec))

    def __len__(self):
        return len(self.records)

    def save(self, path):
        with open(path, "w") as f:
            for rec in self.records:
                f.write(json.dumps(rec, sort_keys=True) + "\n")

    @classmethod
    def load(cls, path) -> "TraceRecorder":
        out = cls()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.records.append(json.loads(line))
        return out


def replay_streams(records, deploy_vec, *, window: int = 32) -> list[dict]:
    """→ one ``{"resource","perf","deploy"}`` snapshot per tick, each shaped
    (1,T,F)/(1,F) — exactly what the live allocator's StreamBuilder hands
    ``agent.q_values``/``agent.observe`` after observing that tick."""
    sb = StreamBuilder(window=window)
    out = []
    for rec in records:
        sb.push(rec)
        out.append(sb.streams(np.asarray(deploy_vec, np.float32)))
    return out


def _stack(snapshots, idx) -> dict:
    return {k: np.concatenate([snapshots[i][k] for i in idx], axis=0)
            for k in ("resource", "perf", "deploy")}


def _strategy_label(rec: dict, *, model_params_b: float, slo_ms: float) -> int:
    """Retrospective strategy class: the decision-tree selector evaluated on
    the tick's realized operating point (the repo's strategy oracle)."""
    ctx = DeploymentContext(
        model_params_b=model_params_b,
        traffic_rps=float(rec.get("rps", 0.0)),
        slo_ms=slo_ms,
        error_budget=0.01,
        spare_capacity_frac=max(1.0 - float(rec.get("flop_util", 0.0)), 0.0),
        cost_sensitivity=0.5,
        is_critical=True,
        transport_ms=float(rec.get("transport_ms", 0.0)),
    )
    return STRATEGY_NAMES.index(DecisionTreeSelector().select(ctx))


def supervised_dataset(records, deploy_vec, *, window: int = 32,
                       slo_ms: float = 200.0,
                       model_params_b: float = 1.0) -> dict:
    """Trace → ``train.fit`` dataset.  Row t pairs the streams AFTER
    observing tick t with tick t+1's realized outcome: the alloc head
    learns to forecast next-window (flop, hbm, ici, replicas_frac); the
    strategy head the retrospectively-selected deployment strategy."""
    if len(records) < 2:
        raise ValueError("supervised_dataset needs >= 2 recorded ticks")
    snaps = replay_streams(records, deploy_vec, window=window)
    idx = range(len(records) - 1)
    alloc_t = np.asarray(
        [[float(records[t + 1].get(k, 0.0))
          for k in ("flop_util", "hbm_util", "ici_util", "replicas_frac")]
         for t in idx], np.float32)
    strat_t = np.asarray(
        [_strategy_label(records[t + 1], model_params_b=model_params_b,
                         slo_ms=slo_ms) for t in idx], np.int32)
    return {"streams": _stack(snaps, idx), "alloc_target": alloc_t,
            "strategy_target": strat_t}


def action_index(delta: float) -> int:
    """Nearest discrete ACTIONS index to a recorded replica delta."""
    # allocation.rl imports dnn.model, so dnn/__init__ can't import rl at
    # module scope without a cycle — resolve it at call time instead
    from repro.core.allocation.rl import ACTIONS
    return int(np.argmin([abs(a - delta) for a in ACTIONS]))


def transitions(records, deploy_vec, *, window: int = 32,
                slo_ms: float = 200.0, cost_scale: float = 1.0,
                w_util: float = 1.0, w_lat: float = 1.0,
                w_cost: float = 1.0) -> list[tuple]:
    """Trace → DQN transitions, mirroring the live ``learn()`` chain: the
    action recorded at tick t is credited with the reward realized at tick
    t+1, between the stream snapshots after observing each tick."""
    from repro.core.allocation.rl import reward_fn   # cycle: see action_index
    snaps = replay_streams(records, deploy_vec, window=window)
    out = []
    for t in range(len(records) - 1):
        nxt = records[t + 1]
        r = reward_fn(
            utilization=float(nxt.get("flop_util", 0.0)),
            latency_ms=float(nxt.get("latency_p95", 0.0)),
            slo_ms=slo_ms,
            cost_per_tick=float(nxt.get("cost_per_tick", 0.0)),
            cost_scale=cost_scale,
            w_util=w_util, w_lat=w_lat, w_cost=w_cost)
        a = action_index(float(records[t].get("action_delta", 0.0)))
        done = t == len(records) - 2
        out.append((snaps[t], a, r, snaps[t + 1], done))
    return out


def fill_replay(agent, trans) -> int:
    """Push recorded transitions into the agent's ReplayBuffer (no training
    step — use ``agent.train_offline`` afterwards).  → transitions pushed."""
    for s, a, r, s2, done in trans:
        agent.buffer.push(s, a, r, s2, done)
    return len(trans)


def pretrain_on_trace(alloc, records, *, epochs: int = 20,
                      imitation_epochs: int = 30, dqn_steps: int = 60,
                      lr: float = 1e-3, seed: int = 0,
                      warm_streams: bool = True) -> dict:
    """Offline-train a ``PredictiveAllocator`` on a recorded fleet trace.

    Order matters: supervised ``fit`` shapes the shared trunk (alloc +
    strategy heads), DQN replay fits the Q head to the recorded rewards,
    and Q-head imitation of the recorded (planner) actions runs LAST so the
    cold-start policy the hybrid mode acts with is anchored to the planner
    — learned deviations then come from the value estimates, inside the
    safety envelope.  ``warm_streams`` additionally replays the trace into
    the allocator's live StreamBuilder so its running normalization matches
    what the nets were trained under.  → loss curves per phase."""
    agent = alloc.agent
    c = alloc.constraints
    kw = dict(window=alloc.dnn_cfg.window, slo_ms=c.slo_ms)
    ds = supervised_dataset(
        records, alloc.deploy_vec,
        model_params_b=float(10.0 ** (2.0 * alloc.deploy_vec[0])), **kw)
    agent.params, agent.bn_state, sup_losses = fit(
        agent.params, agent.bn_state, ds, epochs=epochs, lr=lr, seed=seed)
    trans = transitions(
        records, alloc.deploy_vec,
        cost_scale=c.max_replicas * c.cost_per_replica,
        w_util=alloc.cfg.w_util, w_lat=alloc.cfg.w_lat,
        w_cost=alloc.cfg.w_cost, **kw)
    fill_replay(agent, trans)
    dqn_losses = agent.train_offline(dqn_steps)
    snaps = replay_streams(records, alloc.deploy_vec,
                           window=alloc.dnn_cfg.window)
    acts = [action_index(float(r.get("action_delta", 0.0))) for r in records]
    imit_losses = agent.imitate(_stack(snaps, range(len(records))),
                                acts, epochs=imitation_epochs, lr=lr)
    # a pretrained agent is already warm: keep fine-tuning from the first
    # live tick instead of sitting out the online `warmup` fill all over
    # again (the buffer keeps the recorded transitions it trained on)
    agent.cfg.warmup = min(agent.cfg.warmup, max(agent.buffer.n, 1))
    if warm_streams:
        for rec in records:
            alloc.streams.push(rec)
    return {"supervised": sup_losses, "dqn": dqn_losses,
            "imitation": imit_losses, "transitions": len(trans)}
