"""Feature engineering for the multi-stream DNN (paper §3.2.2).

Raw monitoring records (dicts of scalars per tick) are turned into the three
model streams: sliding windows with running-statistics normalization for the
temporal streams, and a static vector (normalized against catalog ranges) for
deployment parameters.
"""
from __future__ import annotations

import numpy as np

RESOURCE_KEYS = ("flop_util", "hbm_util", "ici_util", "mem_frac",
                 "queue_depth", "replicas_frac",
                 # paged-pool cache efficiency (0 on dense fleets): shared-
                 # prefix admissions and the prompt tokens they saved
                 "prefix_hits", "tokens_shared",
                 # capacity volatility: spot replicas reclaimed this tick
                 # (the collector's fleet event channel; 0 on homogeneous
                 # fleets) — the model sees supply disappearing, not just
                 # the latency it causes
                 "preemptions")
PERF_KEYS = ("latency_p50", "latency_p95", "throughput", "error_rate",
             "rps",
             # speculative-decode acceptance this window (0 with spec off)
             "accept_rate",
             # per-tier SLO pressure (0 on single-tier fleets): the DNN
             # sees interactive-lane risk separately from batch queueing
             "latency_p95_interactive", "latency_p95_batch",
             # placement pressure this tick (fleet event channels, 0 when
             # unprofiled/region-less): interactive work forced onto
             # volatile capacity, and forced out of its origin region
             "tier_spills", "region_spills")


class RunningNorm:
    """Streaming mean/std (Welford) used to normalize each metric channel."""

    def __init__(self, n: int):
        self.n = 0
        self.mean = np.zeros(n)
        self.m2 = np.ones(n)

    def update(self, x: np.ndarray):
        self.n += 1
        d = x - self.mean
        self.mean += d / self.n
        self.m2 += d * (x - self.mean)

    def normalize(self, x: np.ndarray) -> np.ndarray:
        std = np.sqrt(self.m2 / max(self.n, 1)) + 1e-6
        return (x - self.mean) / std


class StreamBuilder:
    """Maintains sliding windows over monitoring ticks → DNN input streams."""

    def __init__(self, window: int = 32):
        self.window = window
        self.res_hist: list[np.ndarray] = []
        self.perf_hist: list[np.ndarray] = []
        self.res_norm = RunningNorm(len(RESOURCE_KEYS))
        self.perf_norm = RunningNorm(len(PERF_KEYS))

    def push(self, record: dict):
        r = np.array([float(record.get(k, 0.0)) for k in RESOURCE_KEYS])
        p = np.array([float(record.get(k, 0.0)) for k in PERF_KEYS])
        self.res_norm.update(r)
        self.perf_norm.update(p)
        self.res_hist.append(r)
        self.perf_hist.append(p)
        if len(self.res_hist) > 4 * self.window:
            del self.res_hist[:-2 * self.window]
            del self.perf_hist[:-2 * self.window]

    def streams(self, deploy_vec: np.ndarray):
        """→ {"resource": (1,T,F_r), "perf": (1,T,F_p), "deploy": (1,F_d)}."""
        T = self.window
        res = np.stack(self.res_hist[-T:]) if self.res_hist else np.zeros((1, len(RESOURCE_KEYS)))
        perf = np.stack(self.perf_hist[-T:]) if self.perf_hist else np.zeros((1, len(PERF_KEYS)))
        res = self.res_norm.normalize(res)
        perf = self.perf_norm.normalize(perf)
        if len(res) < T:    # left-pad with the earliest row
            res = np.concatenate([np.repeat(res[:1], T - len(res), 0), res])
            perf = np.concatenate([np.repeat(perf[:1], T - len(perf), 0), perf])
        return {
            "resource": res[None].astype(np.float32),
            "perf": perf[None].astype(np.float32),
            "deploy": deploy_vec[None].astype(np.float32),
        }


def deploy_vector(*, model_params_b: float, family: str, mesh_model: int,
                  mesh_data: int, region_idx: int, slo_ms: float,
                  cost_weight: float, n_deploy_features: int = 12) -> np.ndarray:
    """Static deployment-parameter featurization (normalized)."""
    families = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")
    v = np.zeros(n_deploy_features, np.float32)
    v[0] = np.log10(max(model_params_b, 0.01)) / 2.0
    v[1] = mesh_model / 64.0
    v[2] = mesh_data / 64.0
    v[3] = region_idx / 8.0
    v[4] = slo_ms / 1000.0
    v[5] = cost_weight
    if family in families:
        v[6 + families.index(family)] = 1.0
    return v
