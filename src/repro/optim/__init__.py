from repro.optim.adamw import adamw, sgd, apply_updates, global_norm, clip_by_global_norm
from repro.optim.schedule import (
    constant_schedule,
    cosine_schedule,
    linear_warmup_cosine,
    wsd_schedule,
)
from repro.optim.compression import (
    compress_int8,
    decompress_int8,
    error_feedback_compress,
    init_error_feedback,
)

__all__ = [
    "adamw",
    "sgd",
    "apply_updates",
    "global_norm",
    "clip_by_global_norm",
    "constant_schedule",
    "cosine_schedule",
    "linear_warmup_cosine",
    "wsd_schedule",
    "compress_int8",
    "decompress_int8",
    "error_feedback_compress",
    "init_error_feedback",
]
