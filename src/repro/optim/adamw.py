"""AdamW (decoupled weight decay) in optax style: init/update pairs.

State and moments are kept in fp32 regardless of param dtype so that bf16
training remains stable; the update is cast back to the param dtype at apply
time.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: object       # pytree like params (fp32)
    nu: object       # pytree like params (fp32)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def adamw(lr, *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, mask=None):
    """lr: float or callable(step)->float. mask: pytree of bools — True where
    weight decay applies (defaults to ndim>=2 leaves, i.e. matrices only)."""

    def init(params):
        f32 = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(f32, params),
                          nu=jax.tree.map(f32, params))

    def update(grads, state: AdamWState, params):
        step = state.step + 1
        lr_t = lr(step) if callable(lr) else lr

        if mask is None:
            decay_mask = jax.tree.map(lambda p: p.ndim >= 2, params)
        else:
            decay_mask = mask

        def upd(g, m, v, p, do_decay):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / (1 - b1 ** step.astype(jnp.float32))
            vhat = v / (1 - b2 ** step.astype(jnp.float32))
            u = mhat / (jnp.sqrt(vhat) + eps)
            if do_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype), m, v

        flat_u, flat_m, flat_v = [], [], []
        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_m = jax.tree.leaves(state.mu)
        leaves_v = jax.tree.leaves(state.nu)
        leaves_p = jax.tree.leaves(params)
        leaves_mask = jax.tree.leaves(decay_mask)
        for g, m, v, p, dm in zip(leaves_g, leaves_m, leaves_v, leaves_p, leaves_mask):
            u, m2, v2 = upd(g, m, v, p, dm)
            flat_u.append(u)
            flat_m.append(m2)
            flat_v.append(v2)
        updates = jax.tree.unflatten(treedef, flat_u)
        new_state = AdamWState(step=step,
                               mu=jax.tree.unflatten(treedef, flat_m),
                               nu=jax.tree.unflatten(treedef, flat_v))
        return updates, new_state

    return init, update


def sgd(lr, *, momentum: float = 0.0):
    def init(params):
        if momentum:
            return {"step": jnp.zeros((), jnp.int32),
                    "mom": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        lr_t = lr(state["step"] + 1) if callable(lr) else lr
        if momentum:
            new_mom = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32), state["mom"], grads)
            updates = jax.tree.map(lambda m, p: (-lr_t * m).astype(p.dtype), new_mom, params)
            return updates, {"step": state["step"] + 1, "mom": new_mom}
        updates = jax.tree.map(lambda g, p: (-lr_t * g).astype(p.dtype), grads, params)
        return updates, {"step": state["step"] + 1}

    return init, update


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
