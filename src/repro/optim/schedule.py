"""LR schedules as callables(step) -> lr."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak_lr: float, total_steps: int, *, final_frac: float = 0.1):
    def sched(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return peak_lr * (final_frac + (1 - final_frac) * cos)

    return sched


def linear_warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                         *, final_frac: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)

    return sched


def wsd_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                 *, decay_frac: float = 0.1):
    """Warmup-stable-decay (used by several of the assigned archs' recipes)."""
    decay_steps = int(total_steps * decay_frac)
    stable_end = total_steps - decay_steps

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        decay = peak_lr * jnp.clip((total_steps - step) / max(decay_steps, 1), 0.0, 1.0)
        lr = jnp.where(step < warmup_steps, warm,
                       jnp.where(step < stable_end, peak_lr, decay))
        return lr

    return sched
