"""Gradient compression for cross-pod all-reduce (distributed-optimization trick).

Error-feedback int8: each step compresses (grad + residual) to per-tensor-scaled
int8, communicates the int8 payload, and carries the quantization error into
the next step's residual. This keeps convergence close to fp32 SGD/Adam while
cutting DCI (inter-pod) gradient traffic 4x vs bf16 / 8x vs fp32.

The compress/decompress pair is pure and jit-safe so it can live inside the
pjit'd train step; the pod-axis psum is then performed on the decompressed
fp32 (hierarchical: in-pod reduce first at full precision, cross-pod on the
compressed stream — see launch/train.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def error_feedback_compress(grads, residuals):
    """Returns (compressed pytree of (q, scale), new_residuals).

    decompress(q, scale) + residual' == grad + residual  (up to clipping).
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, scale = compress_int8(corrected)
        deq = decompress_int8(q, scale)
        new_r = corrected - deq
        return (q, scale), new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    comp, new_res = [], []
    for g, r in zip(flat_g, flat_r):
        c, nr = one(g, r)
        comp.append(c)
        new_res.append(nr)
    return jax.tree.unflatten(treedef, comp), jax.tree.unflatten(treedef, new_res)


def decompress_tree(compressed, dtype=jnp.float32):
    """Inverse of the compress step over a pytree of (q, scale) tuples."""
    def is_leaf(x):
        return isinstance(x, tuple) and len(x) == 2 and hasattr(x[0], "dtype")

    return jax.tree.map(lambda c: decompress_int8(c[0], c[1], dtype), compressed,
                        is_leaf=is_leaf)
