"""Roofline-grounded serving performance model (the data-plane stand-in the
control plane optimizes against).

A *replica* is one model-parallel group (the "model" mesh axis = 16 chips);
the dry-run's decode_32k cell is exactly 16 such replicas (data axis), so
per-replica numbers fall straight out of the measured cell:

  slots/replica      = global_batch / data_axis
  decode step time   = max(compute, memory, collective roofline terms)
  tokens/s/replica   = slots / step_time

Request latency = TTFT (prefill, scaled by prompt/32k) + gen_len·step +
M/M/c queueing wait at the current arrival rate; overload ⇒ queue growth ⇒
timeouts counted as errors.  All knobs the paper's experiments vary (RPS,
replicas, batch slots) are explicit arguments.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.sim.roofline_db import RooflineDB


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Request shape shared by the queueing model AND the real data plane:
    repro/serving/workload.py builds actual engine Requests from the same
    spec the planner's perf model is parameterized by, so closed-loop runs
    (examples/serve_autoscale.py) optimize against the workload they serve."""
    prompt_len: int = 1024
    gen_len: int = 128
    timeout_factor: float = 4.0      # × SLO before a request is dropped


@dataclasses.dataclass(frozen=True)
class ServiceProfile:
    """Per-replica capability derived from the roofline DB."""
    arch: str
    chips_per_replica: int
    slots: int                       # concurrent decode slots per replica
    decode_step_s: float             # one token for all slots
    prefill_32k_s: float             # whole-replica prefill of 32k tokens
    bottleneck: str

    @classmethod
    def from_db(cls, db: RooflineDB, arch: str, *, data_axis: int = 16,
                model_axis: int = 16) -> "ServiceProfile":
        dec = db.terms(arch, "decode_32k")
        pre = db.terms(arch, "prefill_32k")
        from repro.models import SHAPES
        slots = SHAPES["decode_32k"].global_batch // data_axis
        # the prefill_32k cell runs global_batch prompts across data_axis
        # replicas in step_time ⇒ one replica prefills (global_batch/data_axis)
        # 32k-prompts per step ⇒ a single 32k prompt ≈ step_time / that.
        per_replica_batch = SHAPES["prefill_32k"].global_batch / data_axis
        return cls(arch=arch, chips_per_replica=model_axis, slots=slots,
                   decode_step_s=dec.step_time,
                   prefill_32k_s=pre.step_time / per_replica_batch,
                   bottleneck=dec.bottleneck)

    def tokens_per_s(self) -> float:
        return self.slots / self.decode_step_s

    def relative_speed(self, baseline: "ServiceProfile") -> float:
        """Decode throughput relative to another service — the seed for a
        heterogeneous fleet's ReplicaProfile.speed (serving/profiles.py)."""
        return self.tokens_per_s() / max(baseline.tokens_per_s(), 1e-12)

    def requests_per_s(self, w: WorkloadSpec) -> float:
        """Steady-state request service rate per replica."""
        t_req = self.request_service_s(w)
        return self.slots / t_req

    def prefill_s(self, prompt_len: int) -> float:
        return self.prefill_32k_s * prompt_len / 32768.0

    def request_service_s(self, w: WorkloadSpec) -> float:
        return self.prefill_s(w.prompt_len) + w.gen_len * self.decode_step_s


def mmc_wait_s(lam: float, mu: float, c: int) -> float:
    """Erlang-C mean wait.  lam: arrivals/s, mu: per-server rate, c servers."""
    if c <= 0 or mu <= 0:
        return float("inf")
    if lam <= 0.0:
        # an empty system has no queue — and the large-c normal
        # approximation below divides by sqrt(a)=0 (a diurnal trough in a
        # big region used to crash the multi-region benchmark here)
        return 0.0
    rho = lam / (c * mu)
    if rho >= 1.0:
        return float("inf")
    a = lam / mu
    # Erlang C probability of waiting
    s = sum(a ** k / math.factorial(k) for k in range(c)) if c < 120 else None
    if s is None:
        # large-c normal approximation of Erlang C
        from math import erfc, sqrt
        z = (c - a) / sqrt(a)
        pw = min(1.0, max(0.0, erfc(z / sqrt(2)) / 2 / max(rho, 1e-9)))
    else:
        last = a ** c / math.factorial(c) / (1 - rho)
        pw = last / (s + last)
    return pw / (c * mu - lam)


# Per-request latency dispersion around (service + wait): multiplicative
# 1 + Gamma(k=4, θ=GAMMA_SCALE).  P95_DISPERSION is the 95th percentile of
# that multiplier (1 + θ·gammaincinv(4, .95) ≈ 1 + 7.754·θ) — latency_util()
# and tick() must stay consistent, else the planner systematically misjudges
# realized p95.
GAMMA_SHAPE = 4.0
GAMMA_SCALE = 0.035
P95_DISPERSION = 1.0 + 7.754 * GAMMA_SCALE


@dataclasses.dataclass
class TickResult:
    latency_ms_samples: np.ndarray
    served: int
    errors: int
    utilization: float
    queue_depth: float
    tokens: int


class ServingModel:
    """Fleet-level tick simulation over the queueing model."""

    def __init__(self, profile: ServiceProfile, workload: WorkloadSpec,
                 *, slo_ms: float = 200.0, tick_s: float = 10.0,
                 seed: int = 0):
        self.p = profile
        self.w = workload
        self.slo_ms = slo_ms
        self.tick_s = tick_s
        self.rng = np.random.default_rng(seed)
        self.carry_queue = 0.0

    def latency_util(self, replicas: int, rps: float) -> tuple[float, float]:
        """PerfModel protocol for the DynamicScaler: (p95-ish ms, util)."""
        c = max(replicas, 1) * self.p.slots
        mu = 1.0 / self.p.request_service_s(self.w)
        lam = rps
        rho = min(lam / (c * mu), 0.999)
        wait = mmc_wait_s(lam, mu, c)
        # requests time out past timeout_factor×SLO, so the experienced wait
        # is bounded (also guards the near-saturation Erlang blow-up)
        max_wait = self.slo_ms / 1e3 * self.w.timeout_factor
        wait = min(wait, max_wait) if math.isfinite(wait) else max_wait
        base = self.p.request_service_s(self.w)
        p95 = (base + wait) * P95_DISPERSION
        return p95 * 1e3, rho

    def tick(self, replicas: int, rps: float) -> TickResult:
        c = max(replicas, 1) * self.p.slots
        mu = 1.0 / self.p.request_service_s(self.w)
        arrivals = self.rng.poisson(rps * self.tick_s) + self.carry_queue
        capacity = c * mu * self.tick_s
        served = min(arrivals, capacity)
        backlog = arrivals - served
        # requests beyond timeout_factor×SLO of queueing are dropped
        max_wait = self.slo_ms / 1e3 * self.w.timeout_factor
        droppable = backlog - c * mu * max_wait
        errors = max(0.0, droppable)
        self.carry_queue = backlog - errors
        rho = min(rps / (c * mu), 0.999)
        wait = mmc_wait_s(rps, mu, c)
        wait = min(wait, max_wait) if math.isfinite(wait) else max_wait
        base = self.p.request_service_s(self.w)
        n = max(int(min(served, 256)), 1)
        lat = (base + wait) * (1 + self.rng.gamma(GAMMA_SHAPE, GAMMA_SCALE,
                                                  size=n))
        util = rho
        return TickResult(latency_ms_samples=lat * 1e3,
                          served=int(served), errors=int(errors),
                          utilization=float(util),
                          queue_depth=float(self.carry_queue),
                          tokens=int(served * self.w.gen_len))
