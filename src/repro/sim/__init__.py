"""Cluster + serving simulator grounded in the dry-run roofline numbers.

The control plane (repro.core) optimizes against this data-plane model:
workload traces (workload.py) drive a queueing serving model (serving.py)
whose per-replica throughput/latency comes from the compiled dry-run cells
(roofline_db.py); the cluster model (cluster.py) accounts cost/provisioning;
baseline.py implements the paper's "traditional MLOps" comparison points.
"""
from repro.sim.cluster import Cluster, PROVIDERS, REGION_COST_MULT
from repro.sim.roofline_db import RooflineDB, RooflineTerms, PEAK_FLOPS, HBM_BW, ICI_BW
from repro.sim.serving import ServiceProfile, ServingModel, WorkloadSpec, mmc_wait_s
from repro.sim.workload import REGIONS, TraceConfig, generate_trace
from repro.sim.baseline import (
    StaticAllocator, ThresholdAutoscaler, TRADITIONAL_STRATEGY,
    traditional_deploy_seconds,
)

__all__ = [
    "Cluster", "PROVIDERS", "REGION_COST_MULT",
    "RooflineDB", "RooflineTerms", "PEAK_FLOPS", "HBM_BW", "ICI_BW",
    "ServiceProfile", "ServingModel", "WorkloadSpec", "mmc_wait_s",
    "REGIONS", "TraceConfig", "generate_trace",
    "StaticAllocator", "ThresholdAutoscaler", "TRADITIONAL_STRATEGY",
    "traditional_deploy_seconds",
]
