"""The "traditional MLOps" baseline the paper compares against (§4.1.1).

Two variants, both faithful to the paper's description of current practice
("static rules and thresholds", "manual intervention", "reactive rather than
proactive"):

  * StaticAllocator — capacity fixed at sizing time (mean + k·σ of an
    observation window), never changes;
  * ThresholdAutoscaler — reactive rule: scale up max_step when utilization
    has exceeded hi for `patience` ticks, scale down 1 when below lo; no
    forecasting, so every response arrives one provisioning delay late.

Traditional deployment is modelled per the paper's 45-minute figure:
sequential per-stage bring-up, no compile cache, conservative soak times,
and manual approval gates between stages (modeled as fixed operator delay).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.orchestration.strategies import DeployEnv, Strategy


class StaticAllocator:
    def __init__(self, *, sized_for: float, perf_model, slo_ms: float,
                 max_replicas: int = 64):
        # size capacity so `sized_for` RPS meets the SLO — then freeze
        self.replicas = 1
        for r in range(1, max_replicas + 1):
            lat, _ = perf_model(r, sized_for)
            self.replicas = r
            if lat <= slo_ms:
                break

    def decide(self, metrics: dict) -> int:
        del metrics
        return self.replicas


@dataclasses.dataclass
class ThresholdAutoscaler:
    hi: float = 0.80
    lo: float = 0.30
    patience: int = 3
    max_step: int = 2
    min_replicas: int = 1
    max_replicas: int = 64
    _above: int = 0
    _below: int = 0

    def decide(self, metrics: dict, current: int) -> int:
        util = metrics.get("flop_util", 0.0)
        if util > self.hi:
            self._above += 1
            self._below = 0
        elif util < self.lo:
            self._below += 1
            self._above = 0
        else:
            self._above = self._below = 0
        if self._above >= self.patience:
            self._above = 0
            return min(current + self.max_step, self.max_replicas)
        if self._below >= self.patience:
            self._below = 0
            return max(current - 1, self.min_replicas)
        return current


TRADITIONAL_STRATEGY = Strategy("traditional_rolling",
                                (0.25, 0.5, 0.75, 1.0),
                                resource_overhead=0.10,
                                soak_ticks=6,       # conservative fixed soaks
                                risk=0.25)


def traditional_deploy_seconds(env: DeployEnv, *,
                               operator_gate_s: float = 300.0) -> float:
    """Sequential stages + no compile cache + manual approval gates."""
    import dataclasses as dc
    env = dc.replace(env, compile_cache_hit=False)
    from repro.core.orchestration.strategies import stage_deploy_seconds
    total, prev = 0.0, 0.0
    for frac in TRADITIONAL_STRATEGY.stages:
        total += stage_deploy_seconds(env, frac - prev)
        total += TRADITIONAL_STRATEGY.soak_ticks * env.tick_s
        total += operator_gate_s                 # human approval
        prev = frac
    return total
