"""Roofline database: the bridge between the compiled dry-run artifacts and
the cluster simulator (the "grounding loop", DESIGN.md §2).

Reads results/dryrun/<arch>__<shape>__<mesh>.json (written by
repro.launch.dryrun) and derives the three roofline terms per device:

    compute    = FLOPs_dev / PEAK_FLOPS
    memory     = bytes_dev / HBM_BW
    collective = coll_bytes_dev / ICI_BW

Scan bodies are counted once by XLA's cost analysis, so totals prefer the
unrolled-probe linear fit when present (rec["probe"]), plus an analytic
correction for FLOPs inside *time*-scans (SSM recurrences) that even the
probes cannot see.  step_time_s() = max(terms) (perfect-overlap roofline).

When a cell's JSON is missing (dry-run still running), an analytic fallback
estimates the terms from the model config — benchmarks stay runnable, and
the report marks which cells are measured vs estimated.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

from repro.configs import get_config
from repro.models import SHAPES

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

DEFAULT_DIR = Path("results/dryrun")


def ssm_scan_flops(cfg, shape) -> float:
    """Analytic FLOPs of the recurrence body that lax.scan-over-time hides
    from cost_analysis (per device, whole step).  ≈1-5% of layer FLOPs —
    reported for honesty, added to the compute term."""
    if cfg.ssm is None:
        return 0.0
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    N = cfg.ssm.d_state
    if cfg.ssm.version == 1:
        per_tok = 6 * cfg.d_inner * N            # decay·h + dtBx + C·h
    else:
        H, hd = cfg.ssm_heads, cfg.ssm.headdim
        per_tok = 6 * H * hd * N
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd
    return cfg.n_layers * per_tok * tokens * mult


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    flops: float                 # per device
    bytes: float                 # per device (HBM traffic)
    coll_bytes: float            # per device (wire)
    chips: int
    measured: bool               # True = from compiled dry-run
    mem_per_dev: float = 0.0     # bytes (args+temps), from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def step_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)


class RooflineDB:
    def __init__(self, directory: str | Path = DEFAULT_DIR):
        self.dir = Path(directory)
        self._cache: dict[tuple, RooflineTerms] = {}

    def _load(self, arch: str, shape_name: str, mesh: str):
        p = self.dir / f"{arch}__{shape_name}__{mesh}.json"
        if not p.exists():
            return None
        return json.loads(p.read_text())

    def terms(self, arch: str, shape_name: str, mesh: str = "single"
              ) -> RooflineTerms:
        key = (arch, shape_name, mesh)
        if key in self._cache:
            return self._cache[key]
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        rec = self._load(arch, shape_name, mesh)
        if rec is not None:
            chips = rec["chips"]
            if "probe" in rec:
                flops = rec["probe"]["flops"]["total"]
                byts = rec["probe"]["bytes"]["total"]
                coll = rec["probe"]["coll"]["total"]
            else:
                flops = rec["cost"]["flops"]
                byts = rec["cost"]["bytes"]
                coll = rec["collective_bytes"]
            flops += ssm_scan_flops(cfg, shape) / chips
            mem = rec.get("memory", {})
            mem_b = float(mem.get("argument_size_in_bytes", 0)
                          + mem.get("temp_size_in_bytes", 0))
            t = RooflineTerms(flops=max(flops, 0.0), bytes=max(byts, 0.0),
                              coll_bytes=max(coll, 0.0), chips=chips,
                              measured=True, mem_per_dev=mem_b)
        else:
            t = self._analytic(cfg, shape)
        self._cache[key] = t
        return t

    # ------------------------------------------------------- analytic fallback

    def _analytic(self, cfg, shape) -> RooflineTerms:
        chips = 256
        n_active = cfg.active_params()
        if shape.kind == "train":
            tokens = shape.global_batch * shape.seq_len
            flops = 6 * n_active * tokens * 1.33 / chips      # remat ×4/3
            byts = (4 * cfg.n_params() * 3 + tokens * cfg.d_model * 2
                    * cfg.n_layers * 0.25) / chips
            coll = 12 * cfg.n_params() / chips                # grad RS+AG fp32
        elif shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
            flops = 2 * n_active * tokens / chips
            byts = (2 * cfg.n_params() + tokens * cfg.d_model * 2 * 4) / chips
            coll = 2 * tokens * cfg.d_model * 2 * cfg.n_layers / chips
        else:
            tokens = shape.global_batch
            flops = 2 * n_active * tokens / chips
            kv = (2 * cfg.n_layers * max(cfg.n_kv_heads, 1) * cfg.hd
                  * min(shape.seq_len, cfg.sliding_window or shape.seq_len)
                  * shape.global_batch * 2)
            byts = (2 * cfg.n_params() + kv) / chips
            coll = 2 * tokens * cfg.d_model * 2 * cfg.n_layers / chips
        return RooflineTerms(flops=flops, bytes=byts, coll_bytes=coll,
                             chips=chips, measured=False)

    def step_time_s(self, arch: str, shape_name: str, mesh: str = "single"
                    ) -> float:
        return self.terms(arch, shape_name, mesh).step_time
