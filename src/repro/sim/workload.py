"""Workload-trace generation (paper §4.2.2: daily and weekly patterns,
sudden spikes, regional offsets).

The paper's production traces are proprietary; these synthetic traces carry
the properties the paper names — diurnal cycle, weekly seasonality, heavy-
tailed noise, flash spikes — with magnitudes calibrated so the traditional
baseline reproduces the paper's starting point (≈58% utilization at 250 ms,
§4.1.1).  Regions shift the diurnal phase (paper §4.1.2 multi-region).
"""
from __future__ import annotations

import dataclasses

import numpy as np

REGIONS = ("na", "eu", "apac", "sa", "au")
REGION_PHASE = {"na": 0.0, "eu": -6.0, "apac": -13.0, "sa": 1.0, "au": -15.0}
REGION_SCALE = {"na": 1.0, "eu": 0.8, "apac": 0.9, "sa": 0.35, "au": 0.25}


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    base_rps: float = 120.0
    diurnal_amp: float = 0.55        # fraction of base
    weekly_amp: float = 0.15
    noise_cv: float = 0.08
    spike_prob: float = 0.004        # per tick
    spike_mult: (float, float) = (1.8, 3.5)
    spike_len_ticks: (int, int) = (3, 12)
    ticks_per_day: int = 288         # 5-min ticks
    region: str = "na"
    seed: int = 0


def generate_trace(cfg: TraceConfig, n_ticks: int) -> np.ndarray:
    # zlib.crc32, NOT hash(): python's str hash is salted per process, which
    # would make traces irreproducible across runs
    import zlib
    rng = np.random.default_rng(cfg.seed
                                + zlib.crc32(cfg.region.encode()) % 1000)
    t = np.arange(n_ticks)
    hours = (t / cfg.ticks_per_day * 24.0 + REGION_PHASE[cfg.region]) % 24.0
    day = t // cfg.ticks_per_day % 7
    # diurnal: business-hours hump, low at night
    diurnal = 1.0 + cfg.diurnal_amp * np.sin((hours - 6.0) / 24.0 * 2 * np.pi)
    weekly = 1.0 - cfg.weekly_amp * ((day >= 5).astype(float))
    rps = cfg.base_rps * REGION_SCALE[cfg.region] * diurnal * weekly
    rps *= rng.lognormal(0.0, cfg.noise_cv, size=n_ticks)
    # flash spikes
    i = 0
    while i < n_ticks:
        if rng.random() < cfg.spike_prob:
            ln = rng.integers(*cfg.spike_len_ticks)
            mult = rng.uniform(*cfg.spike_mult)
            ramp = np.linspace(1.0, mult, max(ln // 3, 1))
            prof = np.concatenate([ramp, np.full(ln - 2 * len(ramp), mult),
                                   ramp[::-1]]) if ln >= 2 * len(ramp) \
                else np.full(ln, mult)
            end = min(i + len(prof), n_ticks)
            rps[i:end] *= prof[:end - i]
            i = end
        i += 1
    return np.maximum(rps, 1.0)
