"""Multi-cloud cluster model: providers, regions, cost, provisioning delays.

Mirrors the paper's evaluation surface (AWS / GCP / Azure × five regions).
The scaling unit is a TPU-slice replica (chips_per_replica chips).  Costs are
$/chip-hour with provider/region multipliers; provisioning is a lognormal
delay during which the replica bills but serves nothing — this is what makes
*reactive* scaling expensive and *predictive* scaling win (the paper's core
claim).
"""
from __future__ import annotations

import dataclasses

import numpy as np

PROVIDERS = {
    # $/chip-hour base, provisioning median (s), provisioning sigma
    "aws":   {"cost": 1.35, "prov_med_s": 210.0, "prov_sigma": 0.45},
    "gcp":   {"cost": 1.20, "prov_med_s": 150.0, "prov_sigma": 0.35},
    "azure": {"cost": 1.45, "prov_med_s": 260.0, "prov_sigma": 0.55},
}

REGION_COST_MULT = {"na": 1.00, "eu": 1.12, "apac": 1.18, "sa": 1.25,
                    "au": 1.30}


@dataclasses.dataclass
class Replica:
    id: int
    ready_at_tick: float          # provisioning completes
    provider: str
    region: str


class Cluster:
    def __init__(self, *, provider: str = "gcp", region: str = "na",
                 chips_per_replica: int = 16, tick_s: float = 10.0,
                 seed: int = 0):
        self.provider = provider
        self.region = region
        self.chips = chips_per_replica
        self.tick_s = tick_s
        self.rng = np.random.default_rng(seed)
        self.replicas: list[Replica] = []
        self._next_id = 0
        self.tick = 0
        self.spend_usd = 0.0

    # ------------------------------------------------------------- scaling

    def scale_to(self, target: int):
        target = max(target, 0)
        while len(self.replicas) > target:
            # cancel in-flight provisioning first; drain warm replicas only
            # when no cold ones remain (never swap warm capacity for cold)
            idx = len(self.replicas) - 1
            for i in range(len(self.replicas) - 1, -1, -1):
                if self.replicas[i].ready_at_tick > self.tick:
                    idx = i
                    break
            self.replicas.pop(idx)
        p = PROVIDERS[self.provider]
        while len(self.replicas) < target:
            delay_s = self.rng.lognormal(np.log(p["prov_med_s"]),
                                         p["prov_sigma"])
            self.replicas.append(Replica(
                id=self._next_id, provider=self.provider, region=self.region,
                ready_at_tick=self.tick + delay_s / self.tick_s))
            self._next_id += 1

    def ready_replicas(self) -> int:
        return sum(1 for r in self.replicas if r.ready_at_tick <= self.tick)

    def total_replicas(self) -> int:
        return len(self.replicas)

    def replace(self, replica_idx: int):
        """Straggler mitigation: drain + re-provision one replica."""
        if 0 <= replica_idx < len(self.replicas):
            p = PROVIDERS[self.provider]
            delay_s = self.rng.lognormal(np.log(p["prov_med_s"]),
                                         p["prov_sigma"])
            self.replicas[replica_idx] = Replica(
                id=self._next_id, provider=self.provider, region=self.region,
                ready_at_tick=self.tick + delay_s / self.tick_s)
            self._next_id += 1

    # ------------------------------------------------------------- time/cost

    def cost_per_tick(self) -> float:
        rate = (PROVIDERS[self.provider]["cost"]
                * REGION_COST_MULT[self.region])
        return len(self.replicas) * self.chips * rate * self.tick_s / 3600.0

    def advance(self, *, fail_prob: float = 0.0):
        """One tick: accrue cost; optionally fail replicas (node failures)."""
        self.spend_usd += self.cost_per_tick()
        self.tick += 1
        if fail_prob > 0:
            for i, r in enumerate(self.replicas):
                if (r.ready_at_tick <= self.tick
                        and self.rng.random() < fail_prob):
                    self.replace(i)
