"""Model-family behaviour: forward shapes, prefill/decode consistency with the
full forward, train-step finiteness, family-specific invariants.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import LM, ModelConfig, MoECfg
from repro.models.steps import (
    cross_entropy, init_train_state, make_train_step,
)

from conftest import TINY_CFGS, inputs_for, tiny, B, S, V


# ---------------------------------------------------------------- per family

def test_forward_shapes_and_finite(family_cfg):
    name, cfg = family_cfg
    key = jax.random.PRNGKey(0)
    params, axes = LM.init(key, cfg)
    logits, aux = LM.apply(params, inputs_for(cfg, key), cfg)
    assert logits.shape == (B, S, V)
    assert bool(jnp.isfinite(logits).all()), name
    # axes pytree mirrors params exactly (strict zip raises on mismatch)
    jax.tree.map(lambda p, a: None, params,
                 jax.tree.map(lambda x: x, axes,
                              is_leaf=lambda x: isinstance(x, tuple)))


def test_prefill_matches_full_forward(family_cfg):
    name, cfg = family_cfg
    key = jax.random.PRNGKey(1)
    params, _ = LM.init(key, cfg)
    batch = inputs_for(cfg, key)
    logits, _ = LM.apply(params, batch, cfg)
    lp, cache = LM.prefill(params, batch, cfg, max_seq=S + 4)
    assert lp.shape == (B, 1, V)
    np.testing.assert_allclose(lp[:, 0], logits[:, -1], atol=2e-4, rtol=2e-4)
    assert int(cache["index"]) == S


def test_decode_matches_extended_forward(family_cfg):
    """One decode step == full forward on the (prompt + new token) sequence.

    Skipped where the comparison is ill-defined: vlm (patch prefix changes
    position bookkeeping between S and S+1) and enc-dec (decoder grows but
    encoder input does not)."""
    name, cfg = family_cfg
    if cfg.enc_dec or cfg.family == "vlm":
        pytest.skip("decode consistency checked via shapes for this family")
    key = jax.random.PRNGKey(2)
    params, _ = LM.init(key, cfg)
    batch = inputs_for(cfg, key)
    lp, cache = LM.prefill(params, batch, cfg, max_seq=S + 4)
    tok = jnp.argmax(lp[:, 0], -1).astype(jnp.int32)[:, None]
    ld, cache2 = LM.decode(params, tok, cfg, cache)
    assert int(cache2["index"]) == S + 1
    full, _ = LM.apply(
        params, {"tokens": jnp.concatenate([batch["tokens"], tok], 1)}, cfg)
    np.testing.assert_allclose(ld[:, 0], full[:, -1], atol=5e-4, rtol=5e-4)


def test_multi_step_decode_finite(family_cfg):
    name, cfg = family_cfg
    key = jax.random.PRNGKey(3)
    params, _ = LM.init(key, cfg)
    lp, cache = LM.prefill(params, inputs_for(cfg, key), cfg, max_seq=S + 8)
    tok = jnp.argmax(lp[:, 0], -1).astype(jnp.int32)[:, None]
    for _ in range(4):
        ld, cache = LM.decode(params, tok, cfg, cache)
        assert bool(jnp.isfinite(ld).all())
        tok = jnp.argmax(ld[:, 0], -1).astype(jnp.int32)[:, None]


def test_train_step_decreases_loss(family_cfg):
    name, cfg = family_cfg
    key = jax.random.PRNGKey(4)
    batch = inputs_for(cfg, key)
    batch["labels"] = batch["tokens"]
    train_step, (opt_init, _) = make_train_step(cfg, lr=5e-3)
    state = init_train_state(key, cfg, opt_init)
    step = jax.jit(train_step)
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], f"{name}: no learning {losses}"


# ---------------------------------------------------------------- invariants

def test_swa_equals_dense_when_window_covers_seq():
    dense = TINY_CFGS["dense"]
    wide = dataclasses.replace(dense, sliding_window=4 * S)
    key = jax.random.PRNGKey(5)
    params, _ = LM.init(key, dense)
    batch = inputs_for(dense, key)
    l1, _ = LM.apply(params, batch, dense)
    l2, _ = LM.apply(params, batch, wide)
    np.testing.assert_allclose(l1, l2, atol=1e-5, rtol=1e-5)


def test_swa_cache_is_window_bounded():
    cfg = TINY_CFGS["swa"]             # window 8
    spec = LM.cache_spec(cfg, batch=2, max_seq=1024)
    k_shape = spec["layers"]["k"][0]
    assert k_shape[2] == cfg.sliding_window     # (L, B, W, KV, hd)


def test_swa_decode_beyond_window_matches_full_forward():
    """Ring-buffer correctness: decode far past the window must still equal
    the sliding-window full forward on the extended sequence."""
    cfg = TINY_CFGS["swa"]             # window = 8 < S = 16
    key = jax.random.PRNGKey(6)
    params, _ = LM.init(key, cfg)
    batch = inputs_for(cfg, key)
    lp, cache = LM.prefill(params, batch, cfg, max_seq=S + 8)
    toks = batch["tokens"]
    tok = jnp.argmax(lp[:, 0], -1).astype(jnp.int32)[:, None]
    for _ in range(6):                 # wraps the ring nearly once
        toks = jnp.concatenate([toks, tok], 1)
        ld, cache = LM.decode(params, tok, cfg, cache)
        full, _ = LM.apply(params, {"tokens": toks}, cfg)
        np.testing.assert_allclose(ld[:, 0], full[:, -1], atol=5e-4, rtol=5e-4)
        tok = jnp.argmax(ld[:, 0], -1).astype(jnp.int32)[:, None]


def test_moe_capacity_drops_are_the_only_decode_divergence():
    """With capacity_factor small, the full forward drops tokens (decode does
    not — each token trivially fits), so outputs may diverge; with a large
    factor there are no drops and decode is exact.  This pins the semantics."""
    tight = tiny("moe", moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=32,
                                   capacity_factor=0.5))
    key = jax.random.PRNGKey(7)
    params, _ = LM.init(key, tight)
    batch = inputs_for(tight, key)
    _, aux = LM.apply(params, batch, tight)
    assert float(aux["drop_frac"]) > 0.0       # tokens were dropped
    loose = TINY_CFGS["moe"]
    params, _ = LM.init(key, loose)
    _, aux = LM.apply(params, batch, loose)
    assert float(aux["drop_frac"]) == 0.0


def test_moe_router_load_balance_loss_bounds():
    """Per-layer lb_loss ≥ 1 (equality iff perfectly balanced); expert_load
    sums to 1.  Checked on the MoE layer directly (LM aggregates over scan)."""
    from repro.models.moe import MoE
    cfg = TINY_CFGS["moe"]
    key = jax.random.PRNGKey(8)
    params, _ = MoE.init(key, cfg.d_model, cfg.moe)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model))
    _, aux = MoE.apply(params, x, cfg.moe)
    assert float(aux["lb_loss"]) >= 1.0 - 1e-3
    np.testing.assert_allclose(float(jnp.sum(aux["expert_load"])), 1.0,
                               atol=1e-5)
    # LM-level: summed over the 2 scanned layers
    lparams, _ = LM.init(key, cfg)
    _, lm_aux = LM.apply(lparams, inputs_for(cfg, key), cfg)
    assert float(lm_aux["lb_loss"]) >= cfg.n_layers * (1.0 - 1e-3)


def test_scan_and_unrolled_agree(family_cfg):
    name, cfg = family_cfg
    key = jax.random.PRNGKey(9)
    params, _ = LM.init(key, cfg)
    batch = inputs_for(cfg, key)
    l_scan, _ = LM.apply(params, batch, cfg)
    unrolled = dataclasses.replace(cfg, use_scan=False, remat="none")
    l_un, _ = LM.apply(params, batch, unrolled)
    np.testing.assert_allclose(l_scan, l_un, atol=2e-4, rtol=2e-4)


def test_vlm_patches_change_only_prefix_rows():
    cfg = TINY_CFGS["vlm"]
    key = jax.random.PRNGKey(10)
    params, _ = LM.init(key, cfg)
    batch = inputs_for(cfg, key)
    l1, _ = LM.apply(params, batch, cfg)
    batch2 = dict(batch)
    batch2["patches"] = batch["patches"] * 2.0
    l2, _ = LM.apply(params, batch2, cfg)
    # causal: token positions *before* the patch prefix end can change, but
    # the model must remain finite and differ somewhere (patches are used)
    assert not bool(jnp.allclose(l1, l2))


def test_cross_entropy_matches_naive():
    key = jax.random.PRNGKey(11)
    logits = jax.random.normal(key, (3, 5, 17))
    labels = jax.random.randint(jax.random.fold_in(key, 1), (3, 5), 0, 17)
    got = cross_entropy(logits, labels)
    p = jax.nn.log_softmax(logits, -1)
    want = -jnp.mean(jnp.take_along_axis(p, labels[..., None], -1))
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_cross_entropy_ignores_masked_labels():
    key = jax.random.PRNGKey(12)
    logits = jax.random.normal(key, (2, 4, 9))
    labels = jnp.array([[1, 2, -1, -1], [3, -1, -1, -1]])
    got = cross_entropy(logits, labels)
    p = jax.nn.log_softmax(logits, -1)
    want = -(p[0, 0, 1] + p[0, 1, 2] + p[1, 0, 3]) / 3
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_param_count_analytic_close_to_actual(family_cfg):
    name, cfg = family_cfg
    params, _ = LM.init(jax.random.PRNGKey(0), cfg)
    actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    est = cfg.n_params()
    assert abs(est - actual) / actual < 0.15, (name, est, actual)


# ------------------------------------------------- length-masked cross-attn


def test_cross_attention_length_mask_matches_unpadded():
    """Decoding against a padded cross-K/V pool with cross_len must equal
    decoding against the unpadded encoder K/V — per row, with different
    encoder lengths in one batch (the enc-dec slot-serving prerequisite)."""
    from repro.models.attention import Attention
    cfg = TINY_CFGS["audio"]
    key = jax.random.PRNGKey(13)
    params, _ = Attention.init(key, cfg)
    Bsz, Se_max = 2, 12
    lens = [12, 7]                          # per-row encoder lengths
    x = jax.random.normal(jax.random.fold_in(key, 1), (Bsz, 1, cfg.d_model))
    k = jax.random.normal(jax.random.fold_in(key, 2),
                          (Bsz, Se_max, cfg.n_kv_heads, cfg.hd))
    v = jax.random.normal(jax.random.fold_in(key, 3),
                          (Bsz, Se_max, cfg.n_kv_heads, cfg.hd))
    # poison everything past each row's length: the mask must hide it
    pos = jnp.arange(Se_max)[None, :, None, None]
    live = pos < jnp.asarray(lens)[:, None, None, None]
    k_pad = jnp.where(live, k, 1e3)
    v_pad = jnp.where(live, v, -1e3)
    out, _ = Attention.decode(params, x, cfg, None, 0,
                              cross_kv=(k_pad, v_pad),
                              cross_len=jnp.asarray(lens, jnp.int32))
    for b, L in enumerate(lens):            # each row vs its own solo decode
        solo, _ = Attention.decode(params, x[b:b + 1], cfg, None, 0,
                                   cross_kv=(k[b:b + 1, :L], v[b:b + 1, :L]))
        np.testing.assert_allclose(out[b], solo[0], atol=1e-5, rtol=1e-5)


def test_encdec_decode_invariant_to_cross_padding():
    """LM.decode must ignore cross-K/V rows beyond cache["cross_len"]: a
    pool-sized (padded) cross cache decodes exactly like the tight one."""
    cfg = TINY_CFGS["audio"]
    key = jax.random.PRNGKey(14)
    params, _ = LM.init(key, cfg)
    lp, cache = LM.prefill(params, inputs_for(cfg, key), cfg, max_seq=S + 4)
    tok = jnp.argmax(lp[:, 0], -1).astype(jnp.int32)[:, None]
    ld, _ = LM.decode(params, tok, cfg, cache)
    pad = [(0, 0), (0, 0), (0, 4), (0, 0), (0, 0)]      # (L, B, Se, KV, hd)
    cache_pad = dict(cache)
    cache_pad["cross"] = {
        n: jnp.pad(leaf, pad, constant_values=1e3)
        for n, leaf in cache["cross"].items()}
    ld_pad, _ = LM.decode(params, tok, cfg, cache_pad)
    np.testing.assert_allclose(ld_pad, ld, atol=1e-5, rtol=1e-5)
