"""End-to-end integration: (1) the DNN-powered allocator beats the reactive
threshold baseline on the roofline-grounded simulator (the paper's headline
claim, small scale); (2) the training driver runs, checkpoints, and resumes
deterministically; (3) the serving engine serves real batched requests.
"""
import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.allocation.allocator import AllocatorConfig, PredictiveAllocator
from repro.core.dnn.features import deploy_vector
from repro.core.scaling.scaler import ScalingConstraints
from repro.sim import (
    Cluster, RooflineDB, ServiceProfile, ServingModel, TraceConfig,
    ThresholdAutoscaler, WorkloadSpec, generate_trace,
)

REPO = Path(__file__).resolve().parents[1]
DRYRUN = REPO / "results" / "dryrun"


def run_fleet(decider, n_ticks=400, seed=0, tick_s=60.0):
    """Tick loop: trace → serving model → metrics → decider → cluster."""
    db = RooflineDB(DRYRUN)
    prof = ServiceProfile.from_db(db, "qwen2.5-3b")
    w = WorkloadSpec(prompt_len=512, gen_len=64)
    cap1 = prof.requests_per_s(w)                      # rps one replica serves
    trace = generate_trace(TraceConfig(base_rps=cap1 * 10, ticks_per_day=96,
                                       seed=seed), n_ticks)
    model = ServingModel(prof, w, slo_ms=30_000.0, tick_s=tick_s, seed=seed)
    cluster = Cluster(chips_per_replica=prof.chips_per_replica, tick_s=tick_s,
                      seed=seed)
    cluster.scale_to(8)
    cluster.tick = 10**6                               # start warm
    utils, lats, served, errs = [], [], 0, 0
    for t in range(n_ticks):
        ready = max(cluster.ready_replicas(), 1)
        r = model.tick(ready, trace[t])
        metrics = {
            "rps": trace[t], "rps_window": trace[max(0, t - 8):t + 1],
            "flop_util": r.utilization, "hbm_util": r.utilization,
            "ici_util": r.utilization * 0.5, "mem_frac": 0.5,
            "latency_p50": float(np.median(r.latency_ms_samples)),
            "latency_p95": float(np.percentile(r.latency_ms_samples, 95)),
            "throughput": r.served, "error_rate": r.errors / max(r.served, 1),
            "queue_depth": r.queue_depth,
            "replicas_frac": cluster.total_replicas() / 64,
        }
        target = decider(metrics, cluster.total_replicas(), model)
        cluster.scale_to(target)
        cluster.advance()
        utils.append(r.utilization)
        lats.append(metrics["latency_p95"])
        served += r.served
        errs += r.errors
    return {
        "util": float(np.mean(utils)),
        "p95_ms": float(np.mean(lats)),
        "cost_per_req": cluster.spend_usd / max(served, 1),
        "error_rate": errs / max(served + errs, 1),
        "spend": cluster.spend_usd,
    }


def test_dnn_allocator_beats_threshold_baseline():
    """The paper's §4.1.1 comparison at test scale: proactive DNN allocation
    must improve utilization AND cost-per-inference without raising errors."""
    slo = 30_000.0

    thr = ThresholdAutoscaler(hi=0.75, lo=0.25, patience=3, max_step=2,
                              max_replicas=64)
    base = run_fleet(lambda m, cur, model: thr.decide(m, cur))

    db = RooflineDB(DRYRUN)
    prof = ServiceProfile.from_db(db, "qwen2.5-3b")
    model_holder = {}

    def perf_model(replicas, rps):
        return model_holder["m"].latency_util(replicas, rps)

    alloc = PredictiveAllocator(
        perf_model, ScalingConstraints(max_replicas=64, slo_ms=slo),
        deploy_vector(model_params_b=3, family="dense", mesh_model=16,
                      mesh_data=16, region_idx=0, slo_ms=slo, cost_weight=0.5),
        cfg=AllocatorConfig(mode="planner"))

    def dnn_decide(metrics, current, model):
        model_holder["m"] = model
        alloc.replicas = current
        alloc.observe(metrics)
        d = alloc.decide(metrics)
        alloc.apply(d)
        return d.target_replicas

    ours = run_fleet(dnn_decide)

    assert ours["util"] > base["util"] * 1.05, (ours, base)
    assert ours["cost_per_req"] < base["cost_per_req"] * 0.95, (ours, base)
    assert ours["error_rate"] <= base["error_rate"] + 0.01


def test_train_driver_checkpoints_and_resumes(tmp_path):
    """launch.train main(): run 6 steps, kill, resume — the resumed run must
    continue from the checkpoint step and produce finite losses."""
    from repro.launch.train import main
    log1 = tmp_path / "a.jsonl"
    rc = main(["--arch", "qwen2.5-3b", "--smoke", "--steps", "6",
               "--seq", "32", "--batch", "2", "--ckpt-dir", str(tmp_path / "ck"),
               "--ckpt-every", "3", "--log", str(log1)])
    assert rc == 0
    from repro.checkpoint import CheckpointManager
    assert CheckpointManager(tmp_path / "ck").latest_step() == 6

    log2 = tmp_path / "b.jsonl"
    rc = main(["--arch", "qwen2.5-3b", "--smoke", "--steps", "9",
               "--seq", "32", "--batch", "2", "--ckpt-dir", str(tmp_path / "ck"),
               "--resume", "--log", str(log2)])
    assert rc == 0
    recs = [json.loads(l) for l in log2.read_text().splitlines()]
    assert recs[-1]["step"] == 9
    assert all(np.isfinite(r["loss"]) for r in recs)


def test_serve_driver_end_to_end():
    """launch.serve: real model, batched continuous decode, requests finish."""
    from repro.launch.serve import main
    rc = main(["--arch", "qwen2.5-3b", "--smoke", "--requests", "6",
               "--slots", "2", "--max-seq", "48", "--prompt-len", "12",
               "--gen-len", "6", "--arrival-rps", "50"])
    assert rc == 0


def test_serving_engine_decode_matches_single_request():
    """Slot-batched decode must produce the same tokens as a fresh
    single-request engine for the same prompt (batching is transparent)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.launch.serve import ServingEngine

    cfg = get_smoke_config("qwen2.5-3b")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(3, cfg.vocab, size=10).astype(np.int32)
               for _ in range(2)]

    def gen(engine, slot, prompt, n):
        engine.admit(slot, prompt, n)
        out = []
        while engine.active[slot]:
            tok_before = int(engine.tokens[slot, 0])
            out.append(tok_before)
            engine.tick()
        return out

    e1 = ServingEngine(cfg, slots=2, max_seq=32, seed=0)
    # run both prompts concurrently in different slots
    e1.admit(0, prompts[0], 4)
    e1.admit(1, prompts[1], 4)
    toks_concurrent = {0: [int(e1.tokens[0, 0])], 1: [int(e1.tokens[1, 0])]}
    for _ in range(4):
        e1.tick()
        toks_concurrent[0].append(int(e1.tokens[0, 0]))
        toks_concurrent[1].append(int(e1.tokens[1, 0]))

    e2 = ServingEngine(cfg, slots=2, max_seq=32, seed=0)
    solo = gen(e2, 0, prompts[0], 4)
    assert toks_concurrent[0][:4] == solo[:4]
