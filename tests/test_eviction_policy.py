"""The closed loop's straggler-eviction policy: flag → sustain → actuate.

Covers the policy's streak arithmetic (K-1 flagged windows → no action, K →
evict; recovery resets), the collector interplay (an idle window prunes the
EWMA, so a recovered replica un-flags and its streak dies with it), the
1-replica-fleet regression (never evicted to zero), and the end-to-end
actuation path run_closed_loop drives: policy → router.evict_stragglers →
evacuate + requeue + replace.
"""
import numpy as np
import pytest

from repro.core.monitoring.collector import MetricsCollector, ReplicaReport
from repro.core.scaling.scaler import EvictionPolicy
from repro.serving import ReplicaRouter, Request

from conftest import TINY_CFGS

CFG = TINY_CFGS["dense"]


def _report(rid, tick, lat, n):
    return ReplicaReport(replica_id=rid, tick=tick, latency_ms_samples=lat,
                         n_requests=n, n_errors=0, flop_util=0.5,
                         hbm_util=0.5, ici_util=0.0, mem_frac=0.5,
                         queue_depth=0)


# ------------------------------------------------------------ policy streaks


def test_k_minus_one_flagged_windows_take_no_action():
    policy = EvictionPolicy(k_windows=3)
    assert policy.update([7], fleet_size=4) == []
    assert policy.update([7], fleet_size=4) == []
    assert policy.streak(7) == 2


def test_kth_consecutive_window_evicts_and_resets_the_streak():
    policy = EvictionPolicy(k_windows=3)
    policy.update([7], 4), policy.update([7], 4)
    assert policy.update([7], fleet_size=4) == [7]
    assert policy.streak(7) == 0          # the replacement starts clean
    assert policy.update([7], fleet_size=4) == []   # needs K fresh windows


def test_recovery_resets_the_streak():
    policy = EvictionPolicy(k_windows=2)
    assert policy.update([5], 3) == []
    assert policy.update([], 3) == []     # one clean window → forgiven
    assert policy.streak(5) == 0
    assert policy.update([5], 3) == []    # back to square one
    assert policy.update([5], 3) == [5]


def test_one_replica_fleet_is_never_evicted_to_zero():
    """Regression: with min_fleet replicas left there is nowhere to drain
    to while a replacement warms — the policy must sit on its hands no
    matter how long the streak runs."""
    policy = EvictionPolicy(k_windows=2)
    for _ in range(10):
        assert policy.update([0], fleet_size=1) == []
    # headroom appears (scale-up) → the sustained straggler goes at once
    assert policy.update([0], fleet_size=2) == [0]


def test_eviction_budget_caps_simultaneous_evictions():
    """Three replicas all flagged K windows in a 3-fleet with min_fleet=1:
    at most two may go in one window — the fleet is never emptied in a
    single actuation even though each eviction is replaced."""
    policy = EvictionPolicy(k_windows=1)
    out = policy.update([0, 1, 2], fleet_size=3)
    assert len(out) == 2


# -------------------------------------------- collector EWMA recovery path


def test_recovered_replica_unflags_via_collector_ewma_prune():
    """An evicted→parked straggler keeps reporting empty windows; the
    collector prunes its latency EWMA, so the straggler feed drops it and
    the policy streak resets — revival does not re-condemn it."""
    c = MetricsCollector(straggler_factor=1.5)
    policy = EvictionPolicy(k_windows=3)
    for tick in range(2):                 # 2 of the 3 required windows
        for rid in range(4):
            lat = [400.0] * 8 if rid == 3 else [100.0] * 8
            c.submit(_report(rid, tick, lat, 8))
        assert policy.update(c.stragglers(), fleet_size=4) == []
    assert policy.streak(3) == 2
    c.submit(_report(3, 2, [], 0))        # idle window: EWMA pruned
    assert 3 not in c.stragglers()
    assert policy.update(c.stragglers(), fleet_size=4) == []
    assert policy.streak(3) == 0          # recovery observed by the policy
    c.submit(_report(3, 3, [105.0] * 8, 8))   # revived and healthy
    assert policy.update(c.stragglers(), fleet_size=4) == []


# ------------------------------------------------------- actuation end-to-end


def test_policy_actuates_router_eviction_with_requeue_and_replace():
    """The exact wiring run_closed_loop drives each tick: collector feed →
    policy.update → router.evict_stragglers.  The Kth window evicts the
    straggler, its requests requeue through survivors, a replacement holds
    the count, and every request still completes exactly once."""
    router = ReplicaRouter.shared_core(CFG, slots=2, max_seq=24,
                                       n_replicas=3, max_replicas=4)
    collector = MetricsCollector(straggler_factor=1.5)
    policy = EvictionPolicy(k_windows=2)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(3, CFG.vocab, size=6)
                    .astype(np.int32), gen_len=5) for i in range(6)]
    for r in reqs:
        router.submit(r, now=0.0)
    router.step(1.0)
    slow = router.replicas[1].replica_id
    evicted = []
    for tick in range(2):                 # two flagged windows → actuate
        for rep in router.replicas:
            lat = [900.0] * 4 if rep.replica_id == slow else [100.0] * 4
            collector.submit(_report(rep.replica_id, tick, lat, 4))
        evicted += router.evict_stragglers(
            policy.update(collector.stragglers(), router.replica_count),
            now=1.0)
    assert evicted == [slow]
    assert router.replica_count == 3      # replacement restored the count
    assert slow not in [r.replica_id for r in router.replicas]
    done, now = [], 1.0
    while len(done) < 6 and now < 100:
        now += 1.0
        done.extend(router.step(now))
    assert sorted(r.rid for r in done) == list(range(6))


def test_closed_loop_eviction_disabled_matches_enabled_on_healthy_run():
    """On a healthy run the policy is a no-op: evict_after=0 (disabled) and
    the default produce identical streams and scaling decisions — eviction
    changes nothing unless something actually straggles."""
    import dataclasses

    from repro.serving.closed_loop import LoopConfig, run_closed_loop

    results = {}
    for evict_after in (0, 3):
        lc = dataclasses.replace(
            LoopConfig(slots=2, max_replicas=2, max_seq=32, prefill_chunk=4,
                       steps_per_tick=6), evict_after=evict_after)
        sink = []
        router, logs = run_closed_loop(CFG, autoscale=True, ticks=6, seed=0,
                                       lc=lc, sink=sink)
        results[evict_after] = {
            "decisions": [(t.replicas, t.reason) for t in logs],
            "evicted": [t.evicted for t in logs],
            "streams": {r.rid: tuple(r.tokens_out) for r in sink},
        }
        router.close()
    assert results[0]["decisions"] == results[3]["decisions"]
    assert results[0]["streams"] == results[3]["streams"]
    assert all(e == [] for e in results[3]["evicted"])
