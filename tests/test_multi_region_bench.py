"""The sim-side multi-region benchmark (paper §4.1.2), now a CI smoke.

Regression (verified failing on the pre-fix src): ``mmc_wait_s`` large-c
normal approximation divided by ``sqrt(a)`` with ``a = lam/mu = 0`` — a
diurnal trough in a high-demand region (zero arrivals against a ≥120-slot
candidate fleet) crashed the whole benchmark with ZeroDivisionError, which
is why it sat dormant out of CI.  An empty system has no queue: lam == 0
returns 0 wait.

The schema test pins the per-region output contract the CI artifact
(BENCH_multi_region.json) and any downstream reader rely on.
"""
import math

from repro.sim.serving import mmc_wait_s
from repro.sim.workload import REGIONS

PER_REGION_KEYS = {"util_gain_rel", "cost_reduction", "latency_reduction",
                   "util_traditional", "util_dnn"}


def test_mmc_wait_zero_arrivals_is_zero_even_for_large_fleets():
    # the large-c (>=120) normal-approximation branch used to divide by
    # sqrt(lam/mu) = 0 here
    assert mmc_wait_s(0.0, 1.0, 150) == 0.0
    assert mmc_wait_s(0.0, 1.0, 2) == 0.0
    # and the guards around it still hold
    assert mmc_wait_s(1.0, 0.0, 2) == float("inf")
    assert mmc_wait_s(5.0, 1.0, 2) == float("inf")       # rho >= 1
    assert math.isfinite(mmc_wait_s(1.0, 1.0, 150))


def test_multi_region_benchmark_schema():
    from benchmarks.multi_region import run

    r = run(n_ticks=24)                                  # sub-second scale
    assert r["name"] == "multi_region"
    assert r["us_per_call"] > 0.0
    assert isinstance(r["derived"], str) and "regions" in r["derived"]
    per_region = r["detail"]["per_region"]
    assert set(per_region) == set(REGIONS)               # all five, no more
    for region, v in per_region.items():
        assert set(v) == PER_REGION_KEYS, region
        assert all(isinstance(x, float) for x in v.values()), region
        assert 0.0 <= v["util_traditional"] <= 1.0
        assert 0.0 <= v["util_dnn"] <= 1.0
    assert isinstance(r["detail"]["all_improve"], bool)
