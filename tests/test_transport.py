"""The replica-fabric wire contract: framed JSON round-trips (including the
non-finite metric values real windows produce), partial-frame reads (kernel
buffers split frames arbitrarily), typed codecs for Request / ReplicaReport /
ModelConfig, and the failure path — a dead ProcessReplica worker must surface
as a collector straggler, never as a hang.
"""
import math
import socket
import struct
import threading

import numpy as np
import pytest

from repro.core.monitoring.collector import MetricsCollector, ReplicaReport
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Request
import repro.serving.transport as transport
from repro.serving.transport import (
    Connection,
    Listener,
    TransportError,
    decode_config,
    decode_report,
    decode_request,
    dial,
    encode_config,
    encode_report,
    encode_request,
    pack_frame,
    parse_addr,
)

from conftest import TINY_CFGS


# ------------------------------------------------------------------- codecs


def test_replica_report_round_trip_with_nan_and_inf():
    rep = ReplicaReport(
        replica_id=3, tick=7,
        latency_ms_samples=[1.5, float("nan"), float("inf"), -float("inf")],
        n_requests=4, n_errors=1, flop_util=float("nan"), hbm_util=0.25,
        ici_util=0.0, mem_frac=1.0, queue_depth=2, transport_ms=0.125)
    got = decode_report(encode_report(rep))
    assert got.replica_id == 3 and got.tick == 7
    assert got.latency_ms_samples[0] == 1.5
    assert math.isnan(got.latency_ms_samples[1])
    assert got.latency_ms_samples[2] == float("inf")
    assert got.latency_ms_samples[3] == -float("inf")
    assert math.isnan(got.flop_util)
    assert got.transport_ms == 0.125 and got.n_errors == 1


def test_replica_report_decoder_ignores_unknown_fields():
    d = encode_report(ReplicaReport(
        replica_id=0, tick=0, latency_ms_samples=[], n_requests=0,
        n_errors=0, flop_util=0, hbm_util=0, ici_util=0, mem_frac=0,
        queue_depth=0))
    d["added_in_a_future_version"] = 42       # wire compat: skew tolerated
    assert decode_report(d).replica_id == 0


def test_request_round_trip_including_frames_and_sampling():
    rng = np.random.default_rng(0)
    req = Request(rid=11, prompt=np.arange(3, 9, dtype=np.int32), gen_len=5,
                  sampling=SamplingParams(temperature=0.7, top_k=4, seed=9),
                  frames=rng.standard_normal((6, 32)).astype(np.float32))
    req.t_submit = 1.25
    req.tokens_out = [4, 5]
    got = decode_request(encode_request(req))
    np.testing.assert_array_equal(got.prompt, req.prompt)
    np.testing.assert_allclose(got.frames, req.frames)
    assert got.sampling == req.sampling
    assert got.gen_len == 5 and got.t_submit == 1.25
    assert got.tokens_out == [4, 5]
    # no frames → stays None (dense families never grow a frames key)
    lean = decode_request(encode_request(Request(
        rid=0, prompt=np.arange(3, 6, dtype=np.int32), gen_len=1)))
    assert lean.frames is None


@pytest.mark.parametrize("family", sorted(TINY_CFGS))
def test_model_config_round_trip_per_family(family):
    cfg = TINY_CFGS[family]
    assert decode_config(encode_config(cfg)) == cfg


# ------------------------------------------------------------------ framing


def _sock_pair():
    a, b = socket.socketpair()
    return Connection(a, timeout=10.0), Connection(b, timeout=10.0)


def test_framing_round_trip_and_back_to_back_messages():
    a, b = _sock_pair()
    a.send({"x": 1})
    a.send({"y": [1.5, None, "z"]})       # two frames queued in one buffer
    assert b.recv() == {"x": 1}
    assert b.recv() == {"y": [1.5, None, "z"]}
    a.close(), b.close()


def test_partial_frame_reads_reassemble():
    """A frame delivered in arbitrary byte-sized pieces (header split
    included) must reassemble into one message."""
    a_sock, b_sock = socket.socketpair()
    b = Connection(b_sock, timeout=10.0)
    payload = {"op": "step", "data": list(range(64)), "v": float("nan")}
    raw = pack_frame(payload)
    cuts = [0, 1, 3, 4, 9, len(raw) // 2, len(raw) - 1, len(raw)]

    def dribble():
        for lo, hi in zip(cuts, cuts[1:]):
            a_sock.sendall(raw[lo:hi])

    t = threading.Thread(target=dribble)
    t.start()
    got = b.recv()
    t.join()
    assert got["op"] == "step" and got["data"] == list(range(64))
    assert math.isnan(got["v"])
    a_sock.close(), b.close()


def test_eof_raises_transport_error_not_hang():
    a, b = _sock_pair()
    a.close()
    with pytest.raises(TransportError):
        b.recv()
    b.close()


def test_mid_frame_eof_raises_transport_error():
    a_sock, b_sock = socket.socketpair()
    b = Connection(b_sock, timeout=10.0)
    raw = pack_frame({"op": "step"})
    a_sock.sendall(raw[:len(raw) - 3])    # die mid-payload
    a_sock.close()
    with pytest.raises(TransportError):
        b.recv()
    b.close()


def test_pack_frame_enforces_max_frame_at_the_sender(monkeypatch):
    """Regression: MAX_FRAME used to be recv-side only — a sender could
    emit a frame the peer was guaranteed to kill the connection over.  The
    oversized payload must be rejected BEFORE any bytes hit the wire."""
    monkeypatch.setattr(transport, "MAX_FRAME", 64)
    with pytest.raises(TransportError, match="oversized"):
        pack_frame({"blob": "x" * 256})
    # an in-bounds frame still packs under the tightened limit
    assert pack_frame({"ok": 1})


def test_connection_send_oversized_leaves_channel_clean(monkeypatch):
    monkeypatch.setattr(transport, "MAX_FRAME", 64)
    a, b = _sock_pair()
    with pytest.raises(TransportError):
        a.send({"blob": "y" * 256})
    a.send({"after": True})               # nothing partial was written:
    assert b.recv() == {"after": True}    # the channel is still framed
    a.close(), b.close()


def test_garbage_payload_raises_typed_error_not_hang():
    a_sock, b_sock = socket.socketpair()
    b = Connection(b_sock, timeout=10.0)
    junk = b"\xff\xfe\x00not json at all"
    a_sock.sendall(struct.pack(">I", len(junk)) + junk)
    with pytest.raises(TransportError):
        b.recv()
    a_sock.close(), b.close()


# ---------------------------------------------------------------- TCP layer


def test_parse_addr():
    assert parse_addr("10.0.0.7:7077") == ("10.0.0.7", 7077)
    assert parse_addr(":0") == ("127.0.0.1", 0)
    with pytest.raises(ValueError):
        parse_addr("no-port")
    with pytest.raises(ValueError):
        parse_addr("host:seven")


def test_listener_dial_round_trip_with_keepalive_and_nodelay():
    lst = Listener("127.0.0.1", 0)
    assert lst.port != 0                  # kernel-picked port is realized
    client = dial(lst.host, lst.port, timeout=10.0)
    server = lst.accept(timeout=10.0, conn_timeout=10.0)
    client.send({"hello": "🌍", "v": float("inf")})
    got = server.recv()
    assert got["hello"] == "🌍" and got["v"] == float("inf")
    server.send({"ack": 1})
    assert client.recv() == {"ack": 1}
    for sock in (client.sock, server.sock):
        assert sock.getsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY)
        assert sock.getsockopt(socket.SOL_SOCKET, socket.SO_KEEPALIVE)
    client.close(), server.close(), lst.close()


def test_dial_refused_raises_transport_error():
    lst = Listener("127.0.0.1", 0)
    port = lst.port
    lst.close()                           # nobody listening on port now
    with pytest.raises(TransportError):
        dial("127.0.0.1", port, connect_timeout=5.0)


def test_accept_deadline_raises_transport_error():
    lst = Listener("127.0.0.1", 0)
    with pytest.raises(TransportError):
        lst.accept(timeout=0.05)
    lst.close()


# ------------------------------------------------------- crash → straggler


@pytest.mark.slow
def test_process_replica_crash_surfaces_as_straggler():
    """Kill the worker mid-run: the next step() must return (not hang) with
    the replica marked failed, its report must carry n_errors > 0, the
    collector must list it as a straggler, and the submitter-side requests
    must be recoverable (rewound) for requeue."""
    from repro.serving.replica import ProcessReplica

    cfg = TINY_CFGS["dense"]
    rep = ProcessReplica(cfg, slots=1, max_seq=16, prefill_chunk=4,
                         replica_id=7, rpc_timeout_s=60.0)
    try:
        req = Request(rid=1, prompt=np.arange(3, 8, dtype=np.int32),
                      gen_len=8)
        rep.submit(req, now=0.0)
        rep.step(1.0)                       # mid-generation
        rep._proc.kill()
        rep._proc.wait(timeout=30)
        out = rep.step(2.0)                 # EOF → failed, never a hang
        assert out == [] and rep.failed
        report = rep.report(tick=5)
        assert report.n_errors > 0 and report.replica_id == 7

        collector = MetricsCollector()
        collector.submit(report)
        assert 7 in collector.stragglers()
        # a healthy replica's clean report does NOT mark it
        collector.submit(ReplicaReport(
            replica_id=8, tick=5, latency_ms_samples=[1.0], n_requests=1,
            n_errors=0, flop_util=0.5, hbm_util=0.5, ici_util=0.0,
            mem_frac=0.5, queue_depth=0))
        assert collector.stragglers() == [7]

        lost = rep.lost_requests()
        assert [r.rid for r in lost] == [1]
        assert lost[0].tokens_out == [] and lost[0].t_admit is None
    finally:
        rep.close()
