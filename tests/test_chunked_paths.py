"""Chunked (flash-style) attention and chunked cross-entropy: numerics and
gradients must be identical to the full-materialization reference paths
(EXPERIMENTS.md §Perf C3/C4).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.steps as steps
from repro.models import LM
from repro.models.attention import Attention, _mask_bias, sdpa_ref

from conftest import TINY_CFGS, inputs_for


@pytest.fixture
def qkv():
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    return q, k, v


@pytest.mark.parametrize("causal,window", [(True, None), (True, 8),
                                           (False, None)])
def test_chunked_attention_matches_full(qkv, causal, window, monkeypatch):
    q, k, v = qkv
    B, S = q.shape[:2]
    monkeypatch.setattr(Attention, "CHUNK_Q", 16)   # force chunking
    got = Attention._sdpa_masked(q, k, v, causal=causal, window=window)
    q_pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    bias = (_mask_bias(q_pos, jnp.arange(S), causal=causal, window=window)
            if (causal or window is not None) else None)
    want = sdpa_ref(q, k, v, bias)
    np.testing.assert_allclose(got, want, atol=2e-6, rtol=2e-6)


def test_chunked_attention_gradients_match(qkv, monkeypatch):
    q, k, v = qkv

    def loss(chunked):
        if chunked:
            monkeypatch.setattr(Attention, "CHUNK_Q", 16)
        else:
            monkeypatch.setattr(Attention, "CHUNK_Q", 10**9)
        return lambda q_: Attention._sdpa_masked(
            q_, k, v, causal=True, window=None).sum()

    g_c = jax.grad(loss(True))(q)
    g_f = jax.grad(loss(False))(q)
    np.testing.assert_allclose(g_c, g_f, atol=3e-6, rtol=3e-6)


def test_chunked_ce_matches_full():
    cfg = TINY_CFGS["dense"]
    key = jax.random.PRNGKey(1)
    params, _ = LM.init(key, cfg)
    B, S = 2, 64
    batch = inputs_for(cfg, key, batch=B, seq=S)
    labels = jax.random.randint(jax.random.fold_in(key, 9), (B, S), 0,
                                cfg.vocab)
    h, _ = LM.apply(params, batch, cfg, return_hidden=True)
    ce_c = steps.chunked_cross_entropy(params, h, labels, cfg, chunk=16)
    logits, _ = LM.apply(params, batch, cfg)
    ce_f = steps.cross_entropy(logits, labels)
    np.testing.assert_allclose(float(ce_c), float(ce_f), rtol=1e-6)


def test_chunked_ce_gradients_match():
    cfg = TINY_CFGS["dense"]
    key = jax.random.PRNGKey(2)
    params, _ = LM.init(key, cfg)
    B, S = 2, 64
    batch = inputs_for(cfg, key, batch=B, seq=S)
    labels = jax.random.randint(jax.random.fold_in(key, 9), (B, S), 0,
                                cfg.vocab)

    def loss_chunked(p):
        h, _ = LM.apply(p, batch, cfg, return_hidden=True)
        return steps.chunked_cross_entropy(p, h, labels, cfg, chunk=16)

    def loss_full(p):
        logits, _ = LM.apply(p, batch, cfg)
        return steps.cross_entropy(logits, labels)

    g1, g2 = jax.grad(loss_chunked)(params), jax.grad(loss_full)(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-5)


def test_chunked_ce_respects_ignore_id():
    cfg = TINY_CFGS["dense"]
    key = jax.random.PRNGKey(3)
    params, _ = LM.init(key, cfg)
    batch = inputs_for(cfg, key, batch=2, seq=32)
    labels = jax.random.randint(key, (2, 32), 0, cfg.vocab)
    labels = labels.at[:, 16:].set(-1)              # mask second half
    h, _ = LM.apply(params, batch, cfg, return_hidden=True)
    ce_c = steps.chunked_cross_entropy(params, h, labels, cfg, chunk=8)
    logits, _ = LM.apply(params, batch, cfg)
    ce_f = steps.cross_entropy(logits, labels)
    np.testing.assert_allclose(float(ce_c), float(ce_f), rtol=1e-6)


def test_bf16_cast_train_step_still_learns():
    """cast_params_sharded path: bf16 compute with fp32 masters converges."""
    import dataclasses
    cfg = dataclasses.replace(TINY_CFGS["dense"], dtype="bfloat16")
    key = jax.random.PRNGKey(4)
    batch = inputs_for(cfg, key)
    batch["labels"] = batch["tokens"]
    train_step, (opt_init, _) = steps.make_train_step(cfg, lr=5e-3)
    state = steps.init_train_state(key, cfg, opt_init)
    step = jax.jit(train_step)
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
    # masters stay fp32
    assert all(p.dtype == jnp.float32 for p in jax.tree.leaves(state.params))
