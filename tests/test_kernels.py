"""Per-kernel allclose validation against the pure-jnp oracles (ref.py).

Each Pallas kernel runs in interpret mode on CPU (the kernel body executes
in Python) and must match the naive reference within dtype tolerance.
Hypothesis sweeps shapes/dtypes; fixed cases pin the block-boundary edges.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # collection must degrade to skips, not errors
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)

pytestmark = pytest.mark.kernels

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _qkv(key, B, Sq, Sk, H, KV, hd, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, Sq, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (B, Sk, KV, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (B, Sk, KV, hd), jnp.float32).astype(dtype)
    return q, k, v


# ---------------------------------------------------------------- flash

@settings(max_examples=12, deadline=None)
@given(
    B=st.sampled_from([1, 2]),
    S=st.sampled_from([64, 128, 256]),
    HKV=st.sampled_from([(4, 4), (8, 2), (4, 1)]),
    hd=st.sampled_from([32, 64]),
    causal=st.booleans(),
)
def test_flash_attention_matches_ref(B, S, HKV, hd, causal):
    H, KV = HKV
    q, k, v = _qkv(jax.random.PRNGKey(S + H), B, S, S, H, KV, hd, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, want, atol=TOL[jnp.float32],
                               rtol=TOL[jnp.float32])


@pytest.mark.parametrize("window", [8, 32, 64])
def test_flash_attention_sliding_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 128, 128, 4, 2, 32, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 128, 128, 4, 4, 64, jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=3e-2, rtol=3e-2)


def test_flash_attention_ragged_falls_back_to_ref():
    # Sq=100 not divisible by any power-of-two block: wrapper must still be
    # exact (it dispatches to the reference path).
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 100, 100, 4, 2, 32, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_flash_attention_matches_full_softmax_oracle():
    """ref itself cross-checked against an independent dense softmax."""
    B, S, H, KV, hd = 1, 32, 4, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(3), B, S, S, H, KV, hd, jnp.float32)
    G = H // KV
    k_full = jnp.repeat(k, G, axis=2)
    v_full = jnp.repeat(v, G, axis=2)
    mask = jnp.tril(jnp.ones((S, S), bool))
    scores = jnp.einsum("bshd,bthd->bhst", q, k_full) * hd ** -0.5
    scores = jnp.where(mask[None, None], scores, -1e9)
    want = jnp.einsum("bhst,bthd->bshd", jax.nn.softmax(scores, -1), v_full)
    got = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------- decode

@settings(max_examples=12, deadline=None)
@given(
    B=st.sampled_from([1, 2]),
    Smax=st.sampled_from([256, 512]),
    HKV=st.sampled_from([(4, 4), (8, 2)]),
    hd=st.sampled_from([32, 64]),
    frac=st.floats(0.1, 1.0),
)
def test_decode_attention_matches_ref(B, Smax, HKV, hd, frac):
    H, KV = HKV
    index = max(1, int(Smax * frac) - 1)
    key = jax.random.PRNGKey(Smax + H + index)
    q = jax.random.normal(key, (B, 1, H, hd), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(key, 1), (B, Smax, KV, hd))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (B, Smax, KV, hd))
    out = ops.decode_attention(q, kc, vc, index, block_k=128, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, index)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_decode_attention_index_zero():
    """Only slot 0 is valid — attention output must equal v[0] exactly."""
    B, Smax, H, KV, hd = 2, 256, 4, 2, 32
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (B, 1, H, hd), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(key, 1), (B, Smax, KV, hd))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (B, Smax, KV, hd))
    out = ops.decode_attention(q, kc, vc, 0, block_k=128, interpret=True)
    want = jnp.repeat(vc[:, 0:1], H // KV, axis=2).reshape(B, 1, H, hd)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------- ssm scan

@settings(max_examples=10, deadline=None)
@given(
    B=st.sampled_from([1, 2]),
    L=st.sampled_from([64, 128, 256]),
    H=st.sampled_from([2, 4]),
    hd=st.sampled_from([8, 16]),
    N=st.sampled_from([4, 8]),
    chunk=st.sampled_from([32, 64]),
)
def test_ssm_scan_matches_ref(B, L, H, hd, N, chunk):
    key = jax.random.PRNGKey(L + H + N)
    x = jax.random.normal(key, (B, L, H, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, L, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)))
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, L, H, N))
    C = jax.random.normal(jax.random.fold_in(key, 4), (B, L, H, N))
    out = ops.ssm_scan(x, dt, A, Bm, C, chunk=chunk, interpret=True)
    want = ref.ssm_scan_ref(x, dt, A, Bm, C)
    np.testing.assert_allclose(out, want, atol=3e-4, rtol=3e-4)


def test_ssm_scan_state_decay_property():
    """With A→-inf (instant forgetting) the output reduces to
    y_t = (dt_t·x_t)·(B_t·C_t) — no cross-step memory."""
    B, L, H, hd, N = 1, 64, 2, 8, 4
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, (B, L, H, hd), jnp.float32)
    dt = jnp.full((B, L, H), 100.0)      # exp(dt·A) ≈ 0 for A ≤ -1
    A = -jnp.ones((H,))
    Bm = jax.random.normal(jax.random.fold_in(key, 1), (B, L, H, N))
    C = jax.random.normal(jax.random.fold_in(key, 2), (B, L, H, N))
    out = ops.ssm_scan(x, dt, A, Bm, C, chunk=32, interpret=True)
    want = (dt[..., None] * x) * jnp.einsum("blhn,blhn->blh", Bm, C)[..., None]
    np.testing.assert_allclose(out, want, atol=1e-3, rtol=1e-3)


def test_ssm_scan_ragged_falls_back():
    B, L, H, hd, N = 1, 100, 2, 8, 4   # L % chunk != 0
    key = jax.random.PRNGKey(13)
    x = jax.random.normal(key, (B, L, H, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, L, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)))
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, L, H, N))
    C = jax.random.normal(jax.random.fold_in(key, 4), (B, L, H, N))
    out = ops.ssm_scan(x, dt, A, Bm, C, chunk=64, interpret=True)
    want = ref.ssm_scan_ref(x, dt, A, Bm, C)
    np.testing.assert_allclose(out, want, atol=3e-4, rtol=3e-4)
