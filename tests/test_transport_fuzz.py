"""Property fuzz for the wire contract: every codec round-trips (NaN/±inf
metrics, unicode payloads, nested MoE/SSM/Hybrid configs), and the framing
survives adversarial byte streams — random split points reassemble, while
truncated length prefixes, garbage payloads, and oversized declared lengths
all surface as typed TransportError, never a hang.
"""
import math
import socket
import struct
import threading

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import repro.serving.transport as transport
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import Request
from repro.serving.transport import (
    Connection,
    TransportError,
    decode_config,
    decode_report,
    decode_request,
    encode_config,
    encode_report,
    encode_request,
    pack_frame,
    unpack_payload,
)
from repro.core.monitoring.collector import ReplicaReport

from conftest import TINY_CFGS

SETTINGS = dict(max_examples=30, deadline=None)

finite_or_not = st.floats(allow_nan=True, allow_infinity=True, width=32)


def _eq(a: float, b: float) -> bool:
    return (a == b) or (math.isnan(a) and math.isnan(b))


# ------------------------------------------------------------------- codecs


@settings(**SETTINGS)
@given(lat=st.lists(finite_or_not, max_size=8),
       flop=finite_or_not, transport_ms=finite_or_not,
       n_req=st.integers(0, 1 << 20), n_err=st.integers(0, 64),
       qd=st.integers(0, 1 << 16))
def test_report_codec_round_trips_any_metric_values(lat, flop, transport_ms,
                                                    n_req, n_err, qd):
    rep = ReplicaReport(replica_id=1, tick=2, latency_ms_samples=lat,
                        n_requests=n_req, n_errors=n_err, flop_util=flop,
                        hbm_util=0.0, ici_util=0.0, mem_frac=0.0,
                        queue_depth=qd, transport_ms=transport_ms)
    got = decode_report(encode_report(rep))
    assert got.n_requests == n_req and got.n_errors == n_err
    assert got.queue_depth == qd
    assert _eq(got.flop_util, flop) and _eq(got.transport_ms, transport_ms)
    assert len(got.latency_ms_samples) == len(lat)
    assert all(_eq(a, b) for a, b in zip(got.latency_ms_samples, lat))


@settings(**SETTINGS)
@given(data=st.data(),
       prompt_len=st.integers(1, 12), gen_len=st.integers(1, 32),
       temperature=st.floats(0.0, 4.0), top_k=st.integers(0, 64),
       seed=st.integers(0, 2**31 - 1), with_frames=st.booleans())
def test_request_codec_round_trips(data, prompt_len, gen_len, temperature,
                                   top_k, seed, with_frames):
    prompt = np.asarray(data.draw(st.lists(
        st.integers(0, 2**31 - 1), min_size=prompt_len,
        max_size=prompt_len)), np.int32)
    frames = None
    if with_frames:
        frames = np.asarray(data.draw(st.lists(
            st.lists(st.floats(-1e6, 1e6, width=32), min_size=3, max_size=3),
            min_size=1, max_size=4)), np.float32)
    req = Request(rid=data.draw(st.integers(0, 2**31 - 1)), prompt=prompt,
                  gen_len=gen_len,
                  sampling=SamplingParams(temperature=temperature,
                                          top_k=top_k, seed=seed),
                  frames=frames)
    req.tokens_out = data.draw(st.lists(st.integers(0, 2**31 - 1),
                                        max_size=6))
    got = decode_request(encode_request(req))
    np.testing.assert_array_equal(got.prompt, req.prompt)
    assert got.rid == req.rid and got.gen_len == gen_len
    assert got.sampling == req.sampling
    assert got.tokens_out == req.tokens_out
    if with_frames:
        np.testing.assert_allclose(got.frames, frames, rtol=1e-6)
    else:
        assert got.frames is None


@settings(**SETTINGS)
@given(family=st.sampled_from(sorted(TINY_CFGS)),
       vocab=st.integers(8, 1 << 17), n_layers=st.integers(1, 12))
def test_config_codec_round_trips_every_family_with_overrides(family, vocab,
                                                              n_layers):
    """Nested MoE/SSM/Hybrid sub-configs must rebuild equal frozen configs
    for arbitrary top-level overrides, not just the fixture values."""
    import dataclasses
    cfg = dataclasses.replace(TINY_CFGS[family], vocab=vocab,
                              n_layers=n_layers)
    assert decode_config(encode_config(cfg)) == cfg


@settings(**SETTINGS)
@given(obj=st.recursive(
    st.none() | st.booleans() | st.integers(-2**53, 2**53) | st.text()
    | st.floats(allow_nan=False, allow_infinity=True),
    lambda kids: st.lists(kids, max_size=4)
    | st.dictionaries(st.text(max_size=8), kids, max_size=4),
    max_leaves=16))
def test_pack_unpack_round_trips_arbitrary_json_with_unicode(obj):
    raw = pack_frame(obj)
    (n,) = struct.unpack(">I", raw[:4])
    assert n == len(raw) - 4
    assert unpack_payload(raw[4:]) == obj


# ------------------------------------------------------------------ framing


@settings(**SETTINGS)
@given(data=st.data(), payload=st.dictionaries(
    st.text(max_size=6), st.text(max_size=12) | finite_or_not, max_size=6))
def test_random_split_points_reassemble(data, payload):
    """The kernel may deliver a frame in ANY byte-sized pieces — every cut
    set must reassemble to the identical message."""
    raw = pack_frame(payload)
    n_cuts = data.draw(st.integers(0, min(len(raw) - 1, 6)))
    cuts = sorted(data.draw(st.sets(st.integers(1, len(raw) - 1),
                                    min_size=n_cuts, max_size=n_cuts)))
    bounds = [0] + cuts + [len(raw)]
    a_sock, b_sock = socket.socketpair()
    b = Connection(b_sock, timeout=10.0)
    t = threading.Thread(target=lambda: [
        a_sock.sendall(raw[lo:hi]) for lo, hi in zip(bounds, bounds[1:])])
    t.start()
    got = b.recv()
    t.join()
    assert {k: v for k, v in got.items() if not isinstance(v, float)} == \
        {k: v for k, v in payload.items() if not isinstance(v, float)}
    for k, v in payload.items():
        if isinstance(v, float):
            assert _eq(got[k], v)
    a_sock.close(), b.close()


@settings(**SETTINGS)
@given(n_bytes=st.integers(0, 3))
def test_truncated_length_prefix_is_typed_error(n_bytes):
    a_sock, b_sock = socket.socketpair()
    b = Connection(b_sock, timeout=10.0)
    a_sock.sendall(b"\x00" * n_bytes)     # die inside the 4-byte header
    a_sock.close()
    with pytest.raises(TransportError):
        b.recv()
    b.close()


@settings(**SETTINGS)
@given(junk=st.binary(min_size=1, max_size=64))
def test_garbage_bytes_are_typed_error_not_hang(junk):
    """A correctly-framed payload of arbitrary garbage must decode-fail as
    TransportError (malformed JSON / invalid UTF-8), never wedge recv."""
    a_sock, b_sock = socket.socketpair()
    b = Connection(b_sock, timeout=10.0)
    a_sock.sendall(struct.pack(">I", len(junk)) + junk)
    a_sock.close()
    try:
        got = b.recv()                    # some byte strings ARE valid JSON
        assert not isinstance(got, bytes)
    except TransportError:
        pass
    b.close()


@settings(**SETTINGS)
@given(declared=st.integers(transport.MAX_FRAME + 1, 2**32 - 1))
def test_oversized_declared_length_rejected_before_allocation(declared):
    a_sock, b_sock = socket.socketpair()
    b = Connection(b_sock, timeout=10.0)
    a_sock.sendall(struct.pack(">I", declared) + b"x" * 16)
    with pytest.raises(TransportError, match="oversized"):
        b.recv()
    a_sock.close(), b.close()
