"""Optimizer substrate: AdamW math vs a numpy reference, global-norm clipping,
LR schedules, and the error-feedback int8 gradient compression invariants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # collection must degrade to skips, not errors
from hypothesis import given, settings, strategies as st

from repro.optim import adamw, apply_updates, clip_by_global_norm
from repro.optim.adamw import AdamWState
from repro.optim.compression import (
    compress_int8, decompress_int8, decompress_tree, error_feedback_compress,
    init_error_feedback,
)
from repro.optim.schedule import linear_warmup_cosine, wsd_schedule


def test_adamw_matches_numpy_reference():
    """2-D params (weight decay applies); 1-D params (no decay by default)."""
    lr, wd, b1, b2, eps = 1e-2, 0.1, 0.9, 0.999, 1e-8
    opt_init, opt_update = adamw(lr, weight_decay=wd, b1=b1, b2=b2, eps=eps)
    p = {"w": jnp.array([[1.0, -2.0, 3.0]]), "b": jnp.array([0.5])}
    g = {"w": jnp.array([[0.1, 0.2, -0.3]]), "b": jnp.array([0.05])}
    state = opt_init(p)
    ref = {k: np.asarray(p[k]) for k in p}
    mom = {k: np.zeros_like(ref[k]) for k in p}
    vel = {k: np.zeros_like(ref[k]) for k in p}
    for t in range(1, 4):
        updates, state = opt_update(g, state, p)
        p = apply_updates(p, updates)
        for k in ref:
            gw = np.asarray(g[k])
            mom[k] = b1 * mom[k] + (1 - b1) * gw
            vel[k] = b2 * vel[k] + (1 - b2) * gw ** 2
            mhat = mom[k] / (1 - b1 ** t)
            nhat = vel[k] / (1 - b2 ** t)
            decay = wd * ref[k] if ref[k].ndim >= 2 else 0.0
            ref[k] = ref[k] - lr * (mhat / (np.sqrt(nhat) + eps) + decay)
            np.testing.assert_allclose(np.asarray(p[k]), ref[k], rtol=1e-5)


def test_adamw_converges_on_quadratic():
    opt_init, opt_update = adamw(0.1, weight_decay=0.0)
    p = {"x": jnp.array([5.0, -3.0])}
    state = opt_init(p)
    for _ in range(200):
        g = jax.tree.map(lambda x: 2 * x, p)     # d/dx x^2
        updates, state = opt_update(g, state, p)
        p = apply_updates(p, updates)
    assert float(jnp.abs(p["x"]).max()) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0, 0.0]), "b": jnp.array([0.0, 4.0])}   # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)
    # under the limit: untouched
    same, norm2 = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g["a"]))


def test_cosine_schedule_endpoints():
    sched = linear_warmup_cosine(1e-3, warmup_steps=10, total_steps=100,
                                 final_frac=0.1)
    assert float(sched(0)) < 1e-4 + 1e-9
    np.testing.assert_allclose(float(sched(10)), 1e-3, rtol=1e-5)
    np.testing.assert_allclose(float(sched(100)), 1e-4, rtol=1e-5)
    # monotone decay after warmup
    vals = [float(sched(s)) for s in range(10, 101, 10)]
    assert all(a >= b - 1e-12 for a, b in zip(vals, vals[1:]))


def test_wsd_schedule_phases():
    sched = wsd_schedule(1e-3, warmup_steps=10, total_steps=100, decay_frac=0.2)
    np.testing.assert_allclose(float(sched(50)), 1e-3, rtol=1e-6)   # stable
    assert float(sched(5)) < 1e-3                                   # warmup
    assert float(sched(95)) < 1e-3                                  # decay
    np.testing.assert_allclose(float(sched(100)), 0.0, atol=1e-9)


# ---------------------------------------------------------------- compression

@settings(max_examples=25, deadline=None)
@given(scale=st.floats(1e-3, 1e3), n=st.integers(1, 256))
def test_int8_roundtrip_error_bound(scale, n):
    x = scale * jax.random.normal(jax.random.PRNGKey(n), (n,))
    q, s = compress_int8(x)
    err = jnp.abs(decompress_int8(q, s) - x)
    # quantization error ≤ half a quantization step
    assert float(err.max()) <= float(s) * 0.5 + 1e-9
    assert q.dtype == jnp.int8


def test_error_feedback_invariant():
    """decompress(q) + new_residual == grad + old_residual (lossless ledger)."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64,)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (8,)) * 10}
    res = init_error_feedback(g)
    comp, res2 = error_feedback_compress(g, res)
    deq = decompress_tree(comp)
    for k in g:
        np.testing.assert_allclose(np.asarray(deq[k] + res2[k]),
                                   np.asarray(g[k] + res[k]), atol=1e-5)


def test_error_feedback_unbiased_over_steps():
    """Residual carrying ⇒ the *sum* of decompressed grads tracks the sum of
    true grads (compression error does not accumulate)."""
    key = jax.random.PRNGKey(2)
    g_true = [0.01 * jax.random.normal(jax.random.fold_in(key, i), (128,))
              for i in range(50)]
    res = init_error_feedback({"w": g_true[0]})
    acc_deq = np.zeros(128)
    acc_true = np.zeros(128)
    for g in g_true:
        comp, res = error_feedback_compress({"w": g}, res)
        acc_deq += np.asarray(decompress_tree(comp)["w"])
        acc_true += np.asarray(g)
    # final residual bounds the gap
    gap = np.abs(acc_deq + np.asarray(res["w"]) - acc_true).max()
    assert gap < 1e-4
