"""The transport-decoupled replica fabric, end to end.

* Protocol conformance: the router drives InProcess / Sharded / Process
  replicas through the same surface; legacy bare-engine factories still work.
* Cross-topology equivalence (the PR's acceptance bar): run_closed_loop on
  the SAME seed produces identical token streams and identical scaling
  decisions on the inproc, sharded (1-device mesh), and proc topologies;
  ShardedReplica matches InProcessReplica token streams AND decode logits on
  a ≥2-device mesh (subprocess re-exec with
  --xla_force_host_platform_device_count).
* Failure semantics: a ProcessReplica whose worker dies mid-run is reaped by
  the router — lost requests rewound + requeued, a replacement restores the
  actuated count, every request still completes exactly once.
* Straggler eviction: the collector's straggler feed actuates
  router.evict_stragglers.
"""
import functools
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.serving import (
    InProcessReplica, ReplicaRouter, Request, ServingEngine, ShardedReplica,
)
from repro.serving.engine import EngineCore

from conftest import TINY_CFGS

MAX_SEQ = 24
SLOTS = 2


@functools.lru_cache(maxsize=None)
def shared_core() -> EngineCore:
    return EngineCore(TINY_CFGS["dense"], MAX_SEQ, seed=0)


def _requests(n, prompt_len=6, gen_len=4, seed=0):
    cfg = TINY_CFGS["dense"]
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(
                3, cfg.vocab, size=prompt_len).astype(np.int32),
                gen_len=gen_len) for i in range(n)]


def _run_replica(rep, reqs, *, stagger_after=2):
    done, now = [], 0.0
    for r in reqs[:2]:
        rep.submit(r, now=0.0)
    for _ in range(stagger_after):
        now += 1.0
        done.extend(rep.step(now))
    for r in reqs[2:]:
        rep.submit(r, now=now)
    while len(done) < len(reqs) and now < 200:
        now += 1.0
        done.extend(rep.step(now))
    return {r.rid: tuple(r.tokens_out) for r in done}


# ------------------------------------------------------------- protocol


def test_inprocess_replica_protocol_surface():
    rep = InProcessReplica.build(TINY_CFGS["dense"], slots=SLOTS,
                                 max_seq=MAX_SEQ, core=shared_core(),
                                 replica_id=3)
    reqs = _requests(3)
    assert rep.idle and rep.load == 0.0 and rep.transport_ms == 0.0
    for r in reqs:
        rep.submit(r, now=0.0)
    assert rep.pending == 3 and rep.load == 1.5
    done = []
    now = 0.0
    while len(done) < 3 and now < 100:
        now += 1.0
        done.extend(rep.step(now))
    assert rep.idle and not rep.failed
    report = rep.report(tick=0)
    assert report.replica_id == 3 and report.n_requests == 3
    assert report.transport_ms == 0.0
    lt = rep.lifetime()
    assert lt["total_completed"] == 3
    assert lt["total_tokens"] == sum(len(r.tokens_out) for r in done)
    assert rep.lost_requests() == []


def test_evacuate_returns_queued_and_preempted_rewound():
    rep = InProcessReplica.build(TINY_CFGS["dense"], slots=SLOTS,
                                 max_seq=MAX_SEQ, core=shared_core())
    reqs = _requests(4, gen_len=6)
    for r in reqs:
        rep.submit(r, now=0.0)
    rep.step(1.0)                          # 2 admitted, 2 queued
    rep.step(2.0)                          # a token or two generated
    out = rep.evacuate()
    assert sorted(r.rid for r in out) == [0, 1, 2, 3]
    assert rep.idle and rep.draining
    for r in out:                          # rewound: ready for requeue
        assert r.tokens_out == [] and r.t_admit is None
        assert r.t_submit == 0.0           # submit time survives (latency!)
    rep.resume()
    assert not rep.draining


def test_router_accepts_legacy_bare_engine_factory():
    def factory(replica_id):
        return ServingEngine(TINY_CFGS["dense"], slots=SLOTS,
                             max_seq=MAX_SEQ, core=shared_core(),
                             replica_id=replica_id)

    router = ReplicaRouter(factory, n_replicas=2)
    assert all(isinstance(r, InProcessReplica) for r in router.replicas)
    reqs = _requests(3)
    for r in reqs:
        router.submit(r, now=0.0)
    done, now = [], 0.0
    while len(done) < 3 and now < 100:
        now += 1.0
        done.extend(router.step(now))
    assert sorted(r.rid for r in done) == [0, 1, 2]


def test_from_topology_rejects_unknown():
    with pytest.raises(ValueError):
        ReplicaRouter.from_topology(TINY_CFGS["dense"], "carrier-pigeon",
                                    slots=SLOTS, max_seq=MAX_SEQ)


def test_sharded_replica_requires_divisible_slots():
    with pytest.raises(ValueError):
        ShardedReplica(TINY_CFGS["dense"], slots=3, max_seq=MAX_SEQ,
                       mesh=_mesh_1d(2))


def _mesh_1d(n):
    import jax

    from repro.launch.mesh import make_mesh
    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} devices")
    return make_mesh((n,), ("data",))


def test_sharded_replica_matches_inproc_on_single_device_mesh():
    """The shard_map decode path itself (specs, donation, per-leaf slot-axis
    mapping) on a 1-device mesh — cheap coverage that runs everywhere; the
    multi-device equivalence runs in the subprocess test below."""
    reqs = _requests(3, seed=5)
    want = _run_replica(InProcessReplica.build(
        TINY_CFGS["dense"], slots=SLOTS, max_seq=MAX_SEQ, core=shared_core()),
        _requests(3, seed=5))
    got = _run_replica(ShardedReplica(
        TINY_CFGS["dense"], slots=SLOTS, max_seq=MAX_SEQ, mesh=_mesh_1d(1),
        core=shared_core()), reqs)
    assert got == want


def test_evict_stragglers_replaces_and_requeues():
    router = ReplicaRouter.shared_core(TINY_CFGS["dense"], slots=SLOTS,
                                       max_seq=MAX_SEQ, n_replicas=3,
                                       max_replicas=4)
    reqs = _requests(6, gen_len=5)
    for r in reqs:
        router.submit(r, now=0.0)
    router.step(1.0)
    victim = router.replicas[1].replica_id
    evicted = router.evict_stragglers([victim, 999], now=1.0)
    assert evicted == [victim]             # unknown ids are ignored
    assert router.replica_count == 3       # replacement restored the count
    assert victim not in [r.replica_id for r in router.replicas]
    done, now = [], 1.0
    while len(done) < 6 and now < 100:
        now += 1.0
        done.extend(router.step(now))
    assert sorted(r.rid for r in done) == list(range(6))


def test_reaped_replica_reports_crash_then_one_clean_tombstone():
    """A retired (failed) replica sends exactly TWO more reports: its crash
    report (the reap happened inside step(), so this is the only way the
    collector ever sees the failure), then ONE clean tombstone — the
    collector replays each replica's last report every aggregate, so
    leaving the n_errors report in place would keep a long-dead replica on
    the straggler list forever."""
    router = ReplicaRouter.shared_core(TINY_CFGS["dense"], slots=SLOTS,
                                       max_seq=MAX_SEQ, n_replicas=2,
                                       max_replicas=3)
    dead = router.replicas[1]
    dead.failed = True                     # simulate a lost transport
    router.step(1.0)                       # reaped + replaced
    assert dead.replica_id not in [r.replica_id for r in router.replicas]
    assert router.replica_count == 2
    obit = [r for r in router.reports(0) if r.replica_id == dead.replica_id]
    assert len(obit) == 1                  # round 1: the final word
    tomb = [r for r in router.reports(1) if r.replica_id == dead.replica_id]
    assert len(tomb) == 1 and tomb[0].n_errors == 0
    assert not tomb[0].latency_ms_samples  # round 2: clean tombstone
    # later report rounds no longer mention the dead replica
    assert all(r.replica_id != dead.replica_id for r in router.reports(2))


def test_step_preserves_collected_completions_when_a_replica_raises():
    """Completions collected before a later replica's finish_step raises
    are not recoverable anywhere else (their stubs handed them over) — the
    router must stash and redeliver them on the next step, not drop them."""
    router = ReplicaRouter.shared_core(TINY_CFGS["dense"], slots=SLOTS,
                                       max_seq=MAX_SEQ, n_replicas=2,
                                       max_replicas=2)
    r0, r1 = _requests(2, prompt_len=5, gen_len=1)
    router.submit(r0, now=0.0)             # → replica 0
    router.submit(r1, now=0.0)             # → replica 1
    bad = router.replicas[1]
    real_finish = bad.finish_step
    bad.finish_step = lambda: (_ for _ in ()).throw(
        RuntimeError("engine bug bounce"))
    with pytest.raises(RuntimeError):
        router.step(1.0)                   # replica 0 completed r0 already
    bad.finish_step = real_finish
    done = []
    now = 1.0
    while len(done) < 2 and now < 50:
        now += 1.0
        done.extend(router.step(now))
    assert sorted(r.rid for r in done) == [0, 1]   # r0 redelivered


@pytest.mark.slow
def test_rpc_drains_pending_step_reply_before_other_ops():
    """A non-step RPC issued while a step reply is still unread (abandoned
    round) must drain the stale reply first — otherwise every later RPC on
    the connection reads the previous op's reply."""
    from repro.serving.replica import ProcessReplica

    cfg = TINY_CFGS["dense"]
    rep = ProcessReplica(cfg, slots=SLOTS, max_seq=16, prefill_chunk=4,
                         replica_id=4)
    try:
        reqs = _requests(2, prompt_len=5, gen_len=2)
        for r in reqs:
            rep.submit(r, now=0.0)
        rep.begin_step(1.0)                # round in flight, reply unread
        report = rep.report(tick=0)        # must drain, then see a window
        assert report.replica_id == 4 and report.n_errors == 0
        done = rep.finish_step()           # drained completions, if any
        now = 1.0
        while len(done) < 2 and now < 50:
            now += 1.0
            done.extend(rep.step(now))
        assert sorted(r.rid for r in done) == [0, 1]
        assert all(len(r.tokens_out) == 2 for r in done)
        assert rep.lifetime()["total_completed"] == 2
    finally:
        rep.close()


def test_dead_parked_replica_is_retired_via_reports():
    """Nothing steps a parked replica — the report poll is the only place
    its death can be noticed.  reports() must retire the corpse through the
    same crash-report-then-tombstone flow as a live-list failure, and a
    later scale-up must build a fresh replica, not revive the corpse."""
    router = ReplicaRouter.shared_core(TINY_CFGS["dense"], slots=SLOTS,
                                       max_seq=MAX_SEQ, n_replicas=2,
                                       max_replicas=2)
    router.scale_to(1)
    parked = router._parked[0]
    parked.failed = True                   # worker died while parked
    polled = [r for r in router.reports(0)
              if r.replica_id == parked.replica_id]
    assert len(polled) == 1                # the poll that detected death
    assert not router._parked
    tomb = [r for r in router.reports(1)
            if r.replica_id == parked.replica_id]
    assert len(tomb) == 1 and tomb[0].n_errors == 0
    assert all(r.replica_id != parked.replica_id
               for r in router.reports(2))
    router.scale_to(2)                     # revive demand → NEW replica
    assert parked.replica_id not in [r.replica_id for r in router.replicas]
    assert router.replica_count == 2


def test_parked_straggler_ewma_cleared_by_idle_reports():
    """A parked straggler keeps reporting empty windows — that must END its
    latency evidence: otherwise its stale high EWMA keeps it flagged
    forever, skews the fleet median, and re-condemns it on revival."""
    from repro.core.monitoring.collector import MetricsCollector, ReplicaReport

    def report(rid, tick, lat, n):
        return ReplicaReport(replica_id=rid, tick=tick,
                             latency_ms_samples=lat, n_requests=n,
                             n_errors=0, flop_util=0.5, hbm_util=0.5,
                             ici_util=0.0, mem_frac=0.5, queue_depth=0)

    c = MetricsCollector(straggler_factor=1.5)
    for rid in range(4):
        lat = [400.0] * 8 if rid == 3 else [100.0] * 8
        c.submit(report(rid, 0, lat, 8))
    assert c.stragglers() == [3]
    c.submit(report(3, 1, [], 0))          # evicted → parked → idle window
    assert c.stragglers() == []
    c.submit(report(3, 2, [105.0] * 8, 8))  # revived, healthy this time
    assert c.stragglers() == []


# ------------------------------------------- transport as a control feature


def test_scaler_budgets_for_transport_latency():
    """DynamicScaler receives per-replica transport latency via the fleet
    record: above the deadband it comes off the SLO budget (→ more
    replicas); below it (loopback noise) it changes nothing, so inproc and
    local-socket fleets plan identically."""
    from repro.core.allocation.forecaster import WorkloadForecaster
    from repro.core.scaling.scaler import DynamicScaler, ScalingConstraints

    def perf_model(replicas, rps):
        lat = 400.0 / max(replicas, 1) * max(rps, 1.0)
        return lat, min(rps / (4.0 * replicas), 1.0)

    constraints = ScalingConstraints(min_replicas=1, max_replicas=8,
                                     slo_ms=450.0, cooldown_ticks=0)

    def decide(transport_ms):
        fc = WorkloadForecaster()
        for _ in range(8):
            fc.update(1.0)
        scaler = DynamicScaler(fc, perf_model)
        metrics = {"rps": 1.0, "rps_window": [1.0],
                   "transport_ms": transport_ms}
        return scaler.compute_scaling_decision(
            metrics, constraints, current_replicas=1).target_replicas

    # perf model: 1 replica → 400ms.  Plain SLO 450ms: 1 replica is fine.
    assert decide(0.0) == 1
    # loopback noise (< 2% of SLO = 9ms): identical plan
    assert decide(5.0) == decide(0.0)
    # a genuinely remote fleet: 100ms off the budget → 400ms no longer fits
    assert decide(100.0) == 2


def test_selector_transport_gate():
    from repro.core.orchestration.selector import (
        DecisionTreeSelector, DeploymentContext,
    )

    tree = DecisionTreeSelector()
    base = dict(model_params_b=7.0, traffic_rps=200.0, slo_ms=300.0,
                error_budget=0.0005, spare_capacity_frac=0.6,
                cost_sensitivity=0.2, is_critical=True)
    local = tree.select(DeploymentContext(**base))
    assert local == "shadow"               # unchanged default behavior
    remote = tree.select(DeploymentContext(**base, transport_ms=60.0))
    assert remote == "canary_10"           # no double-fleet mirroring


def test_collector_aggregates_transport_ms():
    from repro.core.monitoring.collector import MetricsCollector, ReplicaReport

    def report(rid, tick, t_ms):
        return ReplicaReport(replica_id=rid, tick=tick,
                             latency_ms_samples=[], n_requests=0,
                             n_errors=0, flop_util=0, hbm_util=0,
                             ici_util=0, mem_frac=0, queue_depth=0,
                             transport_ms=t_ms)

    c = MetricsCollector()
    c.submit(report(0, 0, 0.0))            # an in-process replica
    c.submit(report(1, 0, 0.0))
    rec0 = c.aggregate(0, n_replicas=2, max_replicas=4)
    assert rec0["transport_ms"] == 0.0
    c.submit(report(0, 1, 2.0))            # the fleet went remote
    c.submit(report(1, 1, 6.0))
    rec1 = c.aggregate(1, n_replicas=2, max_replicas=4)
    assert rec1["transport_ms"] == pytest.approx(4.0)


# ------------------------------------------------- multi-device sharding

_SHARDED_SUBPROC = r"""
import numpy as np
import jax
assert len(jax.devices()) == 2, jax.devices()
from repro.models.config import ModelConfig
from repro.serving import InProcessReplica, Request, ShardedReplica
from repro.launch.mesh import make_mesh

cfg = ModelConfig(name="tiny-dense", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab=64, qkv_bias=True,
                  param_dtype="float32", dtype="float32")
MAX_SEQ, SLOTS = 24, 2

def requests(seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(3, cfg.vocab, size=8
                    ).astype(np.int32), gen_len=5) for i in range(3)]

inproc = InProcessReplica.build(cfg, slots=SLOTS, max_seq=MAX_SEQ,
                                prefill_chunk=4)
sharded = ShardedReplica(cfg, slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=4,
                         mesh=make_mesh((2,), ("data",)))

# 1) logits parity on one staggered decode tick (the sharded kernel itself)
for rep in (inproc, sharded):
    reqs = requests()
    rep.submit(reqs[0], now=0.0)
    rep.step(1.0)                          # slot 0 one tick ahead
    rep.submit(reqs[1], now=1.0)
    rep.step(2.0)
import jax.numpy as jnp
# decode donates its cache argument: hand each call a copy so the engines'
# live pools survive for the token-stream run below
li, _ = inproc.engine.core.decode(inproc.engine.params, inproc.engine.tokens,
                                  jax.tree.map(jnp.copy,
                                               inproc.engine.pool.cache))
ls, _ = sharded.engine.decode(sharded.engine.params, sharded.engine.tokens,
                              jax.tree.map(jnp.copy,
                                           sharded.engine.pool.cache))
np.testing.assert_allclose(np.asarray(li, np.float32),
                           np.asarray(ls, np.float32), atol=1e-5, rtol=1e-5)

# 2) full token-stream parity, staggered admission
def run(rep, reqs):
    done, now = [], 2.0
    rep.submit(reqs[2], now=now)
    while len(done) < 3 and now < 200:
        now += 1.0
        done.extend(rep.step(now))
    return {r.rid: r.tokens_out for r in done}

a, b = run(inproc, requests()), run(sharded, requests())
assert a == b, (a, b)
print("SHARDED_EQ_OK")
"""


@pytest.mark.slow
def test_sharded_replica_matches_inproc_on_two_device_mesh():
    """Acceptance: ShardedReplica (slot axis sharded over a 2-device mesh
    via repro.sharding.shard_map) matches InProcessReplica decode logits and
    token streams.  Re-execs python with the host-platform device-count
    override — the main test process must keep its single default device."""
    env = os.environ.copy()
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run([sys.executable, "-c", _SHARDED_SUBPROC], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SHARDED_EQ_OK" in out.stdout


# ------------------------------------------------- cross-topology closed loop


@pytest.mark.slow
def test_closed_loop_identical_across_topologies():
    """Acceptance: run_closed_loop on the same seed produces identical token
    streams AND identical scaling decisions on the inproc, sharded, proc,
    and tcp topologies — the control plane cannot tell the fabrics apart."""
    from repro.serving.closed_loop import LoopConfig, run_closed_loop

    cfg = TINY_CFGS["dense"]
    results = {}
    for topology in ("inproc", "sharded", "proc", "tcp"):
        lc = LoopConfig(slots=2, max_replicas=2, max_seq=32, prefill_chunk=4,
                        steps_per_tick=6, topology=topology)
        sink = []
        router, logs = run_closed_loop(cfg, autoscale=True, ticks=8, seed=0,
                                       lc=lc, sink=sink)
        results[topology] = {
            "decisions": [(t.replicas, t.reason) for t in logs],
            "served": [t.served for t in logs],
            "streams": {r.rid: tuple(r.tokens_out) for r in sink},
        }
        router.close()
    assert results["inproc"] == results["sharded"] == results["proc"] \
        == results["tcp"]
    assert results["inproc"]["streams"]          # the loop actually served


@pytest.mark.slow
def test_closed_loop_identical_across_topologies_with_regions():
    """The geographic extension of the cross-topology bar: a REGION-tagged
    fleet — striped across two regions with the plan's RTT injected as
    DelayedReplica shims — still produces identical token streams and
    scaling decisions on inproc, proc, and tcp.  The injected latency
    lives on the virtual clock, so it cannot tell the fabrics apart
    either; and a region-less run on the same seed is unchanged by the
    region machinery existing (its TickLog spill channel stays zero)."""
    from repro.serving.closed_loop import LoopConfig, run_closed_loop

    cfg = TINY_CFGS["dense"]
    results = {}
    for topology in ("inproc", "proc", "tcp"):
        lc = LoopConfig(slots=2, max_replicas=2, max_seq=32, prefill_chunk=4,
                        steps_per_tick=6, topology=topology,
                        reserved_replicas=2, regions=("na", "apac"),
                        spot_market=True)
        sink = []
        router, logs = run_closed_loop(cfg, autoscale=True, ticks=8, seed=0,
                                       lc=lc, sink=sink)
        results[topology] = {
            "decisions": [(t.replicas, t.reason) for t in logs],
            "served": [t.served for t in logs],
            "spills": [t.region_spills for t in logs],
            "streams": {r.rid: tuple(r.tokens_out) for r in sink},
        }
        router.close()
    assert results["inproc"] == results["proc"] == results["tcp"]
    assert results["inproc"]["streams"]          # the loop actually served

    lc = LoopConfig(slots=2, max_replicas=2, max_seq=32, prefill_chunk=4,
                    steps_per_tick=6)            # region-less control
    sink = []
    router, logs = run_closed_loop(cfg, autoscale=True, ticks=8, seed=0,
                                   lc=lc, sink=sink)
    router.close()
    assert all(t.region_spills == 0 for t in logs)
    assert {r.rid: tuple(r.tokens_out) for r in sink}


@pytest.mark.slow
def test_tcp_router_attaches_to_prestarted_fleet():
    """The cross-host shape: pods started by an external scheduler
    (launch_fleet stands in), a router that ATTACHES via addrs — requests
    complete, per-replica transport is measured, and detaching (close)
    leaves the pods alive for the next router."""
    from repro.serving import TcpReplica, launch_fleet

    cfg = TINY_CFGS["dense"]
    with launch_fleet(2) as fleet:
        router = ReplicaRouter.from_topology(
            cfg, "tcp", slots=SLOTS, max_seq=16, prefill_chunk=4,
            n_replicas=2, max_replicas=2, addrs=fleet.addrs)
        assert all(isinstance(r, TcpReplica) for r in router.replicas)
        assert [r.addr for r in router.replicas] == fleet.addrs
        reqs = _requests(4, prompt_len=5, gen_len=3)
        for r in reqs:
            router.submit(r, now=0.0)
        done, now = [], 0.0
        while len(done) < 4 and now < 100:
            now += 1.0
            done.extend(router.step(now))
        assert sorted(r.rid for r in done) == [0, 1, 2, 3]
        router.reports(0)                  # report RPC → transport EWMA
        assert all(r.transport_ms > 0.0 for r in router.replicas)
        router.replicas[0].begin_step(now + 1)   # detach MID-ROUND: the pod
        router.close()                     # must survive its reply landing
        assert all(proc.poll() is None for _, proc in fleet.workers)  # on a
        #                                    dead socket and re-enter accept
        # a SECOND router re-attaches to the same living pods
        router2 = ReplicaRouter.from_topology(
            cfg, "tcp", slots=SLOTS, max_seq=16, prefill_chunk=4,
            n_replicas=2, max_replicas=2, addrs=fleet.addrs)
        [req] = _requests(1, prompt_len=5, gen_len=2)
        router2.submit(req, now=0.0)
        done, now = [], 0.0
        while not done and now < 50:
            now += 1.0
            done.extend(router2.step(now))
        assert [r.rid for r in done] == [0]
        router2.close()


def _skip_if_pod_unavailable(e: Exception):
    """The pod smoke is gated, not required: where multi-process init is
    unavailable (no jax.distributed backend, sandboxed CI) skip cleanly —
    any OTHER failure is a real bug and must fail the test."""
    msg = str(e).lower()
    if any(s in msg for s in ("distributed", "initialize", "coordinator")):
        pytest.skip(f"multi-process pod unavailable here: {e}")
    raise e


@pytest.mark.slow
def test_pod_replica_matches_sharded_topology_with_live_observer():
    """Acceptance: a 2-process pod — two worker ranks joined over
    jax.distributed, rank 0 the RPC head, lockstep verified by per-step
    digests — serves a seeded stream observationally identical to the
    single-host `sharded` topology, while a READ-ONLY metrics attach polls
    the head concurrently during decode without perturbing the stream (the
    observer's lifetime counters match the router-side stub's at every
    poll)."""
    from repro.serving import DistributedPodReplica, MetricsObserver

    cfg = TINY_CFGS["dense"]
    want = _run_replica(ShardedReplica(
        cfg, slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=4,
        mesh=_mesh_1d(1)), _requests(3, seed=7))
    try:
        pod = DistributedPodReplica(cfg, slots=SLOTS, max_seq=MAX_SEQ,
                                    prefill_chunk=4, pod_size=2)
    except Exception as e:
        _skip_if_pod_unavailable(e)
    try:
        obs = MetricsObserver(pod.addr)
        info = obs.status()["pod"]
        assert info["rank"] == 0 and info["size"] == 2
        assert info["process_count"] == 2        # the cluster really formed
        reqs = _requests(3, seed=7)
        done, now = [], 0.0
        for r in reqs[:2]:
            pod.submit(r, now=0.0)
        for _ in range(2):
            now += 1.0
            done.extend(pod.step(now))
            assert obs.lifetime() == pod.lifetime()   # concurrent, agreeing
        for r in reqs[2:]:
            pod.submit(r, now=now)
        while len(done) < 3 and now < 200:
            now += 1.0
            done.extend(pod.step(now))
            assert obs.lifetime() == pod.lifetime()
        got = {r.rid: tuple(r.tokens_out) for r in done}
        assert got == want
        obs.close()
    finally:
        pod.close()


@pytest.mark.slow
def test_pod_closed_loop_matches_inproc():
    """The router addresses a pod as ONE replica: the full closed loop on
    the pod topology (each replica = a 2-rank pod) reproduces the inproc
    topology's token streams and scaling decisions on the same seed."""
    from repro.serving.closed_loop import LoopConfig, run_closed_loop

    cfg = TINY_CFGS["dense"]
    results = {}
    for topology in ("inproc", "pod"):
        lc = LoopConfig(slots=2, max_replicas=2, max_seq=32, prefill_chunk=4,
                        steps_per_tick=6, topology=topology, pod_size=2)
        sink = []
        try:
            router, logs = run_closed_loop(cfg, autoscale=True, ticks=6,
                                           seed=0, lc=lc, sink=sink)
        except Exception as e:
            _skip_if_pod_unavailable(e)
        results[topology] = {
            "decisions": [(t.replicas, t.reason) for t in logs],
            "served": [t.served for t in logs],
            "streams": {r.rid: tuple(r.tokens_out) for r in sink},
        }
        router.close()
    assert results["inproc"] == results["pod"]
    assert results["inproc"]["streams"]


@pytest.mark.slow
def test_submit_reroutes_around_silently_dead_replica():
    """A worker that dies BETWEEN steps is invisible until an RPC touches
    it.  The submit that discovers the corpse must reroute to a survivor —
    not crash the driver, not lose the request."""
    cfg = TINY_CFGS["dense"]
    router = ReplicaRouter.from_topology(cfg, "proc", slots=SLOTS,
                                         max_seq=16, prefill_chunk=4,
                                         n_replicas=2, max_replicas=2)
    try:
        dead = router.replicas[1]
        dead._proc.kill()
        dead._proc.wait(timeout=30)
        reqs = _requests(4, prompt_len=5, gen_len=3)
        for r in reqs:                     # second submit routes to the
            router.submit(r, now=0.0)      # corpse and must fail over
        assert dead.failed
        done, now = [], 0.0
        while len(done) < 4 and now < 100:
            now += 1.0
            done.extend(router.step(now))
        assert sorted(r.rid for r in done) == [0, 1, 2, 3]
    finally:
        router.close()


@pytest.mark.slow
def test_single_replica_fleet_self_heals_on_submit():
    """The hardest failover case: a ONE-replica proc fleet whose worker
    dies between steps.  The submit that discovers the corpse finds no
    survivors — it must reap the corpse and build the replacement right
    there (step()'s reap path hasn't run yet), then route to it."""
    cfg = TINY_CFGS["dense"]
    router = ReplicaRouter.from_topology(cfg, "proc", slots=SLOTS,
                                         max_seq=16, prefill_chunk=4,
                                         n_replicas=1, max_replicas=2)
    try:
        dead = router.replicas[0]
        dead._proc.kill()
        dead._proc.wait(timeout=30)
        [req] = _requests(1, prompt_len=5, gen_len=3)
        router.submit(req, now=0.0)        # discovers, reaps, replaces
        assert router.replica_count == 1
        assert router.replicas[0] is not dead
        done, now = [], 0.0
        while not done and now < 100:
            now += 1.0
            done.extend(router.step(now))
        assert [r.rid for r in done] == [0]
        assert len(done[0].tokens_out) == 3
    finally:
        router.close()


@pytest.mark.slow
def test_router_reaps_failed_process_replica_mid_run():
    """Kill one proc-topology worker mid-run: the router's next step reaps
    it (no hang), rewinds + requeues its lost requests, builds a replacement
    to hold the actuated count, and every request completes exactly once."""
    from repro.serving.replica import ProcessReplica

    cfg = TINY_CFGS["dense"]
    router = ReplicaRouter.from_topology(cfg, "proc", slots=SLOTS,
                                         max_seq=16, prefill_chunk=4,
                                         n_replicas=2, max_replicas=3)
    try:
        reqs = _requests(6, prompt_len=5, gen_len=6)
        for r in reqs:
            router.submit(r, now=0.0)
        done, now = [], 0.0
        while len(done) < 2 and now < 100:   # victim serves real work first
            now += 1.0
            done.extend(router.step(now))
        victim = router.replicas[1]
        assert isinstance(victim, ProcessReplica)
        victim._proc.kill()
        victim._proc.wait(timeout=30)
        while len(done) < 6 and now < 200:
            now += 1.0
            done.extend(router.step(now))
        assert sorted(r.rid for r in done) == list(range(6))
        assert all(len(r.tokens_out) == 6 for r in done)
        assert router.replica_count == 2   # replacement spawned
        # crash-proof accounting: the victim's pre-crash completions stay in
        # fleet metrics via the parent-side lifetime mirror
        assert router.metrics()["completed"] == 6
        assert victim.replica_id not in [r.replica_id
                                         for r in router.replicas]
        # the crash is VISIBLE to the control plane: the next report round
        # carries the victim's n_errors report, which the collector turns
        # into a straggler flag; the round after that clears it
        from repro.core.monitoring.collector import MetricsCollector
        collector = MetricsCollector()
        for rep in router.reports(0):
            collector.submit(rep)
        assert victim.replica_id in collector.stragglers()
        for rep in router.reports(1):
            collector.submit(rep)
        assert victim.replica_id not in collector.stragglers()
    finally:
        router.close()
