"""Correctness of the beyond-paper performance paths (EXPERIMENTS.md §Perf):

  * expert-parallel shard_map MoE dispatch  ≡ global reference path
  * split-K (flash-decoding) decode attention ≡ unsharded decode
  * padded-head attention sharding          ≡ unsharded attention

Each runs in a subprocess with 8 host devices (the device-count override
must not leak into the main test process).
"""
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

PRELUDE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.models import LM, ModelConfig, MoECfg
from repro.sharding import TRAIN_RULES, SERVE_RULES, shard_ctx
from repro.launch.mesh import make_mesh
key = jax.random.PRNGKey(0)
"""


def run_sub(code: str):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", PRELUDE + code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_moe_ep_path_matches_global():
    out = run_sub(r"""
cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=32, n_heads=4,
                  n_kv_heads=2, d_ff=64, vocab=64, param_dtype="float32",
                  dtype="float32",
                  moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=32,
                             capacity_factor=4.0))
params, _ = LM.init(key, cfg)
batch = {"tokens": jax.random.randint(key, (4, 16), 0, 64)}
ref, aux_ref = LM.apply(params, batch, cfg)
mesh = make_mesh((2, 4), ("data", "model"))
def f(p, b):
    with shard_ctx(TRAIN_RULES, mesh):
        return LM.apply(p, b, cfg)
got, aux = jax.jit(f)(params, batch)
assert float(jnp.max(jnp.abs(got - ref))) < 1e-4
assert abs(float(aux["z_loss"]) - float(aux_ref["z_loss"])) < 1e-3
assert float(aux["drop_frac"]) == 0.0
# gradients flow through the shard_map dispatch
from repro.models.steps import make_train_step, init_train_state
ts, (oi, _) = make_train_step(cfg, lr=1e-3)
st = init_train_state(key, cfg, oi)
def g(s, b):
    with shard_ctx(TRAIN_RULES, mesh):
        return ts(s, b)
b2 = dict(batch); b2["labels"] = batch["tokens"]
st2, m = jax.jit(g)(st, b2)
assert np.isfinite(float(m["loss"])) and np.isfinite(float(m["grad_norm"]))
print("OK")
""")
    assert out.strip().endswith("OK")


def test_splitk_decode_matches_reference():
    out = run_sub(r"""
cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=8,
                  n_kv_heads=2, d_ff=64, vocab=64, param_dtype="float32",
                  dtype="float32")
params, _ = LM.init(key, cfg)
batch = {"tokens": jax.random.randint(key, (8, 16), 0, 64)}
lp, cache = LM.prefill(params, batch, cfg, max_seq=32)
tok = jnp.argmax(lp[:, 0], -1).astype(jnp.int32)[:, None]
ld_ref, cache_ref = LM.decode(params, tok, cfg, cache)
mesh = make_mesh((2, 4), ("data", "model"))
def f(p, t, c):
    with shard_ctx(SERVE_RULES, mesh):
        return LM.decode(p, t, cfg, c)
ld, cache_sk = jax.jit(f)(params, tok, cache)
assert float(jnp.max(jnp.abs(ld - ld_ref))) < 1e-4
assert float(jnp.max(jnp.abs(cache_sk["layers"]["k"]
                             - cache_ref["layers"]["k"]))) < 1e-4
# second step continues from the split-K-updated cache
t2 = jnp.argmax(ld[:, 0], -1).astype(jnp.int32)[:, None]
ld2, _ = jax.jit(f)(params, t2, cache_sk)
ld2_ref, _ = LM.decode(params, t2, cfg, cache_ref)
assert float(jnp.max(jnp.abs(ld2 - ld2_ref))) < 1e-4
print("OK")
""")
    assert out.strip().endswith("OK")


def test_padded_heads_match_reference():
    out = run_sub(r"""
# heads=10, kv=2 on a 4-wide model axis: pads to 12 (divisible by 4 and 2)
cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=40,
                  n_heads=10, n_kv_heads=2, d_ff=64, vocab=64, head_dim=4,
                  param_dtype="float32", dtype="float32")
params, _ = LM.init(key, cfg)
batch = {"tokens": jax.random.randint(key, (4, 16), 0, 64)}
ref, _ = LM.apply(params, batch, cfg)
mesh = make_mesh((2, 4), ("data", "model"))
def f(p, b):
    with shard_ctx(TRAIN_RULES, mesh):
        return LM.apply(p, b, cfg)
got, _ = jax.jit(f)(params, batch)
assert float(jnp.max(jnp.abs(got - ref))) < 1e-4
print("OK")
""")
    assert out.strip().endswith("OK")


def test_serve_rules_are_tp_only():
    """Serving layout: weights replicated over data (no per-token FSDP
    gathers), sharded over model; cache sequence-sharded over model."""
    from repro.sharding import SERVE_RULES, TRAIN_RULES
    assert SERVE_RULES.get("embed") == ()
    assert SERVE_RULES.get("cache_seq") == ("model",)
    assert SERVE_RULES.get("ff") == ("model",)
    assert TRAIN_RULES.get("embed") == ("data",)     # training keeps FSDP
