"""SLO tiers + heterogeneous fleets: laned admission, tier-aware routing,
preemptible capacity, and the control-plane satellites.

Satellite regressions — each verified FAILING on the pre-fix src:

 1. ``ScalingOptimizer.optimize`` built its ranking key as
    ``(not feasible, cost, lat)`` — ``target_util[0]`` (the low water mark
    the adaptation engine tunes) was never consulted, so under a flat cost
    curve the latency tie-break overprovisioned forever.
 2. The closed loop published a single-sample ``rps_window`` every tick, so
    ``analyze_current_load``'s std was always 0 and peak always equaled
    mean — burstiness never reached the planner.
 3. ``ReplicaRouter.metrics()["slot_utilization"]`` was an unweighted mean
    over every replica that EVER existed: under evict-replace churn each
    short-lived replacement's near-zero lifetime average diluted the fleet
    number as much as a run-long survivor's.

The tier equivalence suite pins the compatibility contract: a single-tier
workload on the laned scheduler is bit-identical to the pre-tier system
(same pop order, same rng stream, same token streams across inproc/proc),
and only profiled fleets route any differently.
"""
import dataclasses
import functools

import numpy as np
import pytest

from conftest import TINY_CFGS

CFG = TINY_CFGS["dense"]
MAX_SEQ = 24
SLOTS = 2


@functools.lru_cache(maxsize=None)
def shared_core():
    from repro.serving.engine import EngineCore
    return EngineCore(CFG, MAX_SEQ, seed=0)


def make_router(n_replicas=1, max_replicas=4, profile_fn=None):
    from repro.serving import ReplicaRouter, ServingEngine

    core = shared_core()

    def factory(replica_id):
        return ServingEngine(CFG, slots=SLOTS, max_seq=MAX_SEQ,
                             prefill_chunk=4, core=core,
                             replica_id=replica_id)

    # profile_fn only when given: the satellite regression tests run this
    # helper against the pre-fix src, which predates the kwarg
    kw = {} if profile_fn is None else {"profile_fn": profile_fn}
    return ReplicaRouter(factory, n_replicas=n_replicas,
                         max_replicas=max_replicas, **kw)


def req(rid, *, tier="interactive", prompt_len=6, gen_len=3, seed=None):
    from repro.serving import Request
    rng = np.random.default_rng(rid if seed is None else seed)
    # tier kwarg only when non-default, so the satellite regression tests
    # construct pre-fix Requests (which predate the field) unchanged
    kw = {} if tier == "interactive" else {"tier": tier}
    return Request(rid=rid,
                   prompt=rng.integers(3, CFG.vocab,
                                       size=prompt_len).astype(np.int32),
                   gen_len=gen_len, **kw)


# ----------------------------------------------------------- scheduler lanes


def test_single_tier_pop_order_is_fcfs():
    """Lanes on, one tier in play: the laned scheduler IS the old FCFS
    queue — submit order in, submit order out."""
    from repro.serving.scheduler import FCFSScheduler

    sched = FCFSScheduler()
    for i in range(5):
        sched.submit(req(i))
    assert sched.depth == 5
    assert sched.lane_depth("interactive") == 5
    assert [sched.pop().rid for _ in range(5)] == list(range(5))
    assert not sched


def test_interactive_lane_has_priority_fcfs_within_lane():
    from repro.serving.scheduler import FCFSScheduler

    sched = FCFSScheduler()
    sched.submit(req(0, tier="batch"))
    sched.submit(req(1))
    sched.submit(req(2, tier="batch"))
    sched.submit(req(3))
    # interactive drains first (FCFS within the lane), then batch FCFS
    assert [sched.pop().rid for _ in range(4)] == [1, 3, 0, 2]


def test_batch_gate_hides_lane_but_counts_depth():
    from repro.serving.scheduler import FCFSScheduler

    sched = FCFSScheduler()
    sched.submit(req(0, tier="batch"))
    sched.submit(req(1))
    sched.batch_gated = True
    assert sched.depth == 2                  # gated work still queues
    assert sched.pop().rid == 1
    assert not sched                         # only gated batch left
    assert sched.depth == 1
    with pytest.raises(IndexError):
        sched.pop()
    sched.batch_gated = False
    assert sched
    assert sched.pop().rid == 0


def test_drain_empties_gated_lanes_too():
    from repro.serving.scheduler import FCFSScheduler

    sched = FCFSScheduler()
    sched.submit(req(0, tier="batch"))
    sched.submit(req(1))
    sched.batch_gated = True
    drained = sched.drain()
    assert sorted(r.rid for r in drained) == [0, 1]
    assert sched.depth == 0


def test_unknown_tier_rejected():
    from repro.serving.scheduler import validate_tier

    with pytest.raises(ValueError):
        validate_tier("bulk")


# ------------------------------------------------------------ tier workloads


def test_tiered_requests_prompt_stream_identity():
    """The tier draw comes AFTER the prompts: a tiered stream's prompts are
    token-for-token the single-tier stream's on the same seed."""
    from repro.serving.workload import synthetic_requests, tiered_requests
    from repro.sim.serving import WorkloadSpec

    spec = WorkloadSpec(prompt_len=8, gen_len=4)
    plain = synthetic_requests(spec, 12, CFG.vocab,
                               rng=np.random.default_rng(7))
    mixed = tiered_requests(spec, 12, CFG.vocab, batch_frac=0.5,
                            rng=np.random.default_rng(7))
    for a, b in zip(plain, mixed):
        np.testing.assert_array_equal(a.prompt, b.prompt)
    assert {r.tier for r in mixed} == {"interactive", "batch"}
    # batch_frac=0 consumes NO extra rng: the next draw matches
    rng_a, rng_b = np.random.default_rng(7), np.random.default_rng(7)
    synthetic_requests(spec, 12, CFG.vocab, rng=rng_a)
    tiered_requests(spec, 12, CFG.vocab, batch_frac=0.0, rng=rng_b)
    assert rng_a.random() == rng_b.random()


# -------------------------------------------------- profiled fleet routing


def test_interactive_never_lands_on_preemptible():
    from repro.serving import ReplicaProfile

    def profiles(rid):
        return ReplicaProfile(cost_per_tick=0.35, preemptible=True) \
            if rid >= 1 else ReplicaProfile()

    router = make_router(n_replicas=2, profile_fn=profiles)
    for i in range(4):
        router.submit(req(i), now=0.0)
    # unprofiled least-loaded would spread 2/2; tier placement pins all
    # interactive work to the one stable replica
    depths = [r.queue_depth for r in router.replicas]
    assert depths == [4, 0]
    # batch is free to take the cheap volatile capacity (and does: zero
    # load + lower cost_per_tick beats the loaded on-demand replica)
    router.submit(req(10, tier="batch"), now=0.0)
    assert router.replicas[1].queue_depth == 1
    assert router.tier_spills == 0


def test_interactive_spills_when_fleet_is_all_spot():
    from repro.serving import ReplicaProfile

    router = make_router(
        n_replicas=2,
        profile_fn=lambda rid: ReplicaProfile(preemptible=True))
    router.submit(req(0), now=0.0)
    assert router.tier_spills == 1           # admitted, but recorded
    assert router.pending == 1


def test_cheaper_replica_wins_load_ties():
    from repro.serving import ReplicaProfile

    def profiles(rid):
        return ReplicaProfile(cost_per_tick=0.35, preemptible=True) \
            if rid >= 1 else ReplicaProfile()

    router = make_router(n_replicas=2, profile_fn=profiles)
    router.submit(req(0, tier="batch"), now=0.0)
    # both empty: the spot replica (id 1) is cheaper and takes the work —
    # the unprofiled tie-break (lowest id) would have picked replica 0
    assert [r.queue_depth for r in router.replicas] == [0, 1]


def test_unprofiled_router_keeps_legacy_placement():
    router = make_router(n_replicas=2)
    for i in range(4):
        router.submit(req(i), now=0.0)
    assert [r.queue_depth for r in router.replicas] == [2, 2]


# ----------------------------------------------------------- preemption


def _preempt_run():
    """2-replica profiled fleet; replica 1 (spot) is reclaimed mid-decode.
    Returns (router, {rid: tokens})."""
    from repro.serving import ReplicaProfile

    def profiles(rid):
        return ReplicaProfile(cost_per_tick=0.35, preemptible=True) \
            if rid >= 1 else ReplicaProfile()

    router = make_router(n_replicas=2, profile_fn=profiles)
    reqs = [req(i, tier="batch" if i % 2 else "interactive", gen_len=4)
            for i in range(6)]
    for r in reqs:
        router.submit(r, now=0.0)
    done, now = [], 0.0
    for _ in range(2):                       # decode is genuinely mid-flight
        now += 0.5
        done.extend(router.step(now))
    assert router.preempt(1, now=now)
    while len(done) < len(reqs) and now < 500:
        now += 0.5
        done.extend(router.step(now))
    return router, reqs, done


def test_preemption_mid_decode_completes_exactly_once():
    router, reqs, done = _preempt_run()
    rids = [r.rid for r in done]
    assert sorted(rids) == sorted(r.rid for r in reqs)   # no loss, no dup
    for r in done:
        assert len(r.tokens_out) == 4
    assert router.preemptions == 1
    # spot capacity is NOT auto-replaced: the fleet shrank
    assert router.replica_count == 1
    # the reclaim must surface to the control plane as an error even though
    # an in-process replica dies with a clean metric window
    reports = router.reports(0)
    assert any(rep.n_errors > 0 for rep in reports)


def test_preemption_replay_is_deterministic():
    _, _, a = _preempt_run()
    _, _, b = _preempt_run()
    assert {r.rid: list(r.tokens_out) for r in a} \
        == {r.rid: list(r.tokens_out) for r in b}


def test_preempt_refuses_last_serving_replica():
    router = make_router(n_replicas=1)
    router.submit(req(0), now=0.0)
    assert not router.preempt(0)
    assert router.replica_count == 1
    done, now = [], 0.0
    while len(done) < 1 and now < 100:
        now += 0.5
        done.extend(router.step(now))
    assert len(done) == 1


# ----------------------------------------------------------- batch gate


def test_gate_blocks_batch_admission_until_released():
    router = make_router(n_replicas=1)
    router.gate_batch(True)
    router.submit(req(0, tier="batch"), now=0.0)
    router.submit(req(1), now=0.0)
    done, now = [], 0.0
    for _ in range(40):
        now += 0.5
        done.extend(router.step(now))
    assert [r.rid for r in done] == [1]      # interactive drained alone
    assert router.pending == 1               # batch queued, not lost
    router.gate_batch(False)
    while len(done) < 2 and now < 200:
        now += 0.5
        done.extend(router.step(now))
    assert sorted(r.rid for r in done) == [0, 1]


@pytest.mark.slow
def test_gate_rides_step_rpc_to_remote_worker():
    """ProcessReplica: the gate change travels inside the next step message
    (no dedicated RPC) and lands before that round's admission."""
    from repro.serving.replica import ProcessReplica

    rep = ProcessReplica(CFG, slots=SLOTS, max_seq=MAX_SEQ, prefill_chunk=4,
                         batch_submits=True)
    try:
        rep.gate_batch(True)
        rep.submit(req(0, tier="batch"), now=0.0)
        rep.submit(req(1), now=0.0)
        done, now = [], 0.0
        for _ in range(40):
            now += 0.5
            done.extend(rep.step(now))
        assert [r.rid for r in done] == [1]
        assert rep.pending == 1
        rep.gate_batch(False)
        while len(done) < 2 and now < 200:
            now += 0.5
            done.extend(rep.step(now))
        assert sorted(r.rid for r in done) == [0, 1]
    finally:
        rep.close()


def test_batch_gate_decision_hysteresis():
    """Trips at batch_gate_frac x SLO on the INTERACTIVE p95 channel,
    releases only once the lane recovers to half the trip point."""
    from repro.core.scaling.scaler import DynamicScaler, ScalingConstraints

    s = DynamicScaler(None, lambda r, load: (0.0, 0.0))
    c = ScalingConstraints(slo_ms=1000.0, batch_gate_frac=0.9)
    assert not s.batch_gate_decision({"latency_p95_interactive": 800.0}, c)
    assert s.batch_gate_decision({"latency_p95_interactive": 950.0}, c)
    # inside the hysteresis band: stays gated
    assert s.batch_gate_decision({"latency_p95_interactive": 600.0}, c)
    assert not s.batch_gate_decision({"latency_p95_interactive": 400.0}, c)
    # and re-arming needs a full trip again
    assert not s.batch_gate_decision({"latency_p95_interactive": 600.0}, c)


# ------------------------------------------- per-tier latency channels


def test_collector_publishes_per_tier_p95():
    from repro.core.monitoring.collector import (
        MetricsCollector, ReplicaReport,
    )

    col = MetricsCollector()
    col.submit(ReplicaReport(
        replica_id=0, tick=0, latency_ms_samples=[100.0, 120.0, 900.0],
        n_requests=3, n_errors=0, flop_util=0.5, hbm_util=0.5, ici_util=0.0,
        mem_frac=0.5, queue_depth=0,
        lat_tiers={"interactive": [100.0, 120.0], "batch": [900.0]}))
    rec = col.aggregate(0, n_replicas=1, max_replicas=2)
    assert rec["latency_p95_interactive"] < 200.0
    assert rec["latency_p95_batch"] == pytest.approx(900.0)
    # empty tiers read 0.0, not NaN
    col2 = MetricsCollector()
    col2.submit(ReplicaReport(
        replica_id=0, tick=0, latency_ms_samples=[], n_requests=0,
        n_errors=0, flop_util=0.0, hbm_util=0.0, ici_util=0.0,
        mem_frac=0.0, queue_depth=0))
    rec2 = col2.aggregate(0, n_replicas=1, max_replicas=2)
    assert rec2["latency_p95_interactive"] == 0.0
    assert rec2["latency_p95_batch"] == 0.0


# ------------------------------------------------- closed-loop equivalence


def _loop(topology, batch_frac, *, reserved=0, ticks=5, seed=0):
    from repro.serving.closed_loop import LoopConfig, run_closed_loop
    from repro.sim.serving import WorkloadSpec

    lc = LoopConfig(slots=2, max_replicas=2, max_seq=32, prefill_chunk=4,
                    steps_per_tick=6, topology=topology,
                    batch_frac=batch_frac, reserved_replicas=reserved)
    sink = []
    router, logs = run_closed_loop(
        TINY_CFGS["dense"], autoscale=True, ticks=ticks, seed=seed, lc=lc,
        spec=WorkloadSpec(prompt_len=6, gen_len=3), sink=sink)
    router.close()
    return sink, logs


@pytest.mark.slow
def test_single_tier_closed_loop_matches_across_topologies():
    """batch_frac=0: the laned loop is the pre-tier loop — same arrivals,
    same token streams, inproc and proc alike."""
    sink_i, logs_i = _loop("inproc", 0.0)
    sink_p, logs_p = _loop("proc", 0.0)
    assert {r.rid: list(r.tokens_out) for r in sink_i} \
        == {r.rid: list(r.tokens_out) for r in sink_p}
    assert [t.arrivals for t in logs_i] == [t.arrivals for t in logs_p]
    assert all(r.tier == "interactive" for r in sink_i)


@pytest.mark.slow
def test_mixed_tier_closed_loop_matches_across_topologies():
    """Tier labels survive the wire: a mixed-tier heterogeneous run on proc
    completes the same streams with the same tiers as inproc."""
    sink_i, _ = _loop("inproc", 0.5, reserved=1)
    sink_p, _ = _loop("proc", 0.5, reserved=1)
    assert {r.rid: (r.tier, list(r.tokens_out)) for r in sink_i} \
        == {r.rid: (r.tier, list(r.tokens_out)) for r in sink_p}
    assert {r.tier for r in sink_i} == {"interactive", "batch"}


def test_closed_loop_fixed_seed_is_deterministic():
    """Satellite 4 (deque arrival drain): same seed, stream-identical logs
    and token streams — the O(n) drain changed nothing observable."""
    sink_a, logs_a = _loop("inproc", 0.0, ticks=4)
    sink_b, logs_b = _loop("inproc", 0.0, ticks=4)
    assert {r.rid: list(r.tokens_out) for r in sink_a} \
        == {r.rid: list(r.tokens_out) for r in sink_b}
    assert [(t.arrivals, t.served, t.replicas, t.latency_p95_ms)
            for t in logs_a] \
        == [(t.arrivals, t.served, t.replicas, t.latency_p95_ms)
            for t in logs_b]


# --------------------------------------------------- satellite regressions


def test_optimizer_consults_low_water_mark():
    """Regression 1 (verified FAILING on the pre-fix src): with a flat cost
    curve the pre-fix key ``(not feasible, cost, lat)`` let the latency
    tie-break pick the BIGGEST feasible fleet (util far below the band);
    the low-water-mark term must prefer the in-band point."""
    from repro.core.scaling.scaler import (
        ScalingConstraints, ScalingOptimizer,
    )

    def perf(r, load):
        util = min(load / (r * 10.0), 1.0)
        return 100.0 * util, util

    opt = ScalingOptimizer(perf)
    c = ScalingConstraints(min_replicas=1, max_replicas=4, max_step=4,
                           slo_ms=1000.0, target_util=(0.55, 0.85),
                           cost_per_replica=0.0)
    d = opt.optimize(current_load={}, predicted_load=14.0, efficiency=1.0,
                     constraints=c, current_replicas=2)
    # r=2 → util 0.70 (in band); r=3,4 → under the low water mark with
    # lower latency — pre-fix the key picked r=4
    assert d.target_replicas == 2


def test_rps_window_is_a_rolling_multi_tick_history():
    """Regression 2 (verified FAILING on the pre-fix src): a bursty profile
    must produce a published window with real spread (pre-fix every tick's
    window was the single current sample: std 0, peak == mean)."""
    from repro.core.dnn.traces import TraceRecorder
    from repro.serving.closed_loop import LoopConfig, run_closed_loop
    from repro.sim.serving import WorkloadSpec

    # plain pre-fix-constructible LoopConfig (no rps_window kwarg): the
    # regression must fail on the OLD behavior, not on a missing field
    lc = LoopConfig(slots=2, max_replicas=2, max_seq=32, prefill_chunk=4,
                    steps_per_tick=6)
    rec = TraceRecorder()

    def bursty(tick, ticks, lc):
        return lc.spike_rps if tick % 2 else lc.calm_rps

    router, _ = run_closed_loop(TINY_CFGS["dense"], autoscale=True, ticks=5,
                                seed=0, lc=lc, profile=bursty,
                                spec=WorkloadSpec(prompt_len=6, gen_len=3),
                                recorder=rec)
    router.close()
    windows = [r["rps_window"] for r in rec.records]
    assert max(len(w) for w in windows) > 1      # pre-fix: every len == 1
    assert max(len(w) for w in windows) <= LoopConfig().rps_window
    spreads = [np.std(w) for w in windows]
    assert max(spreads) > 0.0
    last = windows[-1]
    assert np.max(last) != np.mean(last)


def test_slot_utilization_is_tick_weighted():
    """Regression 3 (verified FAILING on the pre-fix src): a short-lived
    scale-up must weigh its few ticks, not count like a run-long survivor
    (pre-fix: unweighted mean over every replica ever → churn halved the
    fleet number)."""
    router = make_router(n_replicas=1, max_replicas=4)
    for i in range(8):
        router.submit(req(i, gen_len=3), now=0.0)
    now = 0.0
    while router.pending and now < 100:
        now += 0.5
        router.step(now)
    busy_util = router.serving_replicas[0].lifetime()["slot_utilization"]
    assert busy_util > 0.5
    # one churn cycle: a replica that serves ~one idle tick then parks
    router.scale_to(2, now=now)
    now += 0.5
    router.step(now)
    router.scale_to(1, now=now)
    got = router.metrics()["slot_utilization"]
    # unweighted: (busy + ~0)/2 ≈ busy/2 — far below this bar
    assert got > 0.75 * busy_util
