"""The 10 assigned architectures: exact config numbers from the assignment
table, applicable-shape rules, parameter-count sanity, smoke-config viability.
"""
import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import LM, SHAPES, applicable_shapes
from repro.models.steps import input_structs

# (layers, d_model, heads, kv, d_ff, vocab) from the assignment table
ASSIGNED = {
    "zamba2-2.7b":          (54, 2560, 32, 32, 10240, 32000),
    "qwen2-vl-7b":          (28, 3584, 28, 4, 18944, 152064),
    "qwen2.5-3b":           (36, 2048, 16, 2, 11008, 151936),
    "h2o-danube-1.8b":      (24, 2560, 32, 8, 6912, 32000),
    "qwen2-72b":            (80, 8192, 64, 8, 29568, 152064),
    "qwen2.5-14b":          (48, 5120, 40, 8, 13824, 152064),
    "olmoe-1b-7b":          (16, 2048, 16, 16, 1024, 50304),
    "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
    "falcon-mamba-7b":      (64, 4096, 0, 0, 0, 65024),
    "seamless-m4t-medium":  (12, 1024, 16, 16, 4096, 256206),
}

# approximate parameter counts implied by the model names (billions)
NAMED_PARAMS_B = {
    "zamba2-2.7b": 2.7, "qwen2-vl-7b": 7.0, "qwen2.5-3b": 3.0,
    "h2o-danube-1.8b": 1.8, "qwen2-72b": 72.0, "qwen2.5-14b": 14.0,
    "olmoe-1b-7b": 7.0, "phi3.5-moe-42b-a6.6b": 42.0,
    # seamless "medium" is ~1.2B for the full multimodal model; we build the
    # transformer BACKBONE only (audio frontend is a stub per the assignment),
    # which is ~0.7B — the expectation reflects the backbone scope.
    "falcon-mamba-7b": 7.0, "seamless-m4t-medium": 0.7,
}


def test_all_ten_archs_registered():
    assert set(ARCH_IDS) == set(ASSIGNED)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_assigned_dimensions_exact(arch):
    L, d, H, KV, ff, V = ASSIGNED[arch]
    cfg = get_config(arch)
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == KV
    assert cfg.vocab == V
    if cfg.moe is not None:
        pass                       # d_ff column is the per-expert width
    elif cfg.ssm is not None and cfg.family == "ssm":
        assert cfg.d_ff == 0       # attention-free mamba has no FFN
    else:
        assert cfg.d_ff == ff


def test_family_specific_fields():
    assert get_config("zamba2-2.7b").ssm.d_state == 64
    assert get_config("zamba2-2.7b").ssm.version == 2
    assert get_config("falcon-mamba-7b").ssm.d_state == 16
    assert get_config("falcon-mamba-7b").ssm.version == 1
    assert get_config("olmoe-1b-7b").moe.n_experts == 64
    assert get_config("olmoe-1b-7b").moe.top_k == 8
    assert get_config("phi3.5-moe-42b-a6.6b").moe.n_experts == 16
    assert get_config("phi3.5-moe-42b-a6.6b").moe.top_k == 2
    assert get_config("qwen2-vl-7b").m_rope
    assert get_config("h2o-danube-1.8b").sliding_window is not None
    assert get_config("seamless-m4t-medium").enc_dec
    assert get_config("qwen2.5-3b").qkv_bias


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_param_count_matches_model_name(arch):
    n = get_config(arch).n_params() / 1e9
    want = NAMED_PARAMS_B[arch]
    assert 0.6 * want <= n <= 1.45 * want, f"{arch}: {n:.2f}B vs {want}B"


def test_moe_active_params():
    cfg = get_config("phi3.5-moe-42b-a6.6b")     # 42B total, 6.6B active
    assert 30 <= cfg.n_params() / 1e9 <= 50
    assert 4 <= cfg.active_params() / 1e9 <= 9
    dense = get_config("qwen2.5-3b")
    assert dense.active_params() == dense.n_params()


def test_applicable_shapes_rules():
    """long_500k only for sub-quadratic archs (SSM / hybrid / SWA)."""
    runs_long = {a for a in ARCH_IDS
                 if "long_500k" in applicable_shapes(get_config(a))}
    assert runs_long == {"zamba2-2.7b", "falcon-mamba-7b", "h2o-danube-1.8b"}
    for a in ARCH_IDS:
        shapes = applicable_shapes(get_config(a))
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)


def test_assigned_shape_cells():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_smoke_config_runs_forward(arch):
    """Reduced same-family config: one forward on CPU, shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    assert cfg.family == get_config(arch).family
    assert cfg.n_params() < 50e6           # genuinely small
    key = jax.random.PRNGKey(0)
    params, _ = LM.init(key, cfg)
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        import jax.numpy as jnp
        batch["patches"] = jnp.ones((B, cfg.n_vision_patches, cfg.d_model),
                                    cfg.cdtype)
    if cfg.enc_dec:
        import jax.numpy as jnp
        batch["frames"] = jnp.ones((B, S, cfg.d_model), cfg.cdtype)
    logits, _ = LM.apply(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(np.isfinite(np.asarray(logits, np.float32)).all())


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_input_structs_no_allocation(arch):
    """ShapeDtypeStruct stand-ins exist for every applicable cell — the exact
    inputs the dry-run lowers; nothing is allocated here."""
    cfg = get_config(arch)
    for shape_name in applicable_shapes(cfg):
        structs = input_structs(cfg, SHAPES[shape_name])
        for leaf in jax.tree.leaves(structs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
