"""ReplicaRouter: least-loaded routing, conservation under random arrivals
and mid-run scaling (no request lost or double-completed), and throughput
accounting (reported throughput == completed tokens / wall time).

The conservation check is one shared helper; deterministic tests pin fixed
seeds (always run), and hypothesis — when installed — fuzzes the same helper
over random arrival/scaling sequences.
"""
import functools

import numpy as np
import pytest

from repro.serving import ReplicaRouter, Request, SamplingParams
from repro.serving.engine import EngineCore

from conftest import TINY_CFGS

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

MAX_SEQ = 24
SLOTS = 2
TICK_S = 0.5


@functools.lru_cache(maxsize=None)
def shared_core() -> EngineCore:
    return EngineCore(TINY_CFGS["dense"], MAX_SEQ, seed=0)


def make_router(n_replicas=1, max_replicas=4) -> ReplicaRouter:
    core = shared_core()
    cfg = TINY_CFGS["dense"]

    def factory(replica_id):
        from repro.serving import ServingEngine
        return ServingEngine(cfg, slots=SLOTS, max_seq=MAX_SEQ,
                             prefill_chunk=4, core=core,
                             replica_id=replica_id)

    return ReplicaRouter(factory, n_replicas=n_replicas,
                         max_replicas=max_replicas)


def run_sequence(arrivals, scale_events, *, n_replicas=1, max_steps=2000):
    """arrivals: [(step, prompt_len, gen_len)]; scale_events: {step: target}.
    Returns (router, completed, wall_time).  Asserts conservation."""
    cfg = TINY_CFGS["dense"]
    rng = np.random.default_rng(0)
    router = make_router(n_replicas=n_replicas)
    reqs = {
        i: Request(rid=i,
                   prompt=rng.integers(3, cfg.vocab, size=p).astype(np.int32),
                   gen_len=g)
        for i, (_, p, g) in enumerate(arrivals)
    }
    by_step: dict[int, list[int]] = {}
    for i, (s, _, _) in enumerate(arrivals):
        by_step.setdefault(s, []).append(i)
    completed, now, step = [], 0.0, 0
    while step < max_steps:
        now = step * TICK_S
        for i in by_step.get(step, []):
            router.submit(reqs[i], now=now)
        if step in scale_events:
            router.scale_to(scale_events[step], now=now)
        completed.extend(router.step(now))
        step += 1
        if step > max(by_step, default=0) and router.pending == 0 \
                and len(completed) == len(reqs):
            break
    # conservation: every request completed exactly once
    rids = [r.rid for r in completed]
    assert sorted(rids) == sorted(reqs), (
        f"lost={set(reqs) - set(rids)} dup="
        f"{ {r for r in rids if rids.count(r) > 1} }")
    for r in completed:
        assert r.t_done is not None and r.t_submit is not None
        assert r.t_done >= r.t_submit
        assert len(r.tokens_out) == reqs[r.rid].gen_len
    return router, completed, now


# ------------------------------------------------------------ deterministic


def test_least_loaded_routing_spreads_requests():
    router = make_router(n_replicas=2)
    cfg = TINY_CFGS["dense"]
    for i in range(4):
        router.submit(Request(rid=i,
                              prompt=np.full(6, 3 + i, np.int32),
                              gen_len=3), now=0.0)
    # 2 replicas × 2 slots: least-loaded routing alternates replicas
    depths = [r.queue_depth for r in router.replicas]
    assert depths == [2, 2]


def test_conservation_fixed_burst():
    arrivals = [(0, 6, 3)] * 7 + [(3, 8, 4)] * 5
    router, completed, _ = run_sequence(arrivals, {})
    assert len(completed) == 12


def test_conservation_with_mid_run_scaling():
    arrivals = [(i, 5 + (i % 4), 2 + (i % 3)) for i in range(14)]
    router, completed, _ = run_sequence(
        arrivals, {2: 3, 6: 1, 9: 2}, n_replicas=1)
    assert len(completed) == 14
    assert {r.replica_id for r in completed} != {0}    # scaling actually ran


def test_throughput_equals_tokens_over_wall_time():
    arrivals = [(0, 6, 4)] * 6 + [(2, 6, 4)] * 6
    router, completed, now = run_sequence(arrivals, {1: 2})
    m = router.metrics()
    tokens = sum(len(r.tokens_out) for r in completed)
    assert m["completed_tokens"] == tokens
    wall = now - min(r.t_submit for r in completed)
    assert m["throughput_tok_s"] == pytest.approx(tokens / wall, rel=1e-6)


def test_reports_feed_metrics_collector():
    from repro.core.monitoring.collector import MetricsCollector
    arrivals = [(0, 6, 3)] * 6
    router, completed, _ = run_sequence(arrivals, {0: 2})
    collector = MetricsCollector()
    for rep in router.reports(tick=0):
        collector.submit(rep)
    rec = collector.aggregate(0, n_replicas=router.replica_count,
                              max_replicas=4)
    assert rec["throughput"] == len(completed)
    assert rec["latency_p95"] >= rec["latency_p50"] > 0


def test_scale_to_respects_bounds():
    router = make_router(n_replicas=1, max_replicas=3)
    assert router.scale_to(100) == 3
    assert router.scale_to(0) == 1
    assert router.scale_to(-5) == 1


def test_downscale_requeues_in_flight_requests():
    """Regression: a mid-generation downscale must REQUEUE the victim's
    in-flight requests through the survivors' schedulers — previously they
    stayed behind on the draining replica (stranded until it finished).
    The victim parks immediately; every request still completes exactly
    once, with its full token budget, on a surviving replica."""
    router = make_router(n_replicas=2)
    reqs = [Request(rid=i, prompt=np.full(6, 4, np.int32), gen_len=6)
            for i in range(4)]
    for r in reqs:
        router.submit(r, now=0.0)
    router.step(0.0)                       # all four admitted (2×2 slots)
    for _ in range(2):                     # …and 2 tokens into generation
        router.step(0.0)
    victim_rids = {r.rid for r in reqs if r.replica_id == 1}
    assert victim_rids                     # some work really was in flight
    router.scale_to(1, now=0.0)
    assert len(router.replicas) == 1       # victim parked IMMEDIATELY
    # the preempted requests are back in the survivor's system, not stranded
    assert router.pending == 4
    completed, now = [], 0.0
    while len(completed) < 4 and now < 100:
        now += TICK_S
        completed.extend(router.step(now))
    assert sorted(r.rid for r in completed) == [0, 1, 2, 3]
    for r in completed:
        assert len(r.tokens_out) == 6      # full budget despite preemption
        assert r.replica_id == 0           # finished on the survivor


# ------------------------------------------------------------- property


if HAVE_HYPOTHESIS:
    arrival_strategy = st.lists(
        st.tuples(st.integers(0, 12),          # arrival step
                  st.integers(1, 10),          # prompt_len
                  st.integers(1, 6)),          # gen_len
        min_size=1, max_size=16)
    scaling_strategy = st.dictionaries(
        st.integers(0, 12), st.integers(1, 4), max_size=4)

    @settings(max_examples=12, deadline=None)
    @given(arrivals=arrival_strategy, scale_events=scaling_strategy)
    def test_property_no_request_lost_or_duplicated(arrivals, scale_events):
        run_sequence(arrivals, scale_events)

    @settings(max_examples=8, deadline=None)
    @given(arrivals=arrival_strategy)
    def test_property_throughput_accounting(arrivals):
        router, completed, now = run_sequence(arrivals, {})
        m = router.metrics()
        tokens = sum(len(r.tokens_out) for r in completed)
        assert m["completed_tokens"] == tokens
        wall = max(now - min(r.t_submit for r in completed), 1e-9)
        assert m["throughput_tok_s"] == pytest.approx(tokens / wall,
                                                      rel=1e-6)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_no_request_lost_or_duplicated():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_throughput_accounting():
        pass
