"""Checkpoint manager (atomic commit, async, GC, restore) + data pipeline
(deterministic counted stream — the preemption-resume contract) + the elastic
re-mesh restore path on a 1-device mesh.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, TokenPipeline, extra_inputs
from repro.models.steps import init_train_state, make_train_step


def small_state(seed=0):
    key = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(key, (8, 4)),
            "nested": {"b": jnp.arange(5.0), "step": jnp.asarray(3, jnp.int32)}}


def tree_equal(a, b):
    return all(bool(jnp.array_equal(x, y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------- checkpoint

def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = small_state()
    mgr.save(7, state, blocking=True)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored, manifest = mgr.restore(like)
    assert manifest["step"] == 7
    assert tree_equal(restored, state)


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = small_state()
    mgr.save(1, state)              # async
    mgr.wait()
    assert mgr.latest_step() == 1
    restored, _ = mgr.restore(jax.tree.map(jnp.zeros_like, state))
    assert tree_equal(restored, state)


def test_no_tmp_dirs_after_commit(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, small_state(), blocking=True)
    assert not list(tmp_path.glob("*.tmp"))
    assert (tmp_path / "step_1" / "manifest.json").exists()


def test_gc_keeps_newest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, small_state(), blocking=True)
    assert mgr.steps() == [3, 4]


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)
    s1, s2 = small_state(1), small_state(2)
    mgr.save(1, s1, blocking=True)
    mgr.save(2, s2, blocking=True)
    like = jax.tree.map(jnp.zeros_like, s1)
    r1, _ = mgr.restore(like, step=1)
    assert tree_equal(r1, s1) and not tree_equal(r1, s2)


def test_restore_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": jnp.zeros((4,))}, blocking=True)
    (tmp_path / "step_1" / "w.npy").unlink()
    np.save(tmp_path / "step_1" / "w.npy", np.zeros((5,)))
    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore({"w": jnp.zeros((4,))})


def test_restore_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        CheckpointManager(tmp_path).restore({"w": jnp.zeros(2)})


def test_elastic_restore_roundtrip(tmp_path):
    """Full TrainState through the elastic re-mesh path on a (1,1) mesh —
    the same code that re-shards onto a different topology after node loss."""
    from repro.configs import get_smoke_config
    from repro.launch.elastic import ReMesh, elastic_restore

    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"), n_layers=2)
    _, (opt_init, _) = make_train_step(cfg)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt_init)
    state = state._replace(step=jnp.asarray(5, jnp.int32))
    CheckpointManager(tmp_path).save(5, state, blocking=True)

    state2, jitted, mesh = elastic_restore(tmp_path, cfg,
                                           ReMesh(data_axis=1, model_axis=1))
    assert tree_equal(state2.params, state.params)
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
             "labels": jnp.zeros((2, 8), jnp.int32)}
    state3, metrics = jitted(state2, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state3.step) == 6


# ---------------------------------------------------------------- data

def test_pipeline_is_pure_in_step():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=4, seed=42)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    for step in (0, 7, 123):
        b1, b2 = p1.batch(step), p2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_pipeline_steps_differ():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=4)
    p = TokenPipeline(cfg)
    assert not np.array_equal(p.batch(0)["tokens"], p.batch(1)["tokens"])


def test_labels_are_next_tokens():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=2)
    b = TokenPipeline(cfg).batch(0)
    # labels[t] == tokens[t+1] within the same underlying (S+1) stream
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_tokens_within_vocab():
    cfg = DataConfig(vocab=50, seq_len=64, global_batch=4)
    b = TokenPipeline(cfg).batch(3)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 50
    assert b["tokens"].dtype == np.int32


def test_extra_inputs_deterministic():
    from conftest import TINY_CFGS
    cfg = TINY_CFGS["vlm"]
    b = {"tokens": np.ones((2, 8), np.int32)}
    e1, e2 = extra_inputs(cfg, b), extra_inputs(cfg, b)
    np.testing.assert_array_equal(e1["patches"], e2["patches"])
    assert e1["patches"].shape == (2, cfg.n_vision_patches, cfg.d_model)


def test_resume_reproduces_future_batches():
    """The preemption contract: a fresh pipeline at step k yields the exact
    batch a continuously-running pipeline would have produced."""
    cfg = DataConfig(vocab=70, seq_len=16, global_batch=2, seed=9)
    run = [TokenPipeline(cfg).batch(s)["tokens"] for s in range(5)]
    resumed = TokenPipeline(cfg)                 # "restarted process"
    np.testing.assert_array_equal(resumed.batch(3)["tokens"], run[3])
    np.testing.assert_array_equal(resumed.batch(4)["tokens"], run[4])
